"""Continuous-batching serving engine (Orca-style iteration batching).

A fixed pool of B cache *slots*; requests are admitted into free slots as
they arrive, every engine iteration runs ONE batched decode step across
all active slots (per-slot positions — see layers.attention_block's
vmap'd cache update), and finished slots are freed immediately for the
next waiting request.  Prefill runs per-request (batch=1) and its cache
rows are spliced into the slot pool.

This is the serve-side analog of the paper's D-MGPU lesson: placement is
explicit — each slot's KV rows live at a fixed batch index, sharded per
sharding/specs.py, and admission never moves resident data.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.base import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    # filled by the engine:
    output: typing.List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False          # prompt too long for the cache


# cache leaf -> batch axis (transformer/encdec/ssm/hybrid layouts)
_BATCH_AXIS = {"k": 1, "v": 1, "xk": 1, "xv": 1, "ssm": 1, "conv": 1,
               "ssm_tail": 1, "conv_tail": 1}
_HYBRID_AXIS = {"k": 1, "v": 1, "ssm": 2, "conv": 2,
                "ssm_tail": 1, "conv_tail": 1}


def _axis_for(cfg, key):
    table = _HYBRID_AXIS if cfg.family == "hybrid" else _BATCH_AXIS
    return table.get(key)


class Engine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_seq: int = 512, eos_token: int = -1) -> None:
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_token
        cache = api.init_cache(cfg, slots, max_seq)
        # engine-managed per-slot positions
        cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self.cache = cache
        self.active: typing.Dict[int, Request] = {}      # slot -> request
        self.remaining: typing.Dict[int, int] = {}
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.queue: typing.List[Request] = []
        self._finished_early: typing.List[Request] = []
        self.steps = 0
        self.prefills = 0
        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(p, cfg, c, t))
        self._prefill = jax.jit(
            lambda p, c, b: api.prefill(p, cfg, c, b))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request.  Returns False (request marked done+rejected,
        never queued) when the prompt cannot fit the cache: admitting it
        would splice/decode past row ``max_seq-1``, and jax's clamping
        ``.at[].set`` would silently corrupt the last cache row instead
        of raising."""
        if len(req.prompt) >= self.max_seq:
            req.rejected = True
            req.done = True
            return False
        self.queue.append(req)
        return True

    def _free_slots(self) -> typing.List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self) -> None:
        free = self._free_slots()
        while free and self.queue:
            req = self.queue.pop(0)
            if req.max_new_tokens <= 0:
                # nothing to generate: complete immediately, never touch
                # a slot (previously this pinned a slot through a decode
                # and emitted two spurious tokens)
                req.done = True
                self._finished_early.append(req)
                continue
            slot = free[0]
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]   # (1,S)
            mini = api.init_cache(self.cfg, 1, self.max_seq)
            logits, mini = self._prefill(self.params, mini,
                                         {"tokens": prompt})
            self.prefills += 1
            self._splice(mini, slot, int(prompt.shape[1]))
            tok = int(jnp.argmax(logits[0]))
            req.output.append(tok)
            if tok == self.eos or req.max_new_tokens == 1:
                # complete at admission: the prefill token is the whole
                # answer, so the slot stays free for the next request
                req.done = True
                self._finished_early.append(req)
                continue
            free.pop(0)
            self.last_token = self.last_token.at[slot].set(tok)
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens - 1

    def _splice(self, mini: dict, slot: int, prompt_len: int) -> None:
        """Write the batch=1 prefill cache into slot `slot`."""
        new = {}
        for key, big in self.cache.items():
            if key == "pos":
                new["pos"] = big.at[slot].set(prompt_len)
                continue
            ax = _axis_for(self.cfg, key)
            small = mini[key]
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            new[key] = big.at[tuple(idx)].set(small.astype(big.dtype))
        self.cache = new

    def step(self) -> typing.List[Request]:
        """One engine iteration: admit -> batched decode -> retire.
        Returns requests completed this step."""
        self._admit()
        done, self._finished_early = self._finished_early, []
        if not self.active:
            return done
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_token)
        self.steps += 1
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # only active slots advance; idle slots re-decode garbage rows but
        # their outputs are ignored and their pos is reset on admission
        self.last_token = next_tok
        for slot, req in list(self.active.items()):
            tok = int(next_tok[slot])
            req.output.append(tok)
            self.remaining[slot] -= 1
            hit_cap = int(self.cache["pos"][slot]) >= self.max_seq - 1
            if tok == self.eos or self.remaining[slot] <= 0 or hit_cap:
                req.done = True
                done.append(req)
                del self.active[slot]
                del self.remaining[slot]
        return done

    def run_until_drained(self, max_steps: int = 10_000
                          ) -> typing.List[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.queue:
                break
        return out

    def stats(self) -> dict:
        return {"decode_steps": self.steps, "prefills": self.prefills,
                "active": len(self.active), "queued": len(self.queue)}
