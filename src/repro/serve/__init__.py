"""Serving: the functional continuous-batching engine (real JAX decode,
exactness oracle) and the open-loop serving *simulation* (timing model
on the event engine/fabric — see docs/serving.md)."""
from .engine import Engine, Request
from .sim import (GENERATORS, ServeReport, ServeRequest, ServeSizing,
                  ServingScenario, ServingSystem, SlotLedger, TenantSpec,
                  build_scenario, bursty_trace, diurnal_trace, make_requests,
                  poisson_trace, run_serving)

__all__ = [
    "Engine", "Request",
    "GENERATORS", "ServeReport", "ServeRequest", "ServeSizing",
    "ServingScenario", "ServingSystem", "SlotLedger", "TenantSpec",
    "build_scenario", "bursty_trace", "diurnal_trace", "make_requests",
    "poisson_trace", "run_serving",
]
