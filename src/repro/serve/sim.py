"""Open-loop multi-tenant LLM serving on the system model.

This is the paper's case-study methodology (drive a realistic workload
through the simulator, read end-to-end latency under contention) pointed
at the serving workload the ROADMAP names: open-loop arrival traces feed
per-tenant continuous-batching servers whose prefill/decode compute runs
on :class:`~repro.core.chip.TensorCore` components and whose per-layer
collectives go through the pluggable fabric — so two tenants sharing a
pod contend on real links under ``fabric="event"``, and fault plans from
``docs/faults.md`` degrade tail latency observably.

Nothing here calls JAX: `repro.serve.engine` is the *functional* model
(real decode steps, exactness oracle); this module is the *timing* model
(simulator events sized from the model config).  Both implement Orca
continuous batching: admission waits on free KV-cache slots, iterations
batch every active request, slots release on completion.

Determinism: arrival traces, prompt/decode lengths and all component
logic are seeded and integer-timed, so ``ServeReport.summary()`` is
bit-identical across every scheduler x executor combination — the same
contract the rest of the engine holds (`tests/test_executor.py`).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import typing

import numpy as np

from ..core.chip import ComputeJob, HbmController, TensorCore
from ..core.component import Component
from ..core.connection import Connection, Request
from ..core.engine import Engine
from ..core.event import Event
from ..core.hooks import FaultInjector, MetricsHook
from ..core.hw import SystemSpec, ps_to_s, s_to_ps
from ..core.system import CollectiveCoordinator, StarConnection
from ..models.base import ModelConfig


# ---------------------------------------------------------------------------
# Arrival-trace generators (open loop: arrivals don't wait for completions)
# ---------------------------------------------------------------------------

def poisson_trace(rate_rps: float, duration_s: float, seed: int) -> np.ndarray:
    """Homogeneous Poisson arrivals: exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= duration_s:
            return np.asarray(out)
        out.append(t)


def bursty_trace(rate_rps: float, duration_s: float, seed: int,
                 burst_factor: float = 4.0, dwell_s: float = None) -> np.ndarray:
    """Two-state MMPP: a calm state at ``rate/burst_factor`` and a burst
    state at ``rate*burst_factor``, with exponential dwell times.  Mean
    rate stays near ``rate_rps`` (equal expected dwell in each state)."""
    rng = np.random.default_rng(seed)
    dwell = dwell_s if dwell_s is not None else max(duration_s / 8.0, 1e-6)
    rates = (rate_rps / burst_factor, rate_rps * burst_factor)
    state, t, next_switch = 0, 0.0, rng.exponential(dwell)
    out = []
    while t < duration_s:
        dt = rng.exponential(1.0 / rates[state])
        if t + dt >= next_switch:
            t = next_switch
            next_switch = t + rng.exponential(dwell)
            state = 1 - state
            continue
        t += dt
        if t >= duration_s:
            break
        out.append(t)
    return np.asarray(out)


def diurnal_trace(rate_rps: float, duration_s: float, seed: int,
                  depth: float = 0.8, period_s: float = None) -> np.ndarray:
    """Sinusoidally modulated Poisson process via thinning: instantaneous
    rate ``rate*(1 + depth*sin)``, peak-rate candidates kept with
    probability lambda(t)/lambda_max.  Models the day/night swing of an
    open user population."""
    rng = np.random.default_rng(seed)
    period = period_s if period_s is not None else duration_s
    lam_max = rate_rps * (1.0 + depth)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_s:
            return np.asarray(out)
        lam = rate_rps * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.uniform() * lam_max < lam:
            out.append(t)


GENERATORS: typing.Dict[str, typing.Callable] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One user request: arrival stamp plus pre-drawn lengths (the eos
    position is drawn up front so timing never depends on token values)."""
    uid: int
    arrival_ps: int
    prompt_len: int
    decode_len: int          # decode iterations until eos/completion (>= 1)


def make_requests(times_s: np.ndarray, seed: int,
                  prompt_range: typing.Tuple[int, int] = (16, 64),
                  decode_range: typing.Tuple[int, int] = (4, 12),
                  ) -> typing.Tuple[ServeRequest, ...]:
    """Attach seeded prompt/decode lengths to an arrival trace."""
    rng = np.random.default_rng(seed)
    n = len(times_s)
    prompts = rng.integers(prompt_range[0], prompt_range[1] + 1, size=n)
    decodes = rng.integers(decode_range[0], decode_range[1] + 1, size=n)
    return tuple(
        ServeRequest(uid=i, arrival_ps=s_to_ps(float(t)),
                     prompt_len=int(p), decode_len=int(d))
        for i, (t, p, d) in enumerate(zip(times_s, prompts, decodes)))


# ---------------------------------------------------------------------------
# Scenario description + collective/compute sizing from the model config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a model served tensor-parallel over ``devices`` with
    ``slots`` KV-cache slots and an open-loop request trace."""
    name: str
    devices: typing.Tuple[int, ...]
    model: ModelConfig
    slots: int
    requests: typing.Tuple[ServeRequest, ...]
    coll_ops: int = 4        # decode allreduces per iteration (layer groups)


@dataclasses.dataclass(frozen=True)
class ServingScenario:
    """``spares``: reserved chips owned by no tenant.  They idle in the
    HealthMonitor's shared pool; on a ``chip_dead`` verdict the lowest
    free spare is claimed for the victim's tenant, and a rejoin of the
    original chip returns it."""
    name: str
    tenants: typing.Tuple[TenantSpec, ...]
    spares: typing.Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Failure-detection and recovery knobs for :func:`run_serving`.

    * ``max_retries`` -- recovery requeues a request may survive before
      it is dropped (SLO miss);
    * ``backoff_base_s`` -- requeue delay after an abort, doubled per
      retry (exponential backoff gives the detector time to fence the
      dead chip before the retry lands on it again);
    * ``backoff_max_s`` -- cap on the exponential backoff.  Without it
      ``backoff_base_s * 2**(n-1)`` is unbounded and a handful of
      strikes push a retry past any plausible trace horizon; the cap
      keeps high-retry requests landing (``None`` disables);
    * ``heartbeat_s`` -- gossip heartbeat period for chips and their
      tenant server (0 disables gossip; detection then rides
      collective timeouts alone, so a tenant with no collectives in
      flight has no detector);
    * ``suspect_threshold`` -- consecutive missed heartbeat rounds
      before a peer files a strike against the silent chip;
    * ``quorum`` -- distinct accusers required before the
      :class:`HealthMonitor` declares a suspect dead.  ``None`` derives
      a majority of the suspect's live same-tenant peers (minimum 1).
      Raising it above the reachable accuser count makes a
      partitioned-but-alive chip explicitly representable: one
      accuser's evidence is never enough to fence it;
    * ``migrate_chunk_bytes`` -- per-chip payload of one KV-migration
      all-to-all (fixed so migration plans are enumerable up front for
      the bounded scheduler's strict-window guard).
    """
    max_retries: int = 3
    backoff_base_s: float = 3e-4
    backoff_max_s: typing.Optional[float] = 2e-3
    heartbeat_s: float = 5e-4
    suspect_threshold: int = 3
    quorum: typing.Optional[int] = None
    migrate_chunk_bytes: int = 1 << 20

    def backoff_ps(self, n: int) -> int:
        """Requeue delay (integer ps) for the ``n``-th retry: capped
        exponential ``backoff_base_s * 2**(n-1)``."""
        delay = self.backoff_base_s * (2 ** (max(1, n) - 1))
        if self.backoff_max_s is not None:
            delay = min(delay, self.backoff_max_s)
        return s_to_ps(delay)


class ServeSizing:
    """Deterministic op sizing for one tenant.  Flops/bytes are roofline
    inputs for :class:`TensorCore`; collective payloads are exact ints so
    the byte counts noted to the fabric up front match the issued joins
    bit-for-bit (the event fabric's planned-edge guard requires it).

    ``tp`` overrides the tensor-parallel degree (default: the tenant's
    full device count) -- a re-meshed degraded group serves with ``tp``
    equal to the surviving member count, so per-chip flops/bytes grow
    while the collective payloads (activation rows, tp-independent) stay
    bit-equal to the plans noted up front."""

    def __init__(self, tenant: TenantSpec, tp: int = None) -> None:
        m = tenant.model
        self.tp = max(1, len(tenant.devices) if tp is None else tp)
        d_ff = m.d_ff if m.d_ff else 4 * m.d_model
        layers = max(1, m.num_layers)
        self.params = (layers * (4 * m.d_model * m.d_model
                                 + 2 * m.d_model * d_ff)
                       + m.vocab_size * m.d_model)
        self.param_bytes = 2.0 * self.params          # bf16 weights
        self.d_model = m.d_model
        self.layers = layers
        # K + V, bf16, per committed context token, whole model (the
        # mesh-wide footprint; a tp shard holds 1/tp of it)
        self.kv_token_bytes = 2 * 2 * m.d_model * layers
        self.coll_ops = max(1, min(tenant.coll_ops, layers))
        self.layers_per_op = max(1, layers // self.coll_ops)
        self.moe = m.family == "moe" and m.num_experts > 1
        self.ept = max(1, m.experts_per_token)

    # compute (per device; tensor-parallel shards weights 1/tp)
    def prefill_flops(self, prompt_len: int) -> float:
        return 2.0 * self.params * prompt_len / self.tp

    def prefill_hbm(self, prompt_len: int) -> float:
        return self.param_bytes / self.tp

    def decode_flops(self, batch: int) -> float:
        return 2.0 * self.params * batch / self.tp

    def decode_hbm(self, batch: int) -> float:
        # weight-streaming bound + a token of KV per active request
        return self.param_bytes / self.tp + 2.0 * batch * self.d_model

    # collectives (exact ints; one activation row per active request)
    def ar_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.layers_per_op

    def a2a_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.ept

    def kv_bytes(self, tokens: int) -> int:
        """Mesh-wide KV-cache footprint of ``tokens`` committed context
        tokens (exact int: migration transfers are sized from it)."""
        return int(tokens) * self.kv_token_bytes


# ---------------------------------------------------------------------------
# Slot ledger: KV-cache capacity as pure, property-testable accounting
# ---------------------------------------------------------------------------

class SlotLedger:
    """KV-cache slots as schedulable capacity.  Pure bookkeeping (no
    engine dependency) so hypothesis can drive random admit/release
    interleavings against the invariants: occupancy never exceeds
    capacity, no uid is lost or double-completed, lowest free slot wins
    (deterministic placement)."""

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.free: typing.List[int] = list(range(capacity))
        self.active: typing.Dict[int, int] = {}      # slot -> uid
        self.seated: typing.Dict[int, int] = {}      # uid -> slot
        self.completed: set = set()
        self.peak = 0

    @property
    def in_use(self) -> int:
        return len(self.active)

    def has_free(self) -> bool:
        return bool(self.free)

    def admit(self, uid: int) -> int:
        if uid in self.seated:
            raise ValueError(f"uid {uid} already seated")
        if uid in self.completed:
            raise ValueError(f"uid {uid} already completed")
        if not self.free:
            raise RuntimeError("admit with no free slot")
        slot = self.free.pop(0)                       # lowest slot first
        self.active[slot] = uid
        self.seated[uid] = slot
        self.peak = max(self.peak, len(self.active))
        return slot

    def release(self, uid: int) -> int:
        if uid in self.completed:
            raise ValueError(f"uid {uid} double-completed")
        slot = self.seated.pop(uid, None)
        if slot is None:
            raise ValueError(f"uid {uid} not seated")
        del self.active[slot]
        self.completed.add(uid)
        bisect.insort(self.free, slot)
        return slot

    def evict(self, uid: int) -> int:
        """Reclaim a seat *without* retiring the uid: the request's KV
        state is lost (its mesh died mid-iteration) but the request is
        not done -- unlike :meth:`release` it may be admitted again
        later (the recovery requeue path)."""
        if uid in self.completed:
            raise ValueError(f"uid {uid} already completed")
        slot = self.seated.pop(uid, None)
        if slot is None:
            raise ValueError(f"uid {uid} not seated")
        del self.active[slot]
        bisect.insort(self.free, slot)
        return slot


class _ReqLog:
    """Mutable per-request timing record (all integer picoseconds, so
    queue + prefill + decode == end-to-end exactly, no float residue).
    ``retries`` counts recovery requeues; ``ckpt_tokens`` is the
    committed context whose KV survives on (or was migrated to) the
    mesh named by ``ckpt_group`` -- a re-admitted request only
    recomputes prefill for the context beyond its checkpoint and
    resumes decode at ``remaining``; the group lets a later membership
    loss reconcile checkpoints of requests that are *not seated* at
    verdict time (queued or in backoff); ``dropped_ps`` stamps the SLO
    drop when ``max_retries`` is exceeded."""
    __slots__ = ("uid", "arrival_ps", "prompt_len", "decode_len",
                 "admit_ps", "first_ps", "done_ps", "remaining",
                 "retries", "dropped_ps", "ckpt_tokens", "ckpt_group")

    def __init__(self, req: ServeRequest) -> None:
        self.uid = req.uid
        self.arrival_ps = req.arrival_ps
        self.prompt_len = req.prompt_len
        self.decode_len = req.decode_len
        self.admit_ps = None
        self.first_ps = None
        self.done_ps = None
        self.remaining = req.decode_len
        self.retries = 0
        self.dropped_ps = None
        self.ckpt_tokens = 0
        self.ckpt_group: tuple = ()   # mesh the checkpoint is sharded over

    def __getstate__(self):
        return {s: getattr(self, s) for s in self.__slots__}

    def __setstate__(self, state):
        for k, v in state.items():
            setattr(self, k, v)


# ---------------------------------------------------------------------------
# Components: per-chip serving program + per-tenant batching server
# ---------------------------------------------------------------------------

class ServeProgram(Component):
    """One chip's slice of a tenant: executes the iteration's op list
    (prefill/decode compute on its TensorCore, collective joins through
    the coordinator star) and reports phase completion to its tenant
    server.  Mirrors DeviceProgram's issue/wait loop, but the "trace" is
    re-sent every iteration by the server (DP-3: only connections carry
    cross-component traffic).

    With a recovery policy the program also gossips: every
    ``heartbeat_ps`` it announces a ``beat`` on its control star (the
    server relays it to the other members as ``peer_beat``), judges its
    peers' beats, and files a ``strike`` with the HealthMonitor against
    any peer silent for ``suspect_threshold`` consecutive rounds --
    peer-reported evidence, not an omniscient observer.  A program built
    with ``spare=True`` starts idle in the shared spare pool: it
    registers with the monitor only, and joins a tenant's mesh when the
    monitor sends ``claim`` (a ``release`` from the server returns it to
    the pool)."""

    def __init__(self, name: str, device: int,
                 group: typing.Tuple[int, ...], spare: bool = False,
                 heartbeat_ps: int = 0, suspect_threshold: int = 3) -> None:
        super().__init__(name)
        self.device = device
        self.group = tuple(group)      # current serving mesh (re-formed
                                       # by each phase under recovery)
        self.spare = spare
        self.heartbeat_ps = heartbeat_ps
        self.suspect_threshold = suspect_threshold
        self.ops: tuple = ()
        self.pc = 0
        self.iter_id = -1
        self.phases_done = 0
        # gossip state: which control port talks to my server (spares
        # have one per tenant, "ctrl0".."ctrlN", bound at claim time)
        self._ctrl: typing.Optional[str] = None if spare else "ctrl"
        self._beat_gen = 0             # invalidates stale beat timers
        self._heard: set = set()       # peers heard since my last round
        self._miss: typing.Dict[int, int] = {}
        self._accused: set = set()

    def start(self) -> None:
        self.schedule("hello")

    def handle(self, event: Event) -> None:
        if event.kind == "hello":
            if self.spare:
                self._enlist("register_spare")
            else:
                self._register()
            return
        if event.kind == "fault_wake":
            # The FaultInjector's scheduled wake.  A "fail" froze this
            # program before handle ran; reaching here means the action
            # just applied was a recover -- drop any pre-failure phase
            # state and announce ourselves again (rolling-restart
            # rejoin: the server re-admits the device into its mesh; a
            # recovered spare returns to the pool).
            self.ops = ()
            self.pc = 0
            if self.spare and self._ctrl is None:
                self._enlist("register_spare")
            elif self._ctrl is not None:
                self._register()
            return
        if event.kind == "beat":
            if event.payload == self._beat_gen:
                self._beat_round()
            return
        if event.kind != "request":
            return
        req = event.payload
        if req.kind == "phase":
            self.iter_id, self.ops, self.group = req.payload
            self.pc = 0
            # mesh re-formed: judge only current peers, fresh slate
            self._miss = {d: 0 for d in self.group if d != self.device}
            self._accused &= set(self._miss)
            self._issue()
        elif req.kind == "compute_done":
            if req.payload != (self.iter_id, self.pc):
                return      # job from an aborted iteration; core time
                            # was burned but the phase moved on
            self.pc += 1
            self._issue()
        elif req.kind == "collective_done":
            if not self._expects_coll(req.payload):
                return      # completion of a pre-abort collective
            self.pc += 1
            self._issue()
        elif req.kind == "collective_timeout":
            if not self._expects_coll(req.payload):
                return      # a pre-abort collective timing out late
            self.ops = ()
            self.pc = 0
            self._ctrl_port().send(Request(
                src=self._ctrl_port(), dst=None, kind="phase_failed",
                payload=self.iter_id))
        elif req.kind == "peer_beat":
            self._heard.add(req.payload)
            self._miss[req.payload] = 0
            self._accused.discard(req.payload)
        elif req.kind == "stop_beat":
            self._beat_gen += 1        # tenant drained: stop gossiping
        elif req.kind == "claim":
            # the monitor re-places a dead chip's capacity onto me
            self._ctrl = f"ctrl{req.payload}"
            self.group = ()
            self.iter_id = -1      # stale completions must mismatch
            self._register()
        elif req.kind == "release":
            # rolled back to the pool (the original chip rejoined)
            self._beat_gen += 1
            self._ctrl = None
            self.ops = ()
            self.pc = 0
            self.group = ()
            self.iter_id = -1      # drop any in-flight phase's tokens
            self._enlist("spare_free")

    def _ctrl_port(self):
        return self.port(self._ctrl)

    def _enlist(self, kind: str) -> None:
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            health.send(Request(
                src=health, dst=None, kind=kind,
                payload=(self.device, self)))

    def _register(self) -> None:
        # Register with the tenant server (spoke->hub auto-routes); the
        # reference rides the payload like coordinator joins do,
        # surviving the procs executor as a rank.  With a HealthMonitor
        # wired, also enlist with the failure detector and start the
        # gossip heartbeat.
        self._ctrl_port().send(Request(
            src=self._ctrl_port(), dst=None, kind="register",
            payload=(self.device, self)))
        self._enlist("register_chip")
        self._miss = {}
        self._heard = set()
        self._accused = set()
        if self.heartbeat_ps:
            self._beat_gen += 1
            self.schedule("beat", self.heartbeat_ps,
                          payload=self._beat_gen)

    def _beat_round(self) -> None:
        """One gossip round: judge the peers of my current mesh against
        the beats heard since the last round, strike the silent ones,
        announce my own beat, rearm."""
        health = self.ports.get("health")
        for peer in self.group:
            if peer == self.device:
                continue
            if peer in self._heard:
                continue
            misses = self._miss.get(peer, 0) + 1
            self._miss[peer] = misses
            if (misses >= self.suspect_threshold
                    and peer not in self._accused
                    and health is not None
                    and health.connection is not None):
                self._accused.add(peer)
                health.send(Request(
                    src=health, dst=None, kind="strike",
                    payload=(peer, self.device)))
        self._heard = set()
        self._ctrl_port().send(Request(
            src=self._ctrl_port(), dst=None, kind="beat",
            payload=self.device))
        self.schedule("beat", self.heartbeat_ps, payload=self._beat_gen)

    def _expects_coll(self, key) -> bool:
        """Is this coordinator notification for the collective the
        current op list is waiting on?  Collective names embed the
        server's monotone iteration id, so any notification for an
        aborted iteration's ops mismatches."""
        if self.pc >= len(self.ops):
            return False
        op = self.ops[self.pc]
        return op[0] == "coll" and key is not None and key[0] == op[1]

    def _issue(self) -> None:
        if self.pc >= len(self.ops):
            self.phases_done += 1
            self._ctrl_port().send(Request(
                src=self._ctrl_port(), dst=None, kind="phase_done",
                payload=self.iter_id))
            return
        op = self.ops[self.pc]
        if op[0] == "compute":
            _, tag, flops, hbm_bytes = op
            self.port("core").send(Request(
                src=self.port("core"), dst=None, kind="job",
                payload=ComputeJob(flops=flops, hbm_bytes=hbm_bytes,
                                   tag=tag, reply_to=self,
                                   token=(self.iter_id, self.pc))))
        else:  # ("coll", name, kind, nbytes)
            _, name, kind, nbytes = op
            self.port("coll").send(Request(
                src=self.port("coll"), dst=None, kind="join",
                size_bytes=int(nbytes),
                payload=(name, 0, kind, float(nbytes), self.group,
                         self.device, self)))


class HealthMonitor(Component):
    """Quorum aggregator for peer-reported failure evidence, plus the
    spare-pool arbiter.  Unlike the PR-9 monitor it never probes: it
    only *counts accusers*.

    Evidence arrives as:

    * ``strike`` -- gossip verdicts from chips (accuser = device id) and
      tenant servers (accuser = ``-1 - tid``; the server's own judgment
      is what detects deaths on single-chip tenants, where no peer
      exists to gossip);
    * ``timeout_report`` from the coordinator (key + joined roster):
      every member that *did* join a timed-out collective is treated as
      an accuser of every member that did not -- the roster is exactly
      the peers' testimony.

    A suspect is declared dead only when its distinct accusers reach the
    quorum (``RecoveryPolicy.quorum``, default: majority of its live
    same-tenant peers, minimum 1).  Below quorum the suspect keeps its
    seat -- a partitioned-but-alive chip is representable: one accuser's
    evidence never fences it.  A fully-joined timed-out collective has
    no suspects at all and is reported to the owning server as
    ``coll_failed`` (a fabric stall: retry, blame no chip).

    On a death verdict the monitor also arbitrates the shared spare
    pool: the lowest free spare is claimed for the victim's tenant (the
    ``chip_dead`` verdict carries it), and a ``spare_free`` from a
    released spare returns it.  Everything is ordinary events on the
    health star, so detection latency is simulated and the protocol
    stays bit-identical across schedulers and executors."""

    def __init__(self, name: str,
                 tenants: typing.Tuple[typing.Tuple[int, typing.Tuple[int, ...]], ...],
                 policy: RecoveryPolicy,
                 spares: typing.Tuple[int, ...] = ()) -> None:
        super().__init__(name)
        self.policy = policy
        self.tenant_of = {d: tid for tid, devs in tenants for d in devs}
        self.chips: typing.Dict[int, object] = {}      # device -> program
        self.servers: typing.Dict[int, object] = {}    # tenant id -> server
        self.spares: typing.Dict[int, object] = {}     # spare id -> program
        self.pool: typing.List[int] = []               # free spares (sorted)
        self.expected_spares = tuple(spares)
        self.dead: set = set()
        self.deaths = 0                                # monotone (rejoins
                                                       # shrink ``dead``)
        self.accusers: typing.Dict[int, set] = {}      # suspect -> accusers
        self.quiesced: set = set()                     # tenant ids drained

    def handle(self, event: Event) -> None:
        if event.kind != "request":
            return
        req = event.payload
        if req.kind == "register_chip":
            device, prog = req.payload
            self.chips[device] = prog
            self.dead.discard(device)        # rolling-restart rejoin
            self.accusers.pop(device, None)  # old evidence is stale
        elif req.kind == "register_spare":
            device, prog = req.payload
            self.spares[device] = prog
            self.dead.discard(device)
            self.accusers.pop(device, None)
            if device not in self.pool and device not in self.tenant_of:
                bisect.insort(self.pool, device)
        elif req.kind == "register_server":
            tid, server = req.payload
            self.servers[tid] = server
        elif req.kind == "strike":
            suspect, accuser = req.payload
            self._accuse(suspect, (accuser,))
        elif req.kind == "timeout_report":
            key, joined = req.payload
            self._on_timeout(key, joined)
        elif req.kind == "spare_free":
            device, _prog = req.payload
            self.tenant_of.pop(device, None)
            self.accusers.pop(device, None)
            if device not in self.pool and device not in self.dead:
                bisect.insort(self.pool, device)
        elif req.kind == "quiesce":
            self.quiesced.add(req.payload)

    # -- evidence aggregation ----------------------------------------------
    def _quorum_for(self, suspect: int) -> int:
        if self.policy.quorum is not None:
            return max(1, self.policy.quorum)
        tid = self.tenant_of.get(suspect)
        peers = sum(1 for d, t in self.tenant_of.items()
                    if t == tid and d != suspect and d not in self.dead
                    and d in self.chips)
        return max(1, (peers + 1) // 2)

    def _accuse(self, suspect: int, accusers) -> None:
        if suspect in self.dead or suspect not in self.tenant_of:
            return
        acc = self.accusers.setdefault(suspect, set())
        acc.update(accusers)
        if len(acc) >= self._quorum_for(suspect):
            self._declare_dead(suspect)

    def _on_timeout(self, key, joined) -> None:
        group = key[2]
        joined_set = set(joined)
        suspects = [d for d in group
                    if d not in joined_set and d not in self.dead]
        if not suspects:
            # Fully joined but the transfer never completed: a fabric
            # stall, not a chip death.  Nobody to fence; the owning
            # server aborts and retries through backoff.
            tid = self.tenant_of.get(group[0])
            server = self.servers.get(tid)
            if server is not None:
                hub = self.port("hub")
                hub.send(Request(src=hub, dst=server, kind="coll_failed",
                                 payload=key))
            return
        witnesses = sorted(joined_set)
        for device in suspects:
            self._accuse(device, witnesses)

    def _declare_dead(self, device: int) -> None:
        if device in self.dead:
            return
        self.dead.add(device)
        self.deaths += 1
        self.accusers.pop(device, None)
        tid = self.tenant_of.get(device)
        server = self.servers.get(tid)
        spare = None
        if self.pool:
            # re-place the lost capacity: claim the lowest free spare
            # for the victim's tenant
            spare = self.pool.pop(0)
            self.tenant_of[spare] = tid
            self.chips[spare] = self.spares[spare]
            hub = self.port("hub")
            hub.send(Request(src=hub, dst=self.spares[spare],
                             kind="claim", payload=tid))
        if server is not None:
            hub = self.port("hub")
            hub.send(Request(src=hub, dst=server, kind="chip_dead",
                             payload=(device, spare)))


class TenantServer(Component):
    """Per-tenant continuous-batching scheduler (the Orca loop as
    simulator events).  Each iteration: admit queued requests into free
    KV slots, broadcast one op list (new prefills + one batched decode +
    its collectives) to every member chip, wait for all phase_done
    replies, then retire finished requests and start the next iteration.
    Open loop: arrivals are pre-scheduled self-events from the trace and
    never wait on completions.

    With a :class:`RecoveryPolicy` the server also *serves through*
    faults: a ``chip_dead`` verdict (or a ``phase_failed`` from its own
    chips) aborts the in-flight iteration, evicts every seated request,
    migrates the KV shards that survive on live chips to the re-formed
    mesh (a priced fabric transfer; only shards lost with the dead chip
    are recomputed at re-admission), requeues each with capped
    exponential backoff -- or drops it past ``max_retries`` -- and
    re-forms the serving group from the surviving members plus any
    spare the monitor claimed for it.  A dead device registering again
    rejoins the mesh (returning a claimed spare to the pool); seated
    requests are resharded (evicted + immediately requeued, no retry
    penalty, full KV migrated) before the first iteration on the new
    group.  The server also takes part in gossip detection: it relays
    member beats and files its own strikes (the only accuser a
    single-chip tenant has)."""

    def __init__(self, name: str, tenant: TenantSpec, tid: int = 0,
                 policy: RecoveryPolicy = None) -> None:
        super().__init__(name)
        self.tenant = tenant
        self.tid = tid
        self.policy = policy
        self.sizing = ServeSizing(tenant)
        self._sizings: typing.Dict[int, ServeSizing] = {
            len(tenant.devices): self.sizing}
        self.ledger = SlotLedger(tenant.slots)
        self.members: typing.Dict[int, object] = {}    # device -> program
        self.queue: typing.List[int] = []              # waiting uids (FIFO)
        self.recs: typing.Dict[int, _ReqLog] = {
            r.uid: _ReqLog(r) for r in tenant.requests}
        self.completed_order: typing.List[int] = []
        self.iter_id = -1
        self.iterations = 0
        self._phase_replies = 0
        self._newly: typing.List[int] = []
        # -- recovery state -------------------------------------------------
        self.dead: set = set()               # fenced original devices
        self.retries = 0                     # recovery requeues issued
        self.drops: typing.List[int] = []    # uids dropped past max_retries
        self.recoveries = 0                  # outage windows closed
        self.rejoins = 0                     # dead devices re-registered
        self.outages: typing.List[typing.Tuple[int, int]] = []
        self._outage_start: typing.Optional[int] = None
        self._serving_group: tuple = ()      # mesh the seated KV lives on
        self._resolved = 0                   # done + dropped requests
        self._quiesced = False
        self._abort_stamp: typing.Optional[int] = None
        # -- spare pool -----------------------------------------------------
        self.claimed: set = set()            # spares serving this tenant
        self._pending_spare: set = set()     # claimed, not yet registered
        self._release_on_register: set = set()
        self._release_pending = False
        self.spare_claims = 0
        self.spare_returns = 0
        # -- KV migration ---------------------------------------------------
        self.migrated_bytes = 0
        self.prefill_saved_tokens = 0
        self.prefill_recompute_tokens = 0
        self._mig_pending = 0                # KV bytes awaiting transfer
        # -- gossip (server-side judge + relay) -----------------------------
        self._beat_heard: set = set()
        self._beat_miss: typing.Dict[int, int] = {}
        self._beat_accused: set = set()
        self._ticking = False
        # -- capacity trace for effective availability ----------------------
        # armed once the mesh first fills: startup registration latency
        # is not a capacity dip
        self._cap_log: typing.List[typing.Tuple[int, int]] = [
            (0, len(tenant.devices))]
        self._cap_armed = False

    def start(self) -> None:
        for r in self.tenant.requests:
            self.schedule("arrival", r.arrival_ps, payload=r.uid)
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            health.send(Request(
                src=health, dst=None, kind="register_server",
                payload=(self.tid, self)))
        self._maybe_start_tick()
        self._maybe_quiesce()   # a tenant with an empty trace is done

    def handle(self, event: Event) -> None:
        if event.kind == "arrival":
            self.queue.append(event.payload)
            self._maybe_iterate()
        elif event.kind == "requeue":
            uid = event.payload
            rec = self.recs[uid]
            if (rec.done_ps is None and rec.dropped_ps is None
                    and uid not in self.ledger.seated):
                self.queue.append(uid)
            self._maybe_iterate()
        elif event.kind == "beat_tick":
            self._beat_tick()
        elif event.kind == "request":
            req = event.payload
            if req.kind == "register":
                self._on_register(*req.payload)
            elif req.kind == "beat":
                self._on_beat(req.payload)
            elif req.kind == "phase_done":
                if req.payload != self.iter_id or not self._phase_replies:
                    return                       # reply from an aborted phase
                self._phase_replies -= 1
                if self._phase_replies == 0:
                    self._finish_iteration()
            elif req.kind == "phase_failed":
                if (self.policy is None or req.payload != self.iter_id
                        or not self._phase_replies):
                    return
                self._abort_iteration()
            elif req.kind == "coll_failed":
                # fully-joined collective died in the fabric: retry
                if self.policy is not None and self._phase_replies:
                    self._abort_iteration()
            elif req.kind == "chip_dead":
                self._on_chip_dead(*req.payload)

    # -- membership --------------------------------------------------------
    def _on_register(self, device: int, prog) -> None:
        if device in self._release_on_register:
            # claimed while its original was already rejoining: bounce
            # the spare straight back to the pool, never a member
            self._release_on_register.discard(device)
            self.spare_returns += 1
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=prog, kind="release"))
            return
        if device in self.dead:                  # rolling-restart rejoin
            self.dead.discard(device)
            self.rejoins += 1
            # capacity is back: return a spare -- but never mid-phase
            # (the in-flight phase still needs its phase_done)
            if self._phase_replies:
                self._release_pending = True
            else:
                self._release_one_spare()
        elif device in self._pending_spare:
            self._pending_spare.discard(device)
            self.claimed.add(device)
        elif (device not in self.tenant.devices
              and device not in self.claimed):
            self.claimed.add(device)             # recovered spare rejoining
        self.members[device] = prog
        self._beat_miss[device] = 0
        self._beat_accused.discard(device)
        self._log_cap()
        self._maybe_start_tick()
        self._maybe_iterate()

    def _release_one_spare(self) -> None:
        """The original chip rejoined: hand the highest claimed spare
        back to the shared pool (lowest spares stay claimed longest, the
        mirror image of the claim order)."""
        if self.claimed:
            sp = max(self.claimed)
            self.claimed.discard(sp)
            prog = self.members.pop(sp, None)
            self._beat_miss.pop(sp, None)
            self._beat_accused.discard(sp)
            self.spare_returns += 1
            if prog is not None:
                self.port("ctrl").send(Request(
                    src=self.port("ctrl"), dst=prog, kind="release"))
            self._log_cap()
        elif self._pending_spare:
            sp = max(self._pending_spare)
            self._pending_spare.discard(sp)
            self._release_on_register.add(sp)

    # -- recovery ----------------------------------------------------------
    def _on_chip_dead(self, device: int, spare=None) -> None:
        if self.policy is None or device in self.dead:
            return
        if device in self.claimed:
            self.claimed.discard(device)         # a claimed spare died
        elif device in self._pending_spare:
            self._pending_spare.discard(device)
        elif device in self.tenant.devices:
            self.dead.add(device)
        else:
            return                               # stale / unknown verdict
        self.members.pop(device, None)
        self._beat_miss.pop(device, None)
        self._beat_accused.discard(device)
        if spare is not None:
            self._pending_spare.add(spare)
            self.spare_claims += 1
        self._log_cap()
        self._reconcile_unseated()
        if self._phase_replies or self.ledger.in_use:
            # in-flight iteration and/or seated KV sharded over a mesh
            # that just lost a member: abort, reclaim, requeue
            self._abort_iteration()
        else:
            self._maybe_iterate()

    # -- gossip relay + server-side judge ----------------------------------
    def _on_beat(self, device: int) -> None:
        self._beat_heard.add(device)
        self._beat_miss[device] = 0
        self._beat_accused.discard(device)
        ctrl = self.port("ctrl")
        for other, prog in sorted(self.members.items()):
            if other != device:
                ctrl.send(Request(src=ctrl, dst=prog, kind="peer_beat",
                                  payload=device))

    def _maybe_start_tick(self) -> None:
        if (self._ticking or self._quiesced or not self.members
                or self.policy is None or not self.policy.heartbeat_s):
            return
        self._ticking = True
        self.schedule("beat_tick", s_to_ps(self.policy.heartbeat_s))

    def _beat_tick(self) -> None:
        if self._quiesced or not self.members:
            self._ticking = False      # drained or fully fenced: stop
            return
        health = self.ports.get("health")
        for d in sorted(set(self.members) | self._pending_spare):
            if d in self._beat_heard:
                continue
            misses = self._beat_miss.get(d, 0) + 1
            self._beat_miss[d] = misses
            if (misses >= self.policy.suspect_threshold
                    and d not in self._beat_accused
                    and health is not None
                    and health.connection is not None):
                self._beat_accused.add(d)
                health.send(Request(
                    src=health, dst=None, kind="strike",
                    payload=(d, -1 - self.tid)))
        self._beat_heard = set()
        self.schedule("beat_tick", s_to_ps(self.policy.heartbeat_s))

    def _log_cap(self) -> None:
        if not self._cap_armed:
            if len(self.members) >= len(self.tenant.devices):
                self._cap_armed = True   # seed entry already says full
            return
        self._cap_log.append((self.engine.now, len(self.members)))

    def _abort_iteration(self) -> None:
        now = self.engine.now
        if self._outage_start is None:
            self._outage_start = now
        self._phase_replies = 0
        self._newly = []
        # Idempotence: a second chip_dead verdict landing at the same
        # instant re-aborts seats the first abort's _maybe_iterate just
        # re-admitted -- those must not take a second retry penalty.
        penalize = self._abort_stamp != now
        if self._release_pending:
            self._release_pending = False
            self._release_one_spare()
        tp_old = max(1, len(self._serving_group))
        lost_devs = len(set(self._serving_group) - set(self.members))
        survivors = tuple(sorted(set(self._serving_group)
                                 & set(self.members)))
        front = []
        for uid in sorted(self.ledger.seated):
            self.ledger.evict(uid)
            rec = self.recs[uid]
            rec.admit_ps = None
            rec.first_ps = None
            # KV migration: shards on surviving chips move to the new
            # mesh; only the dead chip's shard of the committed context
            # is recomputed (ceil of the lost fraction).
            resident = rec.ckpt_tokens
            lost_tokens = (-(-resident * lost_devs // tp_old)
                           if resident else 0)
            saved = resident - lost_tokens
            dropped = False
            if penalize:
                rec.retries += 1
                if rec.retries > self.policy.max_retries:
                    rec.dropped_ps = now             # SLO drop
                    rec.ckpt_tokens = 0
                    dropped = True
                    self.drops.append(uid)
                    self._resolved += 1
                else:
                    self.retries += 1
                    self.schedule("requeue",
                                  self.policy.backoff_ps(rec.retries),
                                  payload=uid)
            else:
                front.append(uid)                    # no double penalty
            if dropped:
                continue             # a dropped seat's KV never moves
            rec.ckpt_tokens = saved
            rec.ckpt_group = survivors if saved > 0 else ()
            if saved > 0:
                self.prefill_saved_tokens += saved
                if lost_devs > 0:
                    self._mig_pending += (
                        self.sizing.kv_bytes(resident)
                        * (tp_old - lost_devs) // tp_old)
            if lost_tokens > 0:
                self.prefill_recompute_tokens += lost_tokens
        if front:
            self.queue[:0] = front
        if penalize:
            self._abort_stamp = now
        self._maybe_iterate()
        self._maybe_quiesce()

    def _reshard(self, group: tuple) -> None:
        """Membership changed under seated requests (a rejoin): their KV
        shards live on the old mesh, so evict and requeue them ahead of
        the FIFO queue -- no retry penalty, the reshard is planned and
        every shard survives, so the whole committed context migrates."""
        front = []
        for uid in sorted(self.ledger.seated):
            self.ledger.evict(uid)
            rec = self.recs[uid]
            rec.admit_ps = None
            rec.first_ps = None
            if rec.ckpt_tokens > 0:
                self.prefill_saved_tokens += rec.ckpt_tokens
                self._mig_pending += self.sizing.kv_bytes(rec.ckpt_tokens)
                rec.ckpt_group = group
            front.append(uid)
        self.queue[:0] = front

    def _reconcile_unseated(self) -> None:
        """Membership just shrank: requests holding a checkpoint while
        *not seated* (queued, or waiting out a requeue backoff) lose the
        dead chip's shard of it too.  Recompute the lost fraction, keep
        the survivors' share (priced as migration onto the next mesh),
        exactly as :meth:`_abort_iteration` does for seated requests --
        without this, a request aborted by ``coll_failed`` before the
        quorum verdict lands would resume on the new mesh with its full
        checkpoint for free."""
        members = set(self.members)
        for uid in sorted(self.recs):
            rec = self.recs[uid]
            if (rec.ckpt_tokens <= 0 or not rec.ckpt_group
                    or rec.done_ps is not None or rec.dropped_ps is not None
                    or uid in self.ledger.seated):
                continue
            grp = rec.ckpt_group
            lost = len(set(grp) - members)
            if lost == 0:
                continue
            tp, resident = len(grp), rec.ckpt_tokens
            lost_tokens = -(-resident * lost // tp)
            saved = resident - lost_tokens
            rec.ckpt_tokens = saved
            rec.ckpt_group = (tuple(sorted(set(grp) & members))
                              if saved > 0 else ())
            self.prefill_recompute_tokens += lost_tokens
            if saved > 0:
                self.prefill_saved_tokens += saved
                self._mig_pending += (
                    self.sizing.kv_bytes(resident) * (tp - lost) // tp)

    def _maybe_quiesce(self) -> None:
        if self._quiesced or self._resolved < len(self.recs):
            return
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            self._quiesced = True
            health.send(Request(
                src=health, dst=None, kind="quiesce", payload=self.tid))
            ctrl = self.port("ctrl")
            for d, prog in sorted(self.members.items()):
                ctrl.send(Request(src=ctrl, dst=prog, kind="stop_beat"))

    def _sizing_for(self, n: int) -> ServeSizing:
        s = self._sizings.get(n)
        if s is None:
            s = self._sizings[n] = ServeSizing(self.tenant, tp=n)
        return s

    # -- the Orca iteration ------------------------------------------------
    def _maybe_iterate(self) -> None:
        if self._phase_replies:                  # iteration in flight
            return
        expected = (len(self.tenant.devices) - len(self.dead)
                    + len(self.claimed) + len(self._pending_spare))
        if len(self.members) < expected or not self.members:
            return              # chips still registering, or all fenced
        group = tuple(sorted(self.members))
        if self.ledger.in_use and group != self._serving_group:
            self._reshard(group)
        admitted = []
        while self.queue and self.ledger.has_free():
            uid = self.queue.pop(0)
            self.ledger.admit(uid)
            rec = self.recs[uid]
            rec.admit_ps = self.engine.now
            if rec.ckpt_tokens:
                # any surviving shards were priced onto this mesh at
                # eviction/reconcile time; the checkpoint now lives here
                rec.ckpt_group = group
            admitted.append(uid)
        self._serving_group = group
        if not self.ledger.in_use:
            return                               # idle until next arrival
        self.iter_id += 1
        self.iterations += 1
        self._newly = admitted
        ops = self._build_ops(admitted, group)
        self._phase_replies = len(group)
        for d in group:
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=self.members[d], kind="phase",
                payload=(self.iter_id, ops, group)))

    def _build_ops(self, admitted: typing.List[int], group: tuple) -> tuple:
        s = self._sizing_for(len(group))
        it = self.iter_id
        ops = []
        if self._mig_pending:
            if len(group) > 1:
                # KV migration rides the serving fabric: fixed-size
                # all-to-all chunks (plan keys enumerable for bounded)
                chunk = self.policy.migrate_chunk_bytes
                nops = -(-self._mig_pending // (chunk * len(group)))
                for k in range(nops):
                    ops.append(("coll", f"{self.name}.i{it}.mig{k}",
                                "all-to-all", chunk))
                self.migrated_bytes += self._mig_pending
            # single survivor: shards are already local, nothing moves
            self._mig_pending = 0
        for uid in admitted:
            rec = self.recs[uid]
            # checkpointed prefill: only the context beyond the migrated
            # checkpoint is (re)computed; fresh requests have ckpt 0
            done = rec.decode_len - rec.remaining
            need = rec.prompt_len + done - rec.ckpt_tokens
            if need > 0:
                ops.append(("compute", f"{self.name}.i{it}.prefill{uid}",
                            s.prefill_flops(need), s.prefill_hbm(need)))
        batch = self.ledger.in_use
        ops.append(("compute", f"{self.name}.i{it}.decode",
                    s.decode_flops(batch), s.decode_hbm(batch)))
        if len(group) > 1:
            for k in range(s.coll_ops):
                ops.append(("coll", f"{self.name}.i{it}.ar{k}",
                            "all-reduce", s.ar_bytes(batch)))
            if s.moe:
                # MoE dispatch + combine: two a2a per iteration
                ops.append(("coll", f"{self.name}.i{it}.a2a0",
                            "all-to-all", s.a2a_bytes(batch)))
                ops.append(("coll", f"{self.name}.i{it}.a2a1",
                            "all-to-all", s.a2a_bytes(batch)))
        return tuple(ops)

    def _finish_iteration(self) -> None:
        now = self.engine.now
        for uid in self._newly:
            self.recs[uid].first_ps = now        # first token this iteration
        self._newly = []
        for slot, uid in sorted(self.ledger.active.items()):
            rec = self.recs[uid]
            rec.remaining -= 1
            if rec.remaining <= 0:               # pre-drawn eos reached
                rec.done_ps = now
                self.ledger.release(uid)
                self.completed_order.append(uid)
                self._resolved += 1
            else:
                # commit: this iteration's KV writes are durable shards
                rec.ckpt_tokens = (rec.prompt_len
                                   + (rec.decode_len - rec.remaining))
                rec.ckpt_group = self._serving_group
        if self._outage_start is not None:
            # a completed iteration on the re-formed mesh closes the
            # outage window -- the tenant is serving again
            self.outages.append((self._outage_start, now))
            self._outage_start = None
            self.recoveries += 1
        if self._release_pending:
            self._release_pending = False
            self._release_one_spare()
        self._maybe_iterate()
        self._maybe_quiesce()


# ---------------------------------------------------------------------------
# System assembly
# ---------------------------------------------------------------------------

class ServingSystem:
    """A machine wired for serving: shared coordinator + fabric, plus per
    tenant a :class:`TenantServer` on its own control star and per device
    a fresh TensorCore/HbmController/ServeProgram triple.  Chips are
    wired exactly like :class:`repro.core.system.System` (2-endpoint
    buses so request auto-routing holds); tenants share the fabric, which
    is where multi-tenant link contention comes from."""

    def __init__(self, scenario: ServingScenario, spec: SystemSpec,
                 scheduler=None, max_workers: int = 4, fabric=None,
                 executor=None, deadline_s: float = None,
                 recovery: RecoveryPolicy = None) -> None:
        from ..fabric import make_fabric   # late: fabric imports core modules
        seen: set = set()
        for t in scenario.tenants:
            if not t.devices:
                raise ValueError(f"tenant {t.name!r} has no devices")
            for d in t.devices:
                if not 0 <= d < spec.total_chips:
                    raise ValueError(
                        f"tenant {t.name!r} device {d} outside "
                        f"topology with {spec.total_chips} chips")
                if d in seen:
                    raise ValueError(
                        f"device {d} assigned to two tenants; tenant "
                        f"placements must be disjoint")
                seen.add(d)
        for d in scenario.spares:
            if not 0 <= d < spec.total_chips:
                raise ValueError(
                    f"spare device {d} outside topology with "
                    f"{spec.total_chips} chips")
            if d in seen:
                raise ValueError(
                    f"spare device {d} already assigned to a tenant")
            seen.add(d)
        if scenario.spares and recovery is None:
            raise ValueError("spares need a recovery policy (the "
                             "HealthMonitor arbitrates the pool)")
        self.scenario = scenario
        self.spec = spec
        self.policy = recovery
        self.engine = Engine(scheduler=scheduler, max_workers=max_workers,
                             executor=executor)
        self.fabric = make_fabric(fabric or spec.fabric, spec)
        self.coordinator = self.engine.register(
            CollectiveCoordinator("coordinator", deadline_s=deadline_s))
        self.fabric.install(self.engine, self.coordinator)
        coll_conn = self.engine.register(
            StarConnection("coll_fabric", self.coordinator.port("coll"),
                           latency_s=spec.ctrl_latency_s))
        self.monitor: typing.Optional[HealthMonitor] = None
        health_conn = None
        if recovery is not None:
            # Failure detector on its own control star; the coordinator
            # reports collective timeouts into it (key + joined roster).
            self.monitor = self.engine.register(HealthMonitor(
                "health.monitor",
                tenants=tuple((tid, t.devices)
                              for tid, t in enumerate(scenario.tenants)),
                policy=recovery,
                spares=scenario.spares))
            health_conn = self.engine.register(
                StarConnection("health.star", self.monitor.port("hub"),
                               latency_s=spec.ctrl_latency_s))
            health_conn.plug(self.coordinator.port("health"))
        self.servers: typing.List[TenantServer] = []
        self.programs: typing.List[ServeProgram] = []
        self.cores: typing.List[TensorCore] = []
        self.hbms: typing.List[HbmController] = []
        heartbeat_ps = (s_to_ps(recovery.heartbeat_s)
                        if recovery is not None and recovery.heartbeat_s
                        else 0)
        suspect = recovery.suspect_threshold if recovery is not None else 3
        ctrl_conns: typing.List[StarConnection] = []
        for tid, tenant in enumerate(scenario.tenants):
            server = self.engine.register(
                TenantServer(f"tenant{tid}.server", tenant, tid=tid,
                             policy=recovery))
            ctrl = self.engine.register(
                StarConnection(f"tenant{tid}.ctrl", server.port("ctrl"),
                               latency_s=spec.ctrl_latency_s))
            ctrl_conns.append(ctrl)
            if health_conn is not None:
                health_conn.plug(server.port("health"))
            for d in tenant.devices:
                core = self.engine.register(
                    TensorCore(f"chip{d}.core", spec.chip))
                hbm = self.engine.register(
                    HbmController(f"chip{d}.hbm", spec.chip))
                prog = self.engine.register(
                    ServeProgram(f"chip{d}.prog", d, tenant.devices,
                                 heartbeat_ps=heartbeat_ps,
                                 suspect_threshold=suspect))
                self.engine.register(Connection(f"chip{d}.bus")).plug(
                    prog.port("core")).plug(core.port("prog"))
                self.engine.register(Connection(f"chip{d}.membus")).plug(
                    core.port("hbm")).plug(hbm.port("cpu"))
                coll_conn.plug(prog.port("coll"))
                ctrl.plug(prog.port("ctrl"))
                if health_conn is not None:
                    health_conn.plug(prog.port("health"))
                self.programs.append(prog)
                self.cores.append(core)
                self.hbms.append(hbm)
            self.servers.append(server)
            # Advance notice of every collective this tenant can issue
            # (batch sizes 1..slots): the event fabric refines bounded-lag
            # edges from these exact (kind, bytes, group) triples, and its
            # strict-window guard fails loudly on an un-noted collective.
            if len(tenant.devices) > 1:
                s = ServeSizing(tenant)
                for b in range(1, tenant.slots + 1):
                    self.fabric.note_plan("all-reduce", float(s.ar_bytes(b)),
                                          tuple(tenant.devices))
                    if s.moe:
                        self.fabric.note_plan("all-to-all",
                                              float(s.a2a_bytes(b)),
                                              tuple(tenant.devices))
                if recovery is not None:
                    # rejoin reshard migrates KV on the nominal group
                    self.fabric.note_plan(
                        "all-to-all", float(recovery.migrate_chunk_bytes),
                        tuple(tenant.devices))
        for d in scenario.spares:
            # A spare chip: full compute stack, one control port per
            # tenant star (bound at claim time), idle until claimed.
            core = self.engine.register(
                TensorCore(f"chip{d}.core", spec.chip))
            hbm = self.engine.register(
                HbmController(f"chip{d}.hbm", spec.chip))
            prog = self.engine.register(
                ServeProgram(f"chip{d}.prog", d, (), spare=True,
                             heartbeat_ps=heartbeat_ps,
                             suspect_threshold=suspect))
            self.engine.register(Connection(f"chip{d}.bus")).plug(
                prog.port("core")).plug(core.port("prog"))
            self.engine.register(Connection(f"chip{d}.membus")).plug(
                core.port("hbm")).plug(hbm.port("cpu"))
            coll_conn.plug(prog.port("coll"))
            for tid, ctrl in enumerate(ctrl_conns):
                ctrl.plug(prog.port(f"ctrl{tid}"))
            if health_conn is not None:
                health_conn.plug(prog.port("health"))
            self.programs.append(prog)
            self.cores.append(core)
            self.hbms.append(hbm)

    def note_failover_plans(self, candidates: typing.Iterable[int]) -> None:
        """Note the collective plans of every group a recovery could
        re-mesh to: for each tenant, its device group minus every
        non-empty subset of ``candidates`` (the chips the fault plan can
        kill), each optionally extended by claimed spares (at most one
        spare per lost chip -- the monitor never over-claims).  Plans are
        consumed at run start -- the bounded scheduler derives its
        strict-window edges from them -- so every group that might form
        mid-run must be noted before ``engine.run()``.  Collective
        payloads are activation rows (tp-independent), so the noted
        bytes match the re-meshed iterations bit-for-bit; each group
        also gets the fixed-size KV-migration all-to-all chunk."""
        import itertools
        spares = tuple(sorted(self.scenario.spares))
        chunk = (float(self.policy.migrate_chunk_bytes)
                 if self.policy is not None else None)
        for tenant in self.scenario.tenants:
            cand = sorted((set(tenant.devices) | set(spares))
                          & set(candidates))
            lost_orig = [d for d in cand if d in tenant.devices]
            for r in range(1, len(lost_orig) + 1):
                for gone in itertools.combinations(lost_orig, r):
                    survivors = tuple(d for d in tenant.devices
                                      if d not in gone)
                    for ns in range(0, min(r, len(spares)) + 1):
                        for claim in itertools.combinations(spares, ns):
                            group = tuple(sorted(survivors + claim))
                            if len(group) < 2:
                                continue
                            s = ServeSizing(tenant, tp=len(group))
                            for b in range(1, tenant.slots + 1):
                                self.fabric.note_plan(
                                    "all-reduce", float(s.ar_bytes(b)),
                                    group)
                                if s.moe:
                                    self.fabric.note_plan(
                                        "all-to-all",
                                        float(s.a2a_bytes(b)), group)
                            if chunk is not None:
                                self.fabric.note_plan("all-to-all",
                                                      chunk, group)

    def run(self, until_s: float = None) -> int:
        for prog in self.programs:
            prog.start()
        for server in self.servers:
            server.start()
        return self.engine.run(s_to_ps(until_s) if until_s else None)


# ---------------------------------------------------------------------------
# Report + driver
# ---------------------------------------------------------------------------

def _pctile_ps(values_ps: typing.List[int], q: float) -> float:
    """Nearest-rank percentile in seconds (deterministic, no interpolation)."""
    if not values_ps:
        return 0.0
    v = sorted(values_ps)
    k = max(0, math.ceil(q / 100.0 * len(v)) - 1)
    return ps_to_s(v[k])


@dataclasses.dataclass
class ServeReport:
    """One serving run.  ``summary()`` excludes execution artifacts so it
    is bit-identical across schedulers and executors, same as SimReport."""
    time_s: float                  # makespan (last event)
    events: int
    devices: int
    tenants: int
    offered: int                   # requests in the arrival traces
    completed: int
    in_flight: int                 # admitted but unfinished at horizon
    queued: int                    # never admitted by the horizon
    offered_rps: float
    goodput_rps: float             # completed / makespan
    p50_s: float
    p99_s: float
    mean_s: float
    max_s: float
    queue_mean_s: float            # arrival -> admission
    prefill_mean_s: float          # admission -> first token
    decode_mean_s: float           # first token -> completion
    iterations: int
    peak_slots: typing.List[int]   # per tenant
    collectives_completed: int
    compute_busy_s: float
    compute_util: float
    link_report: dict
    fabric: str = "analytic"
    link_utilization: dict = dataclasses.field(default_factory=dict)
    # per-tenant SLO view: a fault on one tenant's links must show up in
    # that tenant's tail even when another tenant owns the global max
    tenant_p50_s: typing.List[float] = dataclasses.field(default_factory=list)
    tenant_p99_s: typing.List[float] = dataclasses.field(default_factory=list)
    per_request: list = dataclasses.field(default_factory=list)
    # -- graceful degradation (recovery layer; zeros without a policy) ----
    collective_timeouts: int = 0
    retries: int = 0               # recovery requeues across tenants
    dropped: int = 0               # requests dropped past max_retries
    recoveries: int = 0            # outage windows closed by a completion
    rejoins: int = 0               # dead chips that re-registered
    chip_deaths: int = 0           # HealthMonitor verdicts (monotone)
    tenant_outage_s: typing.List[float] = dataclasses.field(
        default_factory=list)
    tenant_availability: typing.List[float] = dataclasses.field(
        default_factory=list)
    outage_windows: typing.List[list] = dataclasses.field(
        default_factory=list)     # per tenant: [start_s, end_s] pairs
    goodput_in_outage_rps: float = 0.0    # completions per tenant-second
    goodput_outside_outage_rps: float = 0.0
    # -- stateful failover (spare pool + KV migration) --------------------
    spare_claims: int = 0          # spares claimed for dead chips
    spare_returns: int = 0         # spares handed back to the pool
    migrated_bytes: int = 0        # KV shards moved over the fabric
    prefill_saved_tokens: int = 0  # context resumed from migrated KV
    prefill_recompute_tokens: int = 0   # context lost with dead shards
    # capacity-weighted availability: min(1, members/nominal) integrated
    # over the serving span, 0 inside outage windows -- a tenant held at
    # 3/4 capacity scores 0.75 even while "available"
    tenant_effective_availability: typing.List[float] = dataclasses.field(
        default_factory=list)
    fabric_traffic: dict = dataclasses.field(default_factory=dict)
    scheduler: str = "serial"
    executor: str = "none"

    _EXECUTION_FIELDS = ("scheduler", "executor")

    def summary(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in self._EXECUTION_FIELDS}


def resolve_recovery(recovery, deadline_s: float = None):
    """Resolve the ``recovery`` argument of :func:`run_serving`:
    ``None`` enables a default :class:`RecoveryPolicy` iff ``deadline_s``
    is set (detection without recovery must be asked for explicitly with
    ``recovery=False``); ``True`` enables defaults; ``False`` disables;
    a :class:`RecoveryPolicy` instance is used as-is."""
    if recovery is False:
        return None
    if recovery is True:
        return RecoveryPolicy()
    if recovery is None:
        return RecoveryPolicy() if deadline_s else None
    return recovery


def _effective_availability(cap_log, windows, nominal: int,
                            span_ps: int) -> float:
    """Integrate ``min(1, members/nominal)`` over ``[0, span_ps]``,
    forcing 0 inside outage windows.  All-int accumulation (numerator
    areas in device·ps) so the result is bit-identical regardless of
    event-processing order."""
    if not span_ps or nominal <= 0:
        return 1.0
    steps: typing.Dict[int, int] = {}
    for t, n in cap_log:
        steps[t] = n                         # same-stamp: last wins
    stamps = sorted(steps)
    area = 0
    for i, t in enumerate(stamps):
        if t >= span_ps:
            break
        end = stamps[i + 1] if i + 1 < len(stamps) else span_ps
        end = min(end, span_ps)
        if end <= t:
            continue
        seg = end - t
        out = 0
        for s, e in windows:
            lo, hi = max(t, s), min(end, e)
            if hi > lo:
                out += hi - lo
        area += min(nominal, steps[t]) * (seg - out)
    return area / (nominal * span_ps)


def _fault_candidates(faults: dict) -> set:
    """Chip indices a fault plan can plausibly remove from a mesh (any
    chipN.* target -- even a straggler can be fenced by strike count)."""
    out = set()
    for name in faults or ():
        if name.startswith("chip"):
            head = name[4:].split(".", 1)[0]
            if head.isdigit():
                out.add(int(head))
    return out


def run_serving(scenario: ServingScenario, spec: SystemSpec = None,
                scheduler: str = None, max_workers: int = 4,
                fabric: str = None, executor: str = None,
                faults: dict = None, until_s: float = None,
                deadline_s: float = None,
                recovery=None) -> ServeReport:
    """Run one open-loop serving scenario and report the latency curve
    inputs.  Mirrors :func:`repro.core.simulate.simulate`'s fault-plan
    handling: same grammar, same validation, ``fabric.*`` targets need
    the event fabric.

    ``deadline_s`` threads through to the shared
    :class:`~repro.core.system.CollectiveCoordinator`: a collective that
    has not completed within the deadline of its first join times out
    (the failure-detection signal).  ``recovery`` selects the policy
    (see :func:`resolve_recovery`); with one, a :class:`HealthMonitor`
    turns timeouts + heartbeats into ``chip_dead`` verdicts and tenants
    serve *through* the fault (see docs/faults.md, Detection & recovery).
    """
    spec = spec or SystemSpec()
    policy = resolve_recovery(recovery, deadline_s)
    system = ServingSystem(scenario, spec, scheduler=scheduler,
                           max_workers=max_workers, fabric=fabric,
                           executor=executor, deadline_s=deadline_s,
                           recovery=policy)
    metrics = MetricsHook()
    system.engine.accept_hook(metrics)   # engine-level only (no fusing)
    if faults:
        plan = {name: [(s_to_ps(t), a,
                        s_to_ps(arg) if a == "transient" else arg)
                       for (t, a, arg) in acts]
                for name, acts in faults.items()}
        targets = (system.cores + system.programs + system.servers
                   + system.fabric.fault_targets())
        unknown = set(plan) - {c.name for c in targets}
        if unknown:
            raise ValueError(
                f"fault plan targets unknown components "
                f"{sorted(unknown)}; serving targets are chipN.core / "
                f"chipN.prog / tenantN.server, and fabric.* link/DMA "
                f"targets require fabric='event' (this run uses "
                f"{system.fabric.name!r})")
        inj = FaultInjector(plan)
        for comp in targets:
            comp.accept_hook(inj)
        inj.arm(targets)   # actions apply on schedule even on idle targets
        if policy is not None:
            system.note_failover_plans(_fault_candidates(faults))

    end_ps = system.run(until_s=until_s)
    time_s = ps_to_s(end_ps)

    per_request = []
    e2e, queue_t, prefill_t, decode_t = [], [], [], []
    tenant_e2e: typing.List[list] = [[] for _ in system.servers]
    offered = completed = in_flight = queued = dropped = 0
    for tid, server in enumerate(system.servers):
        for uid in sorted(server.recs):
            rec = server.recs[uid]
            offered += 1
            if rec.dropped_ps is not None:
                dropped += 1
                continue
            if rec.done_ps is None:
                if rec.admit_ps is None:
                    queued += 1
                else:
                    in_flight += 1
                continue
            completed += 1
            q = rec.admit_ps - rec.arrival_ps
            p = rec.first_ps - rec.admit_ps
            d = rec.done_ps - rec.first_ps
            e2e.append(rec.done_ps - rec.arrival_ps)
            tenant_e2e[tid].append(rec.done_ps - rec.arrival_ps)
            queue_t.append(q)
            prefill_t.append(p)
            decode_t.append(d)
            per_request.append({
                "tenant": tid, "uid": uid,
                "arrival_s": ps_to_s(rec.arrival_ps),
                "queue_s": ps_to_s(q), "prefill_s": ps_to_s(p),
                "decode_s": ps_to_s(d),
                "e2e_s": ps_to_s(rec.done_ps - rec.arrival_ps),
                "prompt_len": rec.prompt_len,
                "decode_len": rec.decode_len,
            })

    busy = max((metrics.busy_ps[c.name] for c in system.cores), default=0)
    span_s = max((float(r.arrival_ps) for t in scenario.tenants
                  for r in t.requests), default=0.0) / 1e12

    # Availability accounting: an outage window opens at an abort and
    # closes at the next completed iteration (still open at the end of
    # serving counts in full).  The serving span is per tenant, last
    # request stamp (done / dropped / arrival) -- trailing deadline
    # no-op events must not dilute availability.
    tenant_outage_s, tenant_avail, outage_windows = [], [], []
    tenant_eff_avail = []
    in_out_done = out_done = 0
    in_out_span_ps = out_span_ps = 0
    for server in system.servers:
        span_ps = max((max(rec.done_ps or 0, rec.dropped_ps or 0,
                           rec.arrival_ps)
                       for rec in server.recs.values()), default=0)
        windows = list(server.outages)
        if server._outage_start is not None:
            windows.append((server._outage_start, max(span_ps,
                                                      server._outage_start)))
        outage_ps = sum(e - s for s, e in windows)
        tenant_outage_s.append(ps_to_s(outage_ps))
        tenant_avail.append(1.0 - outage_ps / span_ps if span_ps else 1.0)
        outage_windows.append([[ps_to_s(s), ps_to_s(e)] for s, e in windows])
        tenant_eff_avail.append(_effective_availability(
            server._cap_log, windows, len(server.tenant.devices), span_ps))
        in_out_span_ps += outage_ps
        out_span_ps += span_ps - outage_ps
        for rec in server.recs.values():
            if rec.done_ps is None:
                continue
            # half-open [start, end): the completion that closes an
            # outage window is the restore moment, counted outside
            if any(s <= rec.done_ps < e for s, e in windows):
                in_out_done += 1
            else:
                out_done += 1

    return ServeReport(
        time_s=time_s,
        events=system.engine.events_processed,
        devices=len(system.programs),
        tenants=len(system.servers),
        offered=offered,
        completed=completed,
        in_flight=in_flight,
        queued=queued,
        offered_rps=offered / span_s if span_s else 0.0,
        goodput_rps=completed / time_s if time_s else 0.0,
        p50_s=_pctile_ps(e2e, 50.0),
        p99_s=_pctile_ps(e2e, 99.0),
        mean_s=ps_to_s(int(sum(e2e) / len(e2e))) if e2e else 0.0,
        max_s=ps_to_s(max(e2e)) if e2e else 0.0,
        queue_mean_s=ps_to_s(int(sum(queue_t) / len(queue_t))) if queue_t else 0.0,
        prefill_mean_s=ps_to_s(int(sum(prefill_t) / len(prefill_t))) if prefill_t else 0.0,
        decode_mean_s=ps_to_s(int(sum(decode_t) / len(decode_t))) if decode_t else 0.0,
        iterations=sum(s.iterations for s in system.servers),
        peak_slots=[s.ledger.peak for s in system.servers],
        tenant_p50_s=[_pctile_ps(v, 50.0) for v in tenant_e2e],
        tenant_p99_s=[_pctile_ps(v, 99.0) for v in tenant_e2e],
        collectives_completed=system.coordinator.completed,
        compute_busy_s=busy / 1e12,
        compute_util=(busy / 1e12) / time_s if time_s else 0.0,
        link_report=system.fabric.link_report(),
        fabric=system.fabric.name,
        link_utilization=system.fabric.link_utilization(end_ps or None),
        per_request=per_request,
        collective_timeouts=len(system.coordinator.timed_out),
        retries=sum(s.retries for s in system.servers),
        dropped=dropped,
        recoveries=sum(s.recoveries for s in system.servers),
        rejoins=sum(s.rejoins for s in system.servers),
        chip_deaths=system.monitor.deaths if system.monitor else 0,
        tenant_outage_s=tenant_outage_s,
        tenant_availability=tenant_avail,
        outage_windows=outage_windows,
        goodput_in_outage_rps=(in_out_done / ps_to_s(in_out_span_ps)
                               if in_out_span_ps else 0.0),
        goodput_outside_outage_rps=(out_done / ps_to_s(out_span_ps)
                                    if out_span_ps else 0.0),
        spare_claims=sum(s.spare_claims for s in system.servers),
        spare_returns=sum(s.spare_returns for s in system.servers),
        migrated_bytes=sum(s.migrated_bytes for s in system.servers),
        prefill_saved_tokens=sum(s.prefill_saved_tokens
                                 for s in system.servers),
        prefill_recompute_tokens=sum(s.prefill_recompute_tokens
                                     for s in system.servers),
        tenant_effective_availability=tenant_eff_avail,
        fabric_traffic=system.fabric.traffic_report(),
        scheduler=system.engine.scheduler.name,
        executor=(system.engine.scheduler.executor.name
                  if getattr(system.engine.scheduler, "executor", None)
                  is not None else "none"),
    )


# ---------------------------------------------------------------------------
# Scenario builders (sweepable: return None when the topology can't host)
# ---------------------------------------------------------------------------

def _dense_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-dense", family="dense",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000)


def _moe_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-moe", family="moe",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000, num_experts=8, experts_per_token=2)


def build_scenario(spec: SystemSpec, name: str = "serving",
                   arrival: str = "poisson", rate_rps: float = 500.0,
                   duration_s: float = 0.02, seed: int = 0,
                   tenants: int = 2, slots: int = 4,
                   prompt_range: typing.Tuple[int, int] = (16, 64),
                   decode_range: typing.Tuple[int, int] = (4, 12),
                   moe: bool = False,
                   model: ModelConfig = None,
                   spares: int = 0) -> typing.Optional[ServingScenario]:
    """Place ``tenants`` tenants on contiguous row-blocks of pod 0 and
    attach seeded open-loop traces.  ``spares`` reserves that many chips
    (the ones right after the tenant blocks, spilling into further pods)
    for the HealthMonitor's shared failover pool.  Returns None when pod
    0 hasn't a row per tenant, or the topology hasn't enough chips left
    over for the spares (sweep grids skip the combo, same contract as
    the collective scenario builders in tools/sweep.py)."""
    if arrival not in GENERATORS:
        raise ValueError(f"unknown arrival generator {arrival!r}; "
                         f"have {sorted(GENERATORS)}")
    y, x = spec.pod_shape[0], spec.pod_shape[1]
    rows_per = y // tenants
    if rows_per < 1:
        return None
    first_free = tenants * rows_per * x
    if spares and first_free + spares > spec.total_chips:
        return None
    spare_devs = tuple(range(first_free, first_free + spares))
    model = model or (_moe_model() if moe else _dense_model())
    specs = []
    for tid in range(tenants):
        devices = tuple(range(tid * rows_per * x, (tid + 1) * rows_per * x))
        times = GENERATORS[arrival](rate_rps, duration_s,
                                    seed=seed * 1000 + tid)
        reqs = make_requests(times, seed=seed * 1000 + tid + 500,
                             prompt_range=prompt_range,
                             decode_range=decode_range)
        specs.append(TenantSpec(name=f"{name}.t{tid}", devices=devices,
                                model=model, slots=slots, requests=reqs))
    return ServingScenario(name=name, tenants=tuple(specs),
                           spares=spare_devs)
