"""Open-loop multi-tenant LLM serving on the system model.

This is the paper's case-study methodology (drive a realistic workload
through the simulator, read end-to-end latency under contention) pointed
at the serving workload the ROADMAP names: open-loop arrival traces feed
per-tenant continuous-batching servers whose prefill/decode compute runs
on :class:`~repro.core.chip.TensorCore` components and whose per-layer
collectives go through the pluggable fabric — so two tenants sharing a
pod contend on real links under ``fabric="event"``, and fault plans from
``docs/faults.md`` degrade tail latency observably.

Nothing here calls JAX: `repro.serve.engine` is the *functional* model
(real decode steps, exactness oracle); this module is the *timing* model
(simulator events sized from the model config).  Both implement Orca
continuous batching: admission waits on free KV-cache slots, iterations
batch every active request, slots release on completion.

Determinism: arrival traces, prompt/decode lengths and all component
logic are seeded and integer-timed, so ``ServeReport.summary()`` is
bit-identical across every scheduler x executor combination — the same
contract the rest of the engine holds (`tests/test_executor.py`).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import typing

import numpy as np

from ..core.chip import ComputeJob, HbmController, TensorCore
from ..core.component import Component
from ..core.connection import Connection, Request
from ..core.engine import Engine
from ..core.event import Event
from ..core.hooks import FaultInjector, MetricsHook
from ..core.hw import SystemSpec, ps_to_s, s_to_ps
from ..core.system import CollectiveCoordinator, StarConnection
from ..models.base import ModelConfig


# ---------------------------------------------------------------------------
# Arrival-trace generators (open loop: arrivals don't wait for completions)
# ---------------------------------------------------------------------------

def poisson_trace(rate_rps: float, duration_s: float, seed: int) -> np.ndarray:
    """Homogeneous Poisson arrivals: exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= duration_s:
            return np.asarray(out)
        out.append(t)


def bursty_trace(rate_rps: float, duration_s: float, seed: int,
                 burst_factor: float = 4.0, dwell_s: float = None) -> np.ndarray:
    """Two-state MMPP: a calm state at ``rate/burst_factor`` and a burst
    state at ``rate*burst_factor``, with exponential dwell times.  Mean
    rate stays near ``rate_rps`` (equal expected dwell in each state)."""
    rng = np.random.default_rng(seed)
    dwell = dwell_s if dwell_s is not None else max(duration_s / 8.0, 1e-6)
    rates = (rate_rps / burst_factor, rate_rps * burst_factor)
    state, t, next_switch = 0, 0.0, rng.exponential(dwell)
    out = []
    while t < duration_s:
        dt = rng.exponential(1.0 / rates[state])
        if t + dt >= next_switch:
            t = next_switch
            next_switch = t + rng.exponential(dwell)
            state = 1 - state
            continue
        t += dt
        if t >= duration_s:
            break
        out.append(t)
    return np.asarray(out)


def diurnal_trace(rate_rps: float, duration_s: float, seed: int,
                  depth: float = 0.8, period_s: float = None) -> np.ndarray:
    """Sinusoidally modulated Poisson process via thinning: instantaneous
    rate ``rate*(1 + depth*sin)``, peak-rate candidates kept with
    probability lambda(t)/lambda_max.  Models the day/night swing of an
    open user population."""
    rng = np.random.default_rng(seed)
    period = period_s if period_s is not None else duration_s
    lam_max = rate_rps * (1.0 + depth)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_s:
            return np.asarray(out)
        lam = rate_rps * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.uniform() * lam_max < lam:
            out.append(t)


GENERATORS: typing.Dict[str, typing.Callable] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One user request: arrival stamp plus pre-drawn lengths (the eos
    position is drawn up front so timing never depends on token values)."""
    uid: int
    arrival_ps: int
    prompt_len: int
    decode_len: int          # decode iterations until eos/completion (>= 1)


def make_requests(times_s: np.ndarray, seed: int,
                  prompt_range: typing.Tuple[int, int] = (16, 64),
                  decode_range: typing.Tuple[int, int] = (4, 12),
                  ) -> typing.Tuple[ServeRequest, ...]:
    """Attach seeded prompt/decode lengths to an arrival trace."""
    rng = np.random.default_rng(seed)
    n = len(times_s)
    prompts = rng.integers(prompt_range[0], prompt_range[1] + 1, size=n)
    decodes = rng.integers(decode_range[0], decode_range[1] + 1, size=n)
    return tuple(
        ServeRequest(uid=i, arrival_ps=s_to_ps(float(t)),
                     prompt_len=int(p), decode_len=int(d))
        for i, (t, p, d) in enumerate(zip(times_s, prompts, decodes)))


# ---------------------------------------------------------------------------
# Scenario description + collective/compute sizing from the model config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a model served tensor-parallel over ``devices`` with
    ``slots`` KV-cache slots and an open-loop request trace."""
    name: str
    devices: typing.Tuple[int, ...]
    model: ModelConfig
    slots: int
    requests: typing.Tuple[ServeRequest, ...]
    coll_ops: int = 4        # decode allreduces per iteration (layer groups)


@dataclasses.dataclass(frozen=True)
class ServingScenario:
    name: str
    tenants: typing.Tuple[TenantSpec, ...]


class ServeSizing:
    """Deterministic op sizing for one tenant.  Flops/bytes are roofline
    inputs for :class:`TensorCore`; collective payloads are exact ints so
    the byte counts noted to the fabric up front match the issued joins
    bit-for-bit (the event fabric's planned-edge guard requires it)."""

    def __init__(self, tenant: TenantSpec) -> None:
        m = tenant.model
        self.tp = max(1, len(tenant.devices))
        d_ff = m.d_ff if m.d_ff else 4 * m.d_model
        layers = max(1, m.num_layers)
        self.params = (layers * (4 * m.d_model * m.d_model
                                 + 2 * m.d_model * d_ff)
                       + m.vocab_size * m.d_model)
        self.param_bytes = 2.0 * self.params          # bf16 weights
        self.d_model = m.d_model
        self.coll_ops = max(1, min(tenant.coll_ops, layers))
        self.layers_per_op = max(1, layers // self.coll_ops)
        self.moe = m.family == "moe" and m.num_experts > 1
        self.ept = max(1, m.experts_per_token)

    # compute (per device; tensor-parallel shards weights 1/tp)
    def prefill_flops(self, prompt_len: int) -> float:
        return 2.0 * self.params * prompt_len / self.tp

    def prefill_hbm(self, prompt_len: int) -> float:
        return self.param_bytes / self.tp

    def decode_flops(self, batch: int) -> float:
        return 2.0 * self.params * batch / self.tp

    def decode_hbm(self, batch: int) -> float:
        # weight-streaming bound + a token of KV per active request
        return self.param_bytes / self.tp + 2.0 * batch * self.d_model

    # collectives (exact ints; one activation row per active request)
    def ar_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.layers_per_op

    def a2a_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.ept


# ---------------------------------------------------------------------------
# Slot ledger: KV-cache capacity as pure, property-testable accounting
# ---------------------------------------------------------------------------

class SlotLedger:
    """KV-cache slots as schedulable capacity.  Pure bookkeeping (no
    engine dependency) so hypothesis can drive random admit/release
    interleavings against the invariants: occupancy never exceeds
    capacity, no uid is lost or double-completed, lowest free slot wins
    (deterministic placement)."""

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.free: typing.List[int] = list(range(capacity))
        self.active: typing.Dict[int, int] = {}      # slot -> uid
        self.seated: typing.Dict[int, int] = {}      # uid -> slot
        self.completed: set = set()
        self.peak = 0

    @property
    def in_use(self) -> int:
        return len(self.active)

    def has_free(self) -> bool:
        return bool(self.free)

    def admit(self, uid: int) -> int:
        if uid in self.seated:
            raise ValueError(f"uid {uid} already seated")
        if uid in self.completed:
            raise ValueError(f"uid {uid} already completed")
        if not self.free:
            raise RuntimeError("admit with no free slot")
        slot = self.free.pop(0)                       # lowest slot first
        self.active[slot] = uid
        self.seated[uid] = slot
        self.peak = max(self.peak, len(self.active))
        return slot

    def release(self, uid: int) -> int:
        if uid in self.completed:
            raise ValueError(f"uid {uid} double-completed")
        slot = self.seated.pop(uid, None)
        if slot is None:
            raise ValueError(f"uid {uid} not seated")
        del self.active[slot]
        self.completed.add(uid)
        bisect.insort(self.free, slot)
        return slot


class _ReqLog:
    """Mutable per-request timing record (all integer picoseconds, so
    queue + prefill + decode == end-to-end exactly, no float residue)."""
    __slots__ = ("uid", "arrival_ps", "prompt_len", "decode_len",
                 "admit_ps", "first_ps", "done_ps", "remaining")

    def __init__(self, req: ServeRequest) -> None:
        self.uid = req.uid
        self.arrival_ps = req.arrival_ps
        self.prompt_len = req.prompt_len
        self.decode_len = req.decode_len
        self.admit_ps = None
        self.first_ps = None
        self.done_ps = None
        self.remaining = req.decode_len

    def __getstate__(self):
        return {s: getattr(self, s) for s in self.__slots__}

    def __setstate__(self, state):
        for k, v in state.items():
            setattr(self, k, v)


# ---------------------------------------------------------------------------
# Components: per-chip serving program + per-tenant batching server
# ---------------------------------------------------------------------------

class ServeProgram(Component):
    """One chip's slice of a tenant: executes the iteration's op list
    (prefill/decode compute on its TensorCore, collective joins through
    the coordinator star) and reports phase completion to its tenant
    server.  Mirrors DeviceProgram's issue/wait loop, but the "trace" is
    re-sent every iteration by the server (DP-3: only connections carry
    cross-component traffic)."""

    def __init__(self, name: str, device: int,
                 group: typing.Tuple[int, ...]) -> None:
        super().__init__(name)
        self.device = device
        self.group = tuple(group)
        self.ops: tuple = ()
        self.pc = 0
        self.iter_id = -1
        self.phases_done = 0

    def start(self) -> None:
        self.schedule("hello")

    def handle(self, event: Event) -> None:
        if event.kind == "hello":
            # Register with the tenant server (spoke->hub auto-routes);
            # the reference rides the payload like coordinator joins do,
            # surviving the procs executor as a rank.
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=None, kind="register",
                payload=(self.device, self)))
            return
        if event.kind != "request":
            return
        req = event.payload
        if req.kind == "phase":
            self.iter_id, self.ops = req.payload
            self.pc = 0
            self._issue()
        elif req.kind in ("compute_done", "collective_done"):
            self.pc += 1
            self._issue()

    def _issue(self) -> None:
        if self.pc >= len(self.ops):
            self.phases_done += 1
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=None, kind="phase_done",
                payload=self.iter_id))
            return
        op = self.ops[self.pc]
        if op[0] == "compute":
            _, tag, flops, hbm_bytes = op
            self.port("core").send(Request(
                src=self.port("core"), dst=None, kind="job",
                payload=ComputeJob(flops=flops, hbm_bytes=hbm_bytes,
                                   tag=tag, reply_to=self)))
        else:  # ("coll", name, kind, nbytes)
            _, name, kind, nbytes = op
            self.port("coll").send(Request(
                src=self.port("coll"), dst=None, kind="join",
                size_bytes=int(nbytes),
                payload=(name, 0, kind, float(nbytes), self.group,
                         self.device, self)))


class TenantServer(Component):
    """Per-tenant continuous-batching scheduler (the Orca loop as
    simulator events).  Each iteration: admit queued requests into free
    KV slots, broadcast one op list (new prefills + one batched decode +
    its collectives) to every member chip, wait for all phase_done
    replies, then retire finished requests and start the next iteration.
    Open loop: arrivals are pre-scheduled self-events from the trace and
    never wait on completions."""

    def __init__(self, name: str, tenant: TenantSpec) -> None:
        super().__init__(name)
        self.tenant = tenant
        self.sizing = ServeSizing(tenant)
        self.ledger = SlotLedger(tenant.slots)
        self.members: typing.Dict[int, object] = {}    # device -> program
        self.queue: typing.List[int] = []              # waiting uids (FIFO)
        self.recs: typing.Dict[int, _ReqLog] = {
            r.uid: _ReqLog(r) for r in tenant.requests}
        self.completed_order: typing.List[int] = []
        self.iter_id = -1
        self.iterations = 0
        self._phase_replies = 0
        self._newly: typing.List[int] = []

    def start(self) -> None:
        for r in self.tenant.requests:
            self.schedule("arrival", r.arrival_ps, payload=r.uid)

    def handle(self, event: Event) -> None:
        if event.kind == "arrival":
            self.queue.append(event.payload)
            self._maybe_iterate()
        elif event.kind == "request":
            req = event.payload
            if req.kind == "register":
                device, prog = req.payload
                self.members[device] = prog
                self._maybe_iterate()
            elif req.kind == "phase_done":
                self._phase_replies -= 1
                if self._phase_replies == 0:
                    self._finish_iteration()

    # -- the Orca iteration ------------------------------------------------
    def _maybe_iterate(self) -> None:
        if self._phase_replies:                  # iteration in flight
            return
        if len(self.members) < len(self.tenant.devices):
            return                               # chips still registering
        admitted = []
        while self.queue and self.ledger.has_free():
            uid = self.queue.pop(0)
            self.ledger.admit(uid)
            rec = self.recs[uid]
            rec.admit_ps = self.engine.now
            admitted.append(uid)
        if not self.ledger.in_use:
            return                               # idle until next arrival
        self.iter_id += 1
        self.iterations += 1
        self._newly = admitted
        ops = self._build_ops(admitted)
        self._phase_replies = len(self.members)
        for d in sorted(self.members):
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=self.members[d], kind="phase",
                payload=(self.iter_id, ops)))

    def _build_ops(self, admitted: typing.List[int]) -> tuple:
        s = self.sizing
        it = self.iter_id
        ops = []
        for uid in admitted:
            rec = self.recs[uid]
            ops.append(("compute", f"{self.name}.i{it}.prefill{uid}",
                        s.prefill_flops(rec.prompt_len),
                        s.prefill_hbm(rec.prompt_len)))
        batch = self.ledger.in_use
        ops.append(("compute", f"{self.name}.i{it}.decode",
                    s.decode_flops(batch), s.decode_hbm(batch)))
        if len(self.tenant.devices) > 1:
            for k in range(s.coll_ops):
                ops.append(("coll", f"{self.name}.i{it}.ar{k}",
                            "all-reduce", s.ar_bytes(batch)))
            if s.moe:
                # MoE dispatch + combine: two a2a per iteration
                ops.append(("coll", f"{self.name}.i{it}.a2a0",
                            "all-to-all", s.a2a_bytes(batch)))
                ops.append(("coll", f"{self.name}.i{it}.a2a1",
                            "all-to-all", s.a2a_bytes(batch)))
        return tuple(ops)

    def _finish_iteration(self) -> None:
        now = self.engine.now
        for uid in self._newly:
            self.recs[uid].first_ps = now        # first token this iteration
        self._newly = []
        for slot, uid in sorted(self.ledger.active.items()):
            rec = self.recs[uid]
            rec.remaining -= 1
            if rec.remaining <= 0:               # pre-drawn eos reached
                rec.done_ps = now
                self.ledger.release(uid)
                self.completed_order.append(uid)
        self._maybe_iterate()


# ---------------------------------------------------------------------------
# System assembly
# ---------------------------------------------------------------------------

class ServingSystem:
    """A machine wired for serving: shared coordinator + fabric, plus per
    tenant a :class:`TenantServer` on its own control star and per device
    a fresh TensorCore/HbmController/ServeProgram triple.  Chips are
    wired exactly like :class:`repro.core.system.System` (2-endpoint
    buses so request auto-routing holds); tenants share the fabric, which
    is where multi-tenant link contention comes from."""

    def __init__(self, scenario: ServingScenario, spec: SystemSpec,
                 scheduler=None, max_workers: int = 4, fabric=None,
                 executor=None) -> None:
        from ..fabric import make_fabric   # late: fabric imports core modules
        seen: set = set()
        for t in scenario.tenants:
            if not t.devices:
                raise ValueError(f"tenant {t.name!r} has no devices")
            for d in t.devices:
                if not 0 <= d < spec.total_chips:
                    raise ValueError(
                        f"tenant {t.name!r} device {d} outside "
                        f"topology with {spec.total_chips} chips")
                if d in seen:
                    raise ValueError(
                        f"device {d} assigned to two tenants; tenant "
                        f"placements must be disjoint")
                seen.add(d)
        self.scenario = scenario
        self.spec = spec
        self.engine = Engine(scheduler=scheduler, max_workers=max_workers,
                             executor=executor)
        self.fabric = make_fabric(fabric or spec.fabric, spec)
        self.coordinator = self.engine.register(
            CollectiveCoordinator("coordinator"))
        self.fabric.install(self.engine, self.coordinator)
        coll_conn = self.engine.register(
            StarConnection("coll_fabric", self.coordinator.port("coll"),
                           latency_s=spec.ctrl_latency_s))
        self.servers: typing.List[TenantServer] = []
        self.programs: typing.List[ServeProgram] = []
        self.cores: typing.List[TensorCore] = []
        self.hbms: typing.List[HbmController] = []
        for tid, tenant in enumerate(scenario.tenants):
            server = self.engine.register(
                TenantServer(f"tenant{tid}.server", tenant))
            ctrl = self.engine.register(
                StarConnection(f"tenant{tid}.ctrl", server.port("ctrl"),
                               latency_s=spec.ctrl_latency_s))
            for d in tenant.devices:
                core = self.engine.register(
                    TensorCore(f"chip{d}.core", spec.chip))
                hbm = self.engine.register(
                    HbmController(f"chip{d}.hbm", spec.chip))
                prog = self.engine.register(
                    ServeProgram(f"chip{d}.prog", d, tenant.devices))
                self.engine.register(Connection(f"chip{d}.bus")).plug(
                    prog.port("core")).plug(core.port("prog"))
                self.engine.register(Connection(f"chip{d}.membus")).plug(
                    core.port("hbm")).plug(hbm.port("cpu"))
                coll_conn.plug(prog.port("coll"))
                ctrl.plug(prog.port("ctrl"))
                self.programs.append(prog)
                self.cores.append(core)
                self.hbms.append(hbm)
            self.servers.append(server)
            # Advance notice of every collective this tenant can issue
            # (batch sizes 1..slots): the event fabric refines bounded-lag
            # edges from these exact (kind, bytes, group) triples, and its
            # strict-window guard fails loudly on an un-noted collective.
            if len(tenant.devices) > 1:
                s = ServeSizing(tenant)
                for b in range(1, tenant.slots + 1):
                    self.fabric.note_plan("all-reduce", float(s.ar_bytes(b)),
                                          tuple(tenant.devices))
                    if s.moe:
                        self.fabric.note_plan("all-to-all",
                                              float(s.a2a_bytes(b)),
                                              tuple(tenant.devices))

    def run(self, until_s: float = None) -> int:
        for prog in self.programs:
            prog.start()
        for server in self.servers:
            server.start()
        return self.engine.run(s_to_ps(until_s) if until_s else None)


# ---------------------------------------------------------------------------
# Report + driver
# ---------------------------------------------------------------------------

def _pctile_ps(values_ps: typing.List[int], q: float) -> float:
    """Nearest-rank percentile in seconds (deterministic, no interpolation)."""
    if not values_ps:
        return 0.0
    v = sorted(values_ps)
    k = max(0, math.ceil(q / 100.0 * len(v)) - 1)
    return ps_to_s(v[k])


@dataclasses.dataclass
class ServeReport:
    """One serving run.  ``summary()`` excludes execution artifacts so it
    is bit-identical across schedulers and executors, same as SimReport."""
    time_s: float                  # makespan (last event)
    events: int
    devices: int
    tenants: int
    offered: int                   # requests in the arrival traces
    completed: int
    in_flight: int                 # admitted but unfinished at horizon
    queued: int                    # never admitted by the horizon
    offered_rps: float
    goodput_rps: float             # completed / makespan
    p50_s: float
    p99_s: float
    mean_s: float
    max_s: float
    queue_mean_s: float            # arrival -> admission
    prefill_mean_s: float          # admission -> first token
    decode_mean_s: float           # first token -> completion
    iterations: int
    peak_slots: typing.List[int]   # per tenant
    collectives_completed: int
    compute_busy_s: float
    compute_util: float
    link_report: dict
    fabric: str = "analytic"
    link_utilization: dict = dataclasses.field(default_factory=dict)
    # per-tenant SLO view: a fault on one tenant's links must show up in
    # that tenant's tail even when another tenant owns the global max
    tenant_p50_s: typing.List[float] = dataclasses.field(default_factory=list)
    tenant_p99_s: typing.List[float] = dataclasses.field(default_factory=list)
    per_request: list = dataclasses.field(default_factory=list)
    scheduler: str = "serial"
    executor: str = "none"

    _EXECUTION_FIELDS = ("scheduler", "executor")

    def summary(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in self._EXECUTION_FIELDS}


def run_serving(scenario: ServingScenario, spec: SystemSpec = None,
                scheduler: str = None, max_workers: int = 4,
                fabric: str = None, executor: str = None,
                faults: dict = None, until_s: float = None) -> ServeReport:
    """Run one open-loop serving scenario and report the latency curve
    inputs.  Mirrors :func:`repro.core.simulate.simulate`'s fault-plan
    handling: same grammar, same validation, ``fabric.*`` targets need
    the event fabric."""
    spec = spec or SystemSpec()
    system = ServingSystem(scenario, spec, scheduler=scheduler,
                           max_workers=max_workers, fabric=fabric,
                           executor=executor)
    metrics = MetricsHook()
    system.engine.accept_hook(metrics)   # engine-level only (no fusing)
    if faults:
        plan = {name: [(s_to_ps(t), a,
                        s_to_ps(arg) if a == "transient" else arg)
                       for (t, a, arg) in acts]
                for name, acts in faults.items()}
        targets = (system.cores + system.programs + system.servers
                   + system.fabric.fault_targets())
        unknown = set(plan) - {c.name for c in targets}
        if unknown:
            raise ValueError(
                f"fault plan targets unknown components "
                f"{sorted(unknown)}; serving targets are chipN.core / "
                f"chipN.prog / tenantN.server, and fabric.* link/DMA "
                f"targets require fabric='event' (this run uses "
                f"{system.fabric.name!r})")
        inj = FaultInjector(plan)
        for comp in targets:
            comp.accept_hook(inj)

    end_ps = system.run(until_s=until_s)
    time_s = ps_to_s(end_ps)

    per_request = []
    e2e, queue_t, prefill_t, decode_t = [], [], [], []
    tenant_e2e: typing.List[list] = [[] for _ in system.servers]
    offered = completed = in_flight = queued = 0
    for tid, server in enumerate(system.servers):
        for uid in sorted(server.recs):
            rec = server.recs[uid]
            offered += 1
            if rec.done_ps is None:
                if rec.admit_ps is None:
                    queued += 1
                else:
                    in_flight += 1
                continue
            completed += 1
            q = rec.admit_ps - rec.arrival_ps
            p = rec.first_ps - rec.admit_ps
            d = rec.done_ps - rec.first_ps
            e2e.append(rec.done_ps - rec.arrival_ps)
            tenant_e2e[tid].append(rec.done_ps - rec.arrival_ps)
            queue_t.append(q)
            prefill_t.append(p)
            decode_t.append(d)
            per_request.append({
                "tenant": tid, "uid": uid,
                "arrival_s": ps_to_s(rec.arrival_ps),
                "queue_s": ps_to_s(q), "prefill_s": ps_to_s(p),
                "decode_s": ps_to_s(d),
                "e2e_s": ps_to_s(rec.done_ps - rec.arrival_ps),
                "prompt_len": rec.prompt_len,
                "decode_len": rec.decode_len,
            })

    busy = max((metrics.busy_ps[c.name] for c in system.cores), default=0)
    span_s = max((float(r.arrival_ps) for t in scenario.tenants
                  for r in t.requests), default=0.0) / 1e12
    return ServeReport(
        time_s=time_s,
        events=system.engine.events_processed,
        devices=len(system.programs),
        tenants=len(system.servers),
        offered=offered,
        completed=completed,
        in_flight=in_flight,
        queued=queued,
        offered_rps=offered / span_s if span_s else 0.0,
        goodput_rps=completed / time_s if time_s else 0.0,
        p50_s=_pctile_ps(e2e, 50.0),
        p99_s=_pctile_ps(e2e, 99.0),
        mean_s=ps_to_s(int(sum(e2e) / len(e2e))) if e2e else 0.0,
        max_s=ps_to_s(max(e2e)) if e2e else 0.0,
        queue_mean_s=ps_to_s(int(sum(queue_t) / len(queue_t))) if queue_t else 0.0,
        prefill_mean_s=ps_to_s(int(sum(prefill_t) / len(prefill_t))) if prefill_t else 0.0,
        decode_mean_s=ps_to_s(int(sum(decode_t) / len(decode_t))) if decode_t else 0.0,
        iterations=sum(s.iterations for s in system.servers),
        peak_slots=[s.ledger.peak for s in system.servers],
        tenant_p50_s=[_pctile_ps(v, 50.0) for v in tenant_e2e],
        tenant_p99_s=[_pctile_ps(v, 99.0) for v in tenant_e2e],
        collectives_completed=system.coordinator.completed,
        compute_busy_s=busy / 1e12,
        compute_util=(busy / 1e12) / time_s if time_s else 0.0,
        link_report=system.fabric.link_report(),
        fabric=system.fabric.name,
        link_utilization=system.fabric.link_utilization(end_ps or None),
        per_request=per_request,
        scheduler=system.engine.scheduler.name,
        executor=(system.engine.scheduler.executor.name
                  if getattr(system.engine.scheduler, "executor", None)
                  is not None else "none"),
    )


# ---------------------------------------------------------------------------
# Scenario builders (sweepable: return None when the topology can't host)
# ---------------------------------------------------------------------------

def _dense_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-dense", family="dense",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000)


def _moe_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-moe", family="moe",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000, num_experts=8, experts_per_token=2)


def build_scenario(spec: SystemSpec, name: str = "serving",
                   arrival: str = "poisson", rate_rps: float = 500.0,
                   duration_s: float = 0.02, seed: int = 0,
                   tenants: int = 2, slots: int = 4,
                   prompt_range: typing.Tuple[int, int] = (16, 64),
                   decode_range: typing.Tuple[int, int] = (4, 12),
                   moe: bool = False,
                   model: ModelConfig = None) -> typing.Optional[ServingScenario]:
    """Place ``tenants`` tenants on contiguous row-blocks of pod 0 and
    attach seeded open-loop traces.  Returns None when pod 0 hasn't a
    row per tenant (sweep grids skip the combo, same contract as the
    collective scenario builders in tools/sweep.py)."""
    if arrival not in GENERATORS:
        raise ValueError(f"unknown arrival generator {arrival!r}; "
                         f"have {sorted(GENERATORS)}")
    y, x = spec.pod_shape[0], spec.pod_shape[1]
    rows_per = y // tenants
    if rows_per < 1:
        return None
    model = model or (_moe_model() if moe else _dense_model())
    specs = []
    for tid in range(tenants):
        devices = tuple(range(tid * rows_per * x, (tid + 1) * rows_per * x))
        times = GENERATORS[arrival](rate_rps, duration_s,
                                    seed=seed * 1000 + tid)
        reqs = make_requests(times, seed=seed * 1000 + tid + 500,
                             prompt_range=prompt_range,
                             decode_range=decode_range)
        specs.append(TenantSpec(name=f"{name}.t{tid}", devices=devices,
                                model=model, slots=slots, requests=reqs))
    return ServingScenario(name=name, tenants=tuple(specs))
