"""Open-loop multi-tenant LLM serving on the system model.

This is the paper's case-study methodology (drive a realistic workload
through the simulator, read end-to-end latency under contention) pointed
at the serving workload the ROADMAP names: open-loop arrival traces feed
per-tenant continuous-batching servers whose prefill/decode compute runs
on :class:`~repro.core.chip.TensorCore` components and whose per-layer
collectives go through the pluggable fabric — so two tenants sharing a
pod contend on real links under ``fabric="event"``, and fault plans from
``docs/faults.md`` degrade tail latency observably.

Nothing here calls JAX: `repro.serve.engine` is the *functional* model
(real decode steps, exactness oracle); this module is the *timing* model
(simulator events sized from the model config).  Both implement Orca
continuous batching: admission waits on free KV-cache slots, iterations
batch every active request, slots release on completion.

Determinism: arrival traces, prompt/decode lengths and all component
logic are seeded and integer-timed, so ``ServeReport.summary()`` is
bit-identical across every scheduler x executor combination — the same
contract the rest of the engine holds (`tests/test_executor.py`).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import typing

import numpy as np

from ..core.chip import ComputeJob, HbmController, TensorCore
from ..core.component import Component
from ..core.connection import Connection, Request
from ..core.engine import Engine
from ..core.event import Event
from ..core.hooks import FaultInjector, MetricsHook
from ..core.hw import SystemSpec, ps_to_s, s_to_ps
from ..core.system import CollectiveCoordinator, StarConnection
from ..models.base import ModelConfig


# ---------------------------------------------------------------------------
# Arrival-trace generators (open loop: arrivals don't wait for completions)
# ---------------------------------------------------------------------------

def poisson_trace(rate_rps: float, duration_s: float, seed: int) -> np.ndarray:
    """Homogeneous Poisson arrivals: exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= duration_s:
            return np.asarray(out)
        out.append(t)


def bursty_trace(rate_rps: float, duration_s: float, seed: int,
                 burst_factor: float = 4.0, dwell_s: float = None) -> np.ndarray:
    """Two-state MMPP: a calm state at ``rate/burst_factor`` and a burst
    state at ``rate*burst_factor``, with exponential dwell times.  Mean
    rate stays near ``rate_rps`` (equal expected dwell in each state)."""
    rng = np.random.default_rng(seed)
    dwell = dwell_s if dwell_s is not None else max(duration_s / 8.0, 1e-6)
    rates = (rate_rps / burst_factor, rate_rps * burst_factor)
    state, t, next_switch = 0, 0.0, rng.exponential(dwell)
    out = []
    while t < duration_s:
        dt = rng.exponential(1.0 / rates[state])
        if t + dt >= next_switch:
            t = next_switch
            next_switch = t + rng.exponential(dwell)
            state = 1 - state
            continue
        t += dt
        if t >= duration_s:
            break
        out.append(t)
    return np.asarray(out)


def diurnal_trace(rate_rps: float, duration_s: float, seed: int,
                  depth: float = 0.8, period_s: float = None) -> np.ndarray:
    """Sinusoidally modulated Poisson process via thinning: instantaneous
    rate ``rate*(1 + depth*sin)``, peak-rate candidates kept with
    probability lambda(t)/lambda_max.  Models the day/night swing of an
    open user population."""
    rng = np.random.default_rng(seed)
    period = period_s if period_s is not None else duration_s
    lam_max = rate_rps * (1.0 + depth)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= duration_s:
            return np.asarray(out)
        lam = rate_rps * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        if rng.uniform() * lam_max < lam:
            out.append(t)


GENERATORS: typing.Dict[str, typing.Callable] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One user request: arrival stamp plus pre-drawn lengths (the eos
    position is drawn up front so timing never depends on token values)."""
    uid: int
    arrival_ps: int
    prompt_len: int
    decode_len: int          # decode iterations until eos/completion (>= 1)


def make_requests(times_s: np.ndarray, seed: int,
                  prompt_range: typing.Tuple[int, int] = (16, 64),
                  decode_range: typing.Tuple[int, int] = (4, 12),
                  ) -> typing.Tuple[ServeRequest, ...]:
    """Attach seeded prompt/decode lengths to an arrival trace."""
    rng = np.random.default_rng(seed)
    n = len(times_s)
    prompts = rng.integers(prompt_range[0], prompt_range[1] + 1, size=n)
    decodes = rng.integers(decode_range[0], decode_range[1] + 1, size=n)
    return tuple(
        ServeRequest(uid=i, arrival_ps=s_to_ps(float(t)),
                     prompt_len=int(p), decode_len=int(d))
        for i, (t, p, d) in enumerate(zip(times_s, prompts, decodes)))


# ---------------------------------------------------------------------------
# Scenario description + collective/compute sizing from the model config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a model served tensor-parallel over ``devices`` with
    ``slots`` KV-cache slots and an open-loop request trace."""
    name: str
    devices: typing.Tuple[int, ...]
    model: ModelConfig
    slots: int
    requests: typing.Tuple[ServeRequest, ...]
    coll_ops: int = 4        # decode allreduces per iteration (layer groups)


@dataclasses.dataclass(frozen=True)
class ServingScenario:
    name: str
    tenants: typing.Tuple[TenantSpec, ...]


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Failure-detection and recovery knobs for :func:`run_serving`.

    * ``max_retries`` -- recovery requeues a request may survive before
      it is dropped (SLO miss);
    * ``backoff_base_s`` -- requeue delay after an abort, doubled per
      retry (exponential backoff gives the detector time to fence the
      dead chip before the retry lands on it again);
    * ``heartbeat_s`` -- HealthMonitor probe period (0 disables the
      heartbeat loop; detection then rides collective timeouts alone,
      so a tenant with no collectives in flight has no detector);
    * ``probe_timeout_s`` -- how long a suspect has to answer a
      targeted probe before it is declared dead (must exceed one
      control-star round trip);
    * ``suspect_threshold`` -- collective-timeout strikes that condemn a
      chip even though it still answers probes (a wedged-but-pingable
      chip: compute hangs, control plane lives).
    """
    max_retries: int = 3
    backoff_base_s: float = 3e-4
    heartbeat_s: float = 5e-4
    probe_timeout_s: float = 1e-4
    suspect_threshold: int = 3


class ServeSizing:
    """Deterministic op sizing for one tenant.  Flops/bytes are roofline
    inputs for :class:`TensorCore`; collective payloads are exact ints so
    the byte counts noted to the fabric up front match the issued joins
    bit-for-bit (the event fabric's planned-edge guard requires it).

    ``tp`` overrides the tensor-parallel degree (default: the tenant's
    full device count) -- a re-meshed degraded group serves with ``tp``
    equal to the surviving member count, so per-chip flops/bytes grow
    while the collective payloads (activation rows, tp-independent) stay
    bit-equal to the plans noted up front."""

    def __init__(self, tenant: TenantSpec, tp: int = None) -> None:
        m = tenant.model
        self.tp = max(1, len(tenant.devices) if tp is None else tp)
        d_ff = m.d_ff if m.d_ff else 4 * m.d_model
        layers = max(1, m.num_layers)
        self.params = (layers * (4 * m.d_model * m.d_model
                                 + 2 * m.d_model * d_ff)
                       + m.vocab_size * m.d_model)
        self.param_bytes = 2.0 * self.params          # bf16 weights
        self.d_model = m.d_model
        self.coll_ops = max(1, min(tenant.coll_ops, layers))
        self.layers_per_op = max(1, layers // self.coll_ops)
        self.moe = m.family == "moe" and m.num_experts > 1
        self.ept = max(1, m.experts_per_token)

    # compute (per device; tensor-parallel shards weights 1/tp)
    def prefill_flops(self, prompt_len: int) -> float:
        return 2.0 * self.params * prompt_len / self.tp

    def prefill_hbm(self, prompt_len: int) -> float:
        return self.param_bytes / self.tp

    def decode_flops(self, batch: int) -> float:
        return 2.0 * self.params * batch / self.tp

    def decode_hbm(self, batch: int) -> float:
        # weight-streaming bound + a token of KV per active request
        return self.param_bytes / self.tp + 2.0 * batch * self.d_model

    # collectives (exact ints; one activation row per active request)
    def ar_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.layers_per_op

    def a2a_bytes(self, batch: int) -> int:
        return int(batch) * self.d_model * 2 * self.ept


# ---------------------------------------------------------------------------
# Slot ledger: KV-cache capacity as pure, property-testable accounting
# ---------------------------------------------------------------------------

class SlotLedger:
    """KV-cache slots as schedulable capacity.  Pure bookkeeping (no
    engine dependency) so hypothesis can drive random admit/release
    interleavings against the invariants: occupancy never exceeds
    capacity, no uid is lost or double-completed, lowest free slot wins
    (deterministic placement)."""

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.free: typing.List[int] = list(range(capacity))
        self.active: typing.Dict[int, int] = {}      # slot -> uid
        self.seated: typing.Dict[int, int] = {}      # uid -> slot
        self.completed: set = set()
        self.peak = 0

    @property
    def in_use(self) -> int:
        return len(self.active)

    def has_free(self) -> bool:
        return bool(self.free)

    def admit(self, uid: int) -> int:
        if uid in self.seated:
            raise ValueError(f"uid {uid} already seated")
        if uid in self.completed:
            raise ValueError(f"uid {uid} already completed")
        if not self.free:
            raise RuntimeError("admit with no free slot")
        slot = self.free.pop(0)                       # lowest slot first
        self.active[slot] = uid
        self.seated[uid] = slot
        self.peak = max(self.peak, len(self.active))
        return slot

    def release(self, uid: int) -> int:
        if uid in self.completed:
            raise ValueError(f"uid {uid} double-completed")
        slot = self.seated.pop(uid, None)
        if slot is None:
            raise ValueError(f"uid {uid} not seated")
        del self.active[slot]
        self.completed.add(uid)
        bisect.insort(self.free, slot)
        return slot

    def evict(self, uid: int) -> int:
        """Reclaim a seat *without* retiring the uid: the request's KV
        state is lost (its mesh died mid-iteration) but the request is
        not done -- unlike :meth:`release` it may be admitted again
        later (the recovery requeue path)."""
        if uid in self.completed:
            raise ValueError(f"uid {uid} already completed")
        slot = self.seated.pop(uid, None)
        if slot is None:
            raise ValueError(f"uid {uid} not seated")
        del self.active[slot]
        bisect.insort(self.free, slot)
        return slot


class _ReqLog:
    """Mutable per-request timing record (all integer picoseconds, so
    queue + prefill + decode == end-to-end exactly, no float residue).
    ``retries`` counts recovery requeues (its work restarted from
    scratch -- KV is lost with the mesh); ``dropped_ps`` stamps the SLO
    drop when ``max_retries`` is exceeded."""
    __slots__ = ("uid", "arrival_ps", "prompt_len", "decode_len",
                 "admit_ps", "first_ps", "done_ps", "remaining",
                 "retries", "dropped_ps")

    def __init__(self, req: ServeRequest) -> None:
        self.uid = req.uid
        self.arrival_ps = req.arrival_ps
        self.prompt_len = req.prompt_len
        self.decode_len = req.decode_len
        self.admit_ps = None
        self.first_ps = None
        self.done_ps = None
        self.remaining = req.decode_len
        self.retries = 0
        self.dropped_ps = None

    def __getstate__(self):
        return {s: getattr(self, s) for s in self.__slots__}

    def __setstate__(self, state):
        for k, v in state.items():
            setattr(self, k, v)


# ---------------------------------------------------------------------------
# Components: per-chip serving program + per-tenant batching server
# ---------------------------------------------------------------------------

class ServeProgram(Component):
    """One chip's slice of a tenant: executes the iteration's op list
    (prefill/decode compute on its TensorCore, collective joins through
    the coordinator star) and reports phase completion to its tenant
    server.  Mirrors DeviceProgram's issue/wait loop, but the "trace" is
    re-sent every iteration by the server (DP-3: only connections carry
    cross-component traffic)."""

    def __init__(self, name: str, device: int,
                 group: typing.Tuple[int, ...]) -> None:
        super().__init__(name)
        self.device = device
        self.group = tuple(group)      # current serving mesh (re-formed
                                       # by each phase under recovery)
        self.ops: tuple = ()
        self.pc = 0
        self.iter_id = -1
        self.phases_done = 0

    def start(self) -> None:
        self.schedule("hello")

    def handle(self, event: Event) -> None:
        if event.kind == "hello":
            self._register()
            return
        if event.kind == "fault_wake":
            # The FaultInjector's scheduled wake.  A "fail" froze this
            # program before handle ran; reaching here means the action
            # just applied was a recover -- drop any pre-failure phase
            # state and announce ourselves again (rolling-restart
            # rejoin: the server re-admits the device into its mesh).
            self.ops = ()
            self.pc = 0
            self._register()
            return
        if event.kind != "request":
            return
        req = event.payload
        if req.kind == "phase":
            self.iter_id, self.ops, self.group = req.payload
            self.pc = 0
            self._issue()
        elif req.kind == "compute_done":
            if req.payload != (self.iter_id, self.pc):
                return      # job from an aborted iteration; core time
                            # was burned but the phase moved on
            self.pc += 1
            self._issue()
        elif req.kind == "collective_done":
            if not self._expects_coll(req.payload):
                return      # completion of a pre-abort collective
            self.pc += 1
            self._issue()
        elif req.kind == "collective_timeout":
            if not self._expects_coll(req.payload):
                return      # a pre-abort collective timing out late
            self.ops = ()
            self.pc = 0
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=None, kind="phase_failed",
                payload=self.iter_id))
        elif req.kind == "ping":
            # Heartbeat probe: answer immediately.  A failed program
            # never reaches here -- the engine drops its events -- so a
            # missing pong is exactly the liveness signal.
            health = self.ports.get("health")
            if health is not None and health.connection is not None:
                health.send(Request(
                    src=health, dst=None, kind="pong",
                    payload=(self.device, req.payload)))

    def _register(self) -> None:
        # Register with the tenant server (spoke->hub auto-routes); the
        # reference rides the payload like coordinator joins do,
        # surviving the procs executor as a rank.  With a HealthMonitor
        # wired, also enlist with the failure detector.
        self.port("ctrl").send(Request(
            src=self.port("ctrl"), dst=None, kind="register",
            payload=(self.device, self)))
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            health.send(Request(
                src=health, dst=None, kind="register_chip",
                payload=(self.device, self)))

    def _expects_coll(self, key) -> bool:
        """Is this coordinator notification for the collective the
        current op list is waiting on?  Collective names embed the
        server's monotone iteration id, so any notification for an
        aborted iteration's ops mismatches."""
        if self.pc >= len(self.ops):
            return False
        op = self.ops[self.pc]
        return op[0] == "coll" and key is not None and key[0] == op[1]

    def _issue(self) -> None:
        if self.pc >= len(self.ops):
            self.phases_done += 1
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=None, kind="phase_done",
                payload=self.iter_id))
            return
        op = self.ops[self.pc]
        if op[0] == "compute":
            _, tag, flops, hbm_bytes = op
            self.port("core").send(Request(
                src=self.port("core"), dst=None, kind="job",
                payload=ComputeJob(flops=flops, hbm_bytes=hbm_bytes,
                                   tag=tag, reply_to=self,
                                   token=(self.iter_id, self.pc))))
        else:  # ("coll", name, kind, nbytes)
            _, name, kind, nbytes = op
            self.port("coll").send(Request(
                src=self.port("coll"), dst=None, kind="join",
                size_bytes=int(nbytes),
                payload=(name, 0, kind, float(nbytes), self.group,
                         self.device, self)))


class HealthMonitor(Component):
    """Failure detector for the serving pod, fed by two signals:

    * **collective timeouts** from the coordinator (``timeout_report``
      carries the key and the joined roster): members missing from a
      timed-out group are *suspects* -- each gets a strike plus a
      targeted probe, and dies on a missed probe or on reaching
      ``suspect_threshold`` strikes (a chip whose control plane answers
      while its compute is wedged);
    * optional **heartbeats**: every ``heartbeat_s`` the monitor judges
      the previous round's pongs (a silent chip is declared dead) and
      pings the live, un-quiesced ones -- this catches deaths that no
      collective would ever surface (single-chip tenants, idle meshes).

    Verdicts go to the owning :class:`TenantServer` as ``chip_dead``
    requests (or ``coll_failed`` when a fully-joined collective died in
    the fabric -- nobody to fence, the server just retries).  Everything
    is ordinary events on a control star, so detection latency is
    simulated and the whole protocol stays bit-identical across
    schedulers and executors.  Servers send ``quiesce`` once their trace
    is fully resolved; the probe loop stops when no live, un-quiesced
    chip remains, bounding the event horizon."""

    def __init__(self, name: str,
                 tenants: typing.Tuple[typing.Tuple[int, typing.Tuple[int, ...]], ...],
                 policy: RecoveryPolicy) -> None:
        super().__init__(name)
        self.policy = policy
        self.tenant_of = {d: tid for tid, devs in tenants for d in devs}
        self.expect_chips = sum(len(devs) for _, devs in tenants)
        self.expect_servers = len(tenants)
        self.chips: typing.Dict[int, object] = {}      # device -> program
        self.servers: typing.Dict[int, object] = {}    # tenant id -> server
        self.dead: set = set()
        self.deaths = 0                                # monotone (rejoins
                                                       # shrink ``dead``)
        self.strikes: typing.Dict[int, int] = {}
        self.last_ack: typing.Dict[int, int] = {}      # device -> probe seq
        self.seq = 0
        self.quiesced: set = set()                     # tenant ids drained
        self._probing = False

    def handle(self, event: Event) -> None:
        if event.kind == "probe":
            self._probe()
        elif event.kind == "verdict":
            device, seq = event.payload
            if device not in self.dead and self.last_ack.get(device, -1) < seq:
                self._declare_dead(device)   # targeted probe unanswered
        elif event.kind == "request":
            req = event.payload
            if req.kind == "register_chip":
                device, prog = req.payload
                self.chips[device] = prog
                self.dead.discard(device)    # rolling-restart rejoin
                self.strikes.pop(device, None)
                self.last_ack[device] = self.seq   # fresh: skip this round
                self._maybe_start()
            elif req.kind == "register_server":
                tid, server = req.payload
                self.servers[tid] = server
                self._maybe_start()
            elif req.kind == "pong":
                device, seq = req.payload
                if self.last_ack.get(device, -1) < seq:
                    self.last_ack[device] = seq
            elif req.kind == "timeout_report":
                key, joined = req.payload
                self._on_timeout(key, joined)
            elif req.kind == "quiesce":
                self.quiesced.add(req.payload)

    # -- heartbeat loop ----------------------------------------------------
    def _maybe_start(self) -> None:
        if (self._probing or not self.policy.heartbeat_s
                or len(self.chips) < self.expect_chips
                or len(self.servers) < self.expect_servers):
            return
        self._probing = True
        self.schedule("probe", s_to_ps(self.policy.heartbeat_s))

    def _live_targets(self) -> list:
        return [d for d in sorted(self.chips)
                if d not in self.dead
                and self.tenant_of[d] not in self.quiesced]

    def _probe(self) -> None:
        targets = self._live_targets()
        if not targets:
            # every tenant drained (or fully dead): stop the loop.  A
            # later register_chip restarts it via _maybe_start.
            self._probing = False
            return
        for device in targets:             # judge the previous round
            if self.last_ack.get(device, -1) < self.seq:
                self._declare_dead(device)
        self.seq += 1
        for device in self._live_targets():
            hub = self.port("hub")
            hub.send(Request(src=hub, dst=self.chips[device], kind="ping",
                             payload=self.seq))
        self.schedule("probe", s_to_ps(self.policy.heartbeat_s))

    # -- collective-timeout path -------------------------------------------
    def _on_timeout(self, key, joined) -> None:
        group = key[2]
        joined_set = set(joined)
        suspects = [d for d in group
                    if d not in joined_set and d not in self.dead]
        if not suspects:
            # Fully joined but the transfer never completed: a fabric
            # stall, not a chip death.  Nobody to fence; the owning
            # server aborts and retries through backoff.
            tid = self.tenant_of.get(group[0])
            server = self.servers.get(tid)
            if server is not None:
                hub = self.port("hub")
                hub.send(Request(src=hub, dst=server, kind="coll_failed",
                                 payload=key))
            return
        for device in suspects:
            strikes = self.strikes.get(device, 0) + 1
            self.strikes[device] = strikes
            if strikes >= self.policy.suspect_threshold:
                self._declare_dead(device)
            else:
                # Guilty unless it answers a targeted probe in time.
                self.seq += 1
                hub = self.port("hub")
                hub.send(Request(src=hub, dst=self.chips[device],
                                 kind="ping", payload=self.seq))
                self.schedule("verdict",
                              s_to_ps(self.policy.probe_timeout_s),
                              payload=(device, self.seq))

    def _declare_dead(self, device: int) -> None:
        if device in self.dead:
            return
        self.dead.add(device)
        self.deaths += 1
        self.strikes.pop(device, None)
        server = self.servers.get(self.tenant_of.get(device))
        if server is not None:
            hub = self.port("hub")
            hub.send(Request(src=hub, dst=server, kind="chip_dead",
                             payload=device))


class TenantServer(Component):
    """Per-tenant continuous-batching scheduler (the Orca loop as
    simulator events).  Each iteration: admit queued requests into free
    KV slots, broadcast one op list (new prefills + one batched decode +
    its collectives) to every member chip, wait for all phase_done
    replies, then retire finished requests and start the next iteration.
    Open loop: arrivals are pre-scheduled self-events from the trace and
    never wait on completions.

    With a :class:`RecoveryPolicy` the server also *serves through*
    faults: a ``chip_dead`` verdict (or a ``phase_failed`` from its own
    chips) aborts the in-flight iteration, evicts every seated request
    (their KV shards died with the mesh), requeues each with exponential
    backoff -- or drops it past ``max_retries`` -- and re-forms the
    serving group from the surviving members (elastic re-mesh: the next
    phase simply names the smaller group and re-sized per-chip ops).  A
    dead device registering again rejoins the mesh; seated requests are
    resharded (evicted + immediately requeued, no retry penalty) before
    the first iteration on the grown group."""

    def __init__(self, name: str, tenant: TenantSpec, tid: int = 0,
                 policy: RecoveryPolicy = None) -> None:
        super().__init__(name)
        self.tenant = tenant
        self.tid = tid
        self.policy = policy
        self.sizing = ServeSizing(tenant)
        self._sizings: typing.Dict[int, ServeSizing] = {
            len(tenant.devices): self.sizing}
        self.ledger = SlotLedger(tenant.slots)
        self.members: typing.Dict[int, object] = {}    # device -> program
        self.queue: typing.List[int] = []              # waiting uids (FIFO)
        self.recs: typing.Dict[int, _ReqLog] = {
            r.uid: _ReqLog(r) for r in tenant.requests}
        self.completed_order: typing.List[int] = []
        self.iter_id = -1
        self.iterations = 0
        self._phase_replies = 0
        self._newly: typing.List[int] = []
        # -- recovery state -------------------------------------------------
        self.dead: set = set()               # fenced devices
        self.retries = 0                     # recovery requeues issued
        self.drops: typing.List[int] = []    # uids dropped past max_retries
        self.recoveries = 0                  # outage windows closed
        self.rejoins = 0                     # dead devices re-registered
        self.outages: typing.List[typing.Tuple[int, int]] = []
        self._outage_start: typing.Optional[int] = None
        self._serving_group: tuple = ()      # mesh the seated KV lives on
        self._resolved = 0                   # done + dropped requests
        self._quiesced = False

    def start(self) -> None:
        for r in self.tenant.requests:
            self.schedule("arrival", r.arrival_ps, payload=r.uid)
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            health.send(Request(
                src=health, dst=None, kind="register_server",
                payload=(self.tid, self)))
        self._maybe_quiesce()   # a tenant with an empty trace is done

    def handle(self, event: Event) -> None:
        if event.kind == "arrival":
            self.queue.append(event.payload)
            self._maybe_iterate()
        elif event.kind == "requeue":
            uid = event.payload
            rec = self.recs[uid]
            if (rec.done_ps is None and rec.dropped_ps is None
                    and uid not in self.ledger.seated):
                self.queue.append(uid)
            self._maybe_iterate()
        elif event.kind == "request":
            req = event.payload
            if req.kind == "register":
                device, prog = req.payload
                if device in self.dead:          # rolling-restart rejoin
                    self.dead.discard(device)
                    self.rejoins += 1
                self.members[device] = prog
                self._maybe_iterate()
            elif req.kind == "phase_done":
                if req.payload != self.iter_id or not self._phase_replies:
                    return                       # reply from an aborted phase
                self._phase_replies -= 1
                if self._phase_replies == 0:
                    self._finish_iteration()
            elif req.kind == "phase_failed":
                if (self.policy is None or req.payload != self.iter_id
                        or not self._phase_replies):
                    return
                self._abort_iteration()
            elif req.kind == "coll_failed":
                # fully-joined collective died in the fabric: retry
                if self.policy is not None and self._phase_replies:
                    self._abort_iteration()
            elif req.kind == "chip_dead":
                self._on_chip_dead(req.payload)

    # -- recovery ----------------------------------------------------------
    def _on_chip_dead(self, device: int) -> None:
        if self.policy is None or device in self.dead:
            return
        self.dead.add(device)
        self.members.pop(device, None)
        if self._phase_replies or self.ledger.in_use:
            # in-flight iteration and/or seated KV sharded over a mesh
            # that just lost a member: abort, reclaim, requeue
            self._abort_iteration()
        else:
            self._maybe_iterate()

    def _abort_iteration(self) -> None:
        now = self.engine.now
        if self._outage_start is None:
            self._outage_start = now
        self._phase_replies = 0
        self._newly = []
        for uid in sorted(self.ledger.seated):
            self.ledger.evict(uid)
            rec = self.recs[uid]
            rec.admit_ps = None
            rec.first_ps = None
            rec.remaining = rec.decode_len       # KV lost: restart
            rec.retries += 1
            if rec.retries > self.policy.max_retries:
                rec.dropped_ps = now             # SLO drop
                self.drops.append(uid)
                self._resolved += 1
            else:
                self.retries += 1
                delay = s_to_ps(self.policy.backoff_base_s
                                * (2 ** (rec.retries - 1)))
                self.schedule("requeue", delay, payload=uid)
        self._maybe_iterate()
        self._maybe_quiesce()

    def _reshard(self, group: tuple) -> None:
        """Membership changed under seated requests (a rejoin): their KV
        shards live on the old mesh, so evict and requeue them ahead of
        the FIFO queue -- no retry penalty, the reshard is planned."""
        front = []
        for uid in sorted(self.ledger.seated):
            self.ledger.evict(uid)
            rec = self.recs[uid]
            rec.admit_ps = None
            rec.first_ps = None
            rec.remaining = rec.decode_len
            front.append(uid)
        self.queue[:0] = front

    def _maybe_quiesce(self) -> None:
        if self._quiesced or self._resolved < len(self.recs):
            return
        health = self.ports.get("health")
        if health is not None and health.connection is not None:
            self._quiesced = True
            health.send(Request(
                src=health, dst=None, kind="quiesce", payload=self.tid))

    def _sizing_for(self, n: int) -> ServeSizing:
        s = self._sizings.get(n)
        if s is None:
            s = self._sizings[n] = ServeSizing(self.tenant, tp=n)
        return s

    # -- the Orca iteration ------------------------------------------------
    def _maybe_iterate(self) -> None:
        if self._phase_replies:                  # iteration in flight
            return
        expected = len(self.tenant.devices) - len(self.dead)
        if len(self.members) < expected or not self.members:
            return              # chips still registering, or all fenced
        group = tuple(sorted(self.members))
        if self.ledger.in_use and group != self._serving_group:
            self._reshard(group)
        admitted = []
        while self.queue and self.ledger.has_free():
            uid = self.queue.pop(0)
            self.ledger.admit(uid)
            rec = self.recs[uid]
            rec.admit_ps = self.engine.now
            admitted.append(uid)
        self._serving_group = group
        if not self.ledger.in_use:
            return                               # idle until next arrival
        self.iter_id += 1
        self.iterations += 1
        self._newly = admitted
        ops = self._build_ops(admitted, group)
        self._phase_replies = len(group)
        for d in group:
            self.port("ctrl").send(Request(
                src=self.port("ctrl"), dst=self.members[d], kind="phase",
                payload=(self.iter_id, ops, group)))

    def _build_ops(self, admitted: typing.List[int], group: tuple) -> tuple:
        s = self._sizing_for(len(group))
        it = self.iter_id
        ops = []
        for uid in admitted:
            rec = self.recs[uid]
            ops.append(("compute", f"{self.name}.i{it}.prefill{uid}",
                        s.prefill_flops(rec.prompt_len),
                        s.prefill_hbm(rec.prompt_len)))
        batch = self.ledger.in_use
        ops.append(("compute", f"{self.name}.i{it}.decode",
                    s.decode_flops(batch), s.decode_hbm(batch)))
        if len(group) > 1:
            for k in range(s.coll_ops):
                ops.append(("coll", f"{self.name}.i{it}.ar{k}",
                            "all-reduce", s.ar_bytes(batch)))
            if s.moe:
                # MoE dispatch + combine: two a2a per iteration
                ops.append(("coll", f"{self.name}.i{it}.a2a0",
                            "all-to-all", s.a2a_bytes(batch)))
                ops.append(("coll", f"{self.name}.i{it}.a2a1",
                            "all-to-all", s.a2a_bytes(batch)))
        return tuple(ops)

    def _finish_iteration(self) -> None:
        now = self.engine.now
        for uid in self._newly:
            self.recs[uid].first_ps = now        # first token this iteration
        self._newly = []
        for slot, uid in sorted(self.ledger.active.items()):
            rec = self.recs[uid]
            rec.remaining -= 1
            if rec.remaining <= 0:               # pre-drawn eos reached
                rec.done_ps = now
                self.ledger.release(uid)
                self.completed_order.append(uid)
                self._resolved += 1
        if self._outage_start is not None:
            # a completed iteration on the re-formed mesh closes the
            # outage window -- the tenant is serving again
            self.outages.append((self._outage_start, now))
            self._outage_start = None
            self.recoveries += 1
        self._maybe_iterate()
        self._maybe_quiesce()


# ---------------------------------------------------------------------------
# System assembly
# ---------------------------------------------------------------------------

class ServingSystem:
    """A machine wired for serving: shared coordinator + fabric, plus per
    tenant a :class:`TenantServer` on its own control star and per device
    a fresh TensorCore/HbmController/ServeProgram triple.  Chips are
    wired exactly like :class:`repro.core.system.System` (2-endpoint
    buses so request auto-routing holds); tenants share the fabric, which
    is where multi-tenant link contention comes from."""

    def __init__(self, scenario: ServingScenario, spec: SystemSpec,
                 scheduler=None, max_workers: int = 4, fabric=None,
                 executor=None, deadline_s: float = None,
                 recovery: RecoveryPolicy = None) -> None:
        from ..fabric import make_fabric   # late: fabric imports core modules
        seen: set = set()
        for t in scenario.tenants:
            if not t.devices:
                raise ValueError(f"tenant {t.name!r} has no devices")
            for d in t.devices:
                if not 0 <= d < spec.total_chips:
                    raise ValueError(
                        f"tenant {t.name!r} device {d} outside "
                        f"topology with {spec.total_chips} chips")
                if d in seen:
                    raise ValueError(
                        f"device {d} assigned to two tenants; tenant "
                        f"placements must be disjoint")
                seen.add(d)
        self.scenario = scenario
        self.spec = spec
        self.policy = recovery
        self.engine = Engine(scheduler=scheduler, max_workers=max_workers,
                             executor=executor)
        self.fabric = make_fabric(fabric or spec.fabric, spec)
        self.coordinator = self.engine.register(
            CollectiveCoordinator("coordinator", deadline_s=deadline_s))
        self.fabric.install(self.engine, self.coordinator)
        coll_conn = self.engine.register(
            StarConnection("coll_fabric", self.coordinator.port("coll"),
                           latency_s=spec.ctrl_latency_s))
        self.monitor: typing.Optional[HealthMonitor] = None
        health_conn = None
        if recovery is not None:
            # Failure detector on its own control star; the coordinator
            # reports collective timeouts into it (key + joined roster).
            self.monitor = self.engine.register(HealthMonitor(
                "health.monitor",
                tenants=tuple((tid, t.devices)
                              for tid, t in enumerate(scenario.tenants)),
                policy=recovery))
            health_conn = self.engine.register(
                StarConnection("health.star", self.monitor.port("hub"),
                               latency_s=spec.ctrl_latency_s))
            health_conn.plug(self.coordinator.port("health"))
        self.servers: typing.List[TenantServer] = []
        self.programs: typing.List[ServeProgram] = []
        self.cores: typing.List[TensorCore] = []
        self.hbms: typing.List[HbmController] = []
        for tid, tenant in enumerate(scenario.tenants):
            server = self.engine.register(
                TenantServer(f"tenant{tid}.server", tenant, tid=tid,
                             policy=recovery))
            ctrl = self.engine.register(
                StarConnection(f"tenant{tid}.ctrl", server.port("ctrl"),
                               latency_s=spec.ctrl_latency_s))
            if health_conn is not None:
                health_conn.plug(server.port("health"))
            for d in tenant.devices:
                core = self.engine.register(
                    TensorCore(f"chip{d}.core", spec.chip))
                hbm = self.engine.register(
                    HbmController(f"chip{d}.hbm", spec.chip))
                prog = self.engine.register(
                    ServeProgram(f"chip{d}.prog", d, tenant.devices))
                self.engine.register(Connection(f"chip{d}.bus")).plug(
                    prog.port("core")).plug(core.port("prog"))
                self.engine.register(Connection(f"chip{d}.membus")).plug(
                    core.port("hbm")).plug(hbm.port("cpu"))
                coll_conn.plug(prog.port("coll"))
                ctrl.plug(prog.port("ctrl"))
                if health_conn is not None:
                    health_conn.plug(prog.port("health"))
                self.programs.append(prog)
                self.cores.append(core)
                self.hbms.append(hbm)
            self.servers.append(server)
            # Advance notice of every collective this tenant can issue
            # (batch sizes 1..slots): the event fabric refines bounded-lag
            # edges from these exact (kind, bytes, group) triples, and its
            # strict-window guard fails loudly on an un-noted collective.
            if len(tenant.devices) > 1:
                s = ServeSizing(tenant)
                for b in range(1, tenant.slots + 1):
                    self.fabric.note_plan("all-reduce", float(s.ar_bytes(b)),
                                          tuple(tenant.devices))
                    if s.moe:
                        self.fabric.note_plan("all-to-all",
                                              float(s.a2a_bytes(b)),
                                              tuple(tenant.devices))

    def note_failover_plans(self, candidates: typing.Iterable[int]) -> None:
        """Note the collective plans of every *degraded* group a recovery
        could re-mesh to: for each tenant, its device group minus every
        non-empty subset of ``candidates`` (the chips the fault plan can
        kill).  Plans are consumed at run start -- the bounded scheduler
        derives its strict-window edges from them -- so every group that
        might form mid-run must be noted before ``engine.run()``.
        Collective payloads are activation rows (tp-independent), so the
        noted bytes match the degraded iterations bit-for-bit."""
        import itertools
        for tenant in self.scenario.tenants:
            cand = sorted(set(tenant.devices) & set(candidates))
            for r in range(1, len(cand) + 1):
                for gone in itertools.combinations(cand, r):
                    group = tuple(d for d in tenant.devices
                                  if d not in gone)
                    if len(group) < 2:
                        continue
                    s = ServeSizing(tenant, tp=len(group))
                    for b in range(1, tenant.slots + 1):
                        self.fabric.note_plan("all-reduce",
                                              float(s.ar_bytes(b)), group)
                        if s.moe:
                            self.fabric.note_plan("all-to-all",
                                                  float(s.a2a_bytes(b)),
                                                  group)

    def run(self, until_s: float = None) -> int:
        for prog in self.programs:
            prog.start()
        for server in self.servers:
            server.start()
        return self.engine.run(s_to_ps(until_s) if until_s else None)


# ---------------------------------------------------------------------------
# Report + driver
# ---------------------------------------------------------------------------

def _pctile_ps(values_ps: typing.List[int], q: float) -> float:
    """Nearest-rank percentile in seconds (deterministic, no interpolation)."""
    if not values_ps:
        return 0.0
    v = sorted(values_ps)
    k = max(0, math.ceil(q / 100.0 * len(v)) - 1)
    return ps_to_s(v[k])


@dataclasses.dataclass
class ServeReport:
    """One serving run.  ``summary()`` excludes execution artifacts so it
    is bit-identical across schedulers and executors, same as SimReport."""
    time_s: float                  # makespan (last event)
    events: int
    devices: int
    tenants: int
    offered: int                   # requests in the arrival traces
    completed: int
    in_flight: int                 # admitted but unfinished at horizon
    queued: int                    # never admitted by the horizon
    offered_rps: float
    goodput_rps: float             # completed / makespan
    p50_s: float
    p99_s: float
    mean_s: float
    max_s: float
    queue_mean_s: float            # arrival -> admission
    prefill_mean_s: float          # admission -> first token
    decode_mean_s: float           # first token -> completion
    iterations: int
    peak_slots: typing.List[int]   # per tenant
    collectives_completed: int
    compute_busy_s: float
    compute_util: float
    link_report: dict
    fabric: str = "analytic"
    link_utilization: dict = dataclasses.field(default_factory=dict)
    # per-tenant SLO view: a fault on one tenant's links must show up in
    # that tenant's tail even when another tenant owns the global max
    tenant_p50_s: typing.List[float] = dataclasses.field(default_factory=list)
    tenant_p99_s: typing.List[float] = dataclasses.field(default_factory=list)
    per_request: list = dataclasses.field(default_factory=list)
    # -- graceful degradation (recovery layer; zeros without a policy) ----
    collective_timeouts: int = 0
    retries: int = 0               # recovery requeues across tenants
    dropped: int = 0               # requests dropped past max_retries
    recoveries: int = 0            # outage windows closed by a completion
    rejoins: int = 0               # dead chips that re-registered
    chip_deaths: int = 0           # HealthMonitor verdicts (monotone)
    tenant_outage_s: typing.List[float] = dataclasses.field(
        default_factory=list)
    tenant_availability: typing.List[float] = dataclasses.field(
        default_factory=list)
    outage_windows: typing.List[list] = dataclasses.field(
        default_factory=list)     # per tenant: [start_s, end_s] pairs
    goodput_in_outage_rps: float = 0.0    # completions per tenant-second
    goodput_outside_outage_rps: float = 0.0
    scheduler: str = "serial"
    executor: str = "none"

    _EXECUTION_FIELDS = ("scheduler", "executor")

    def summary(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in self._EXECUTION_FIELDS}


def resolve_recovery(recovery, deadline_s: float = None):
    """Resolve the ``recovery`` argument of :func:`run_serving`:
    ``None`` enables a default :class:`RecoveryPolicy` iff ``deadline_s``
    is set (detection without recovery must be asked for explicitly with
    ``recovery=False``); ``True`` enables defaults; ``False`` disables;
    a :class:`RecoveryPolicy` instance is used as-is."""
    if recovery is False:
        return None
    if recovery is True:
        return RecoveryPolicy()
    if recovery is None:
        return RecoveryPolicy() if deadline_s else None
    return recovery


def _fault_candidates(faults: dict) -> set:
    """Chip indices a fault plan can plausibly remove from a mesh (any
    chipN.* target -- even a straggler can be fenced by strike count)."""
    out = set()
    for name in faults or ():
        if name.startswith("chip"):
            head = name[4:].split(".", 1)[0]
            if head.isdigit():
                out.add(int(head))
    return out


def run_serving(scenario: ServingScenario, spec: SystemSpec = None,
                scheduler: str = None, max_workers: int = 4,
                fabric: str = None, executor: str = None,
                faults: dict = None, until_s: float = None,
                deadline_s: float = None,
                recovery=None) -> ServeReport:
    """Run one open-loop serving scenario and report the latency curve
    inputs.  Mirrors :func:`repro.core.simulate.simulate`'s fault-plan
    handling: same grammar, same validation, ``fabric.*`` targets need
    the event fabric.

    ``deadline_s`` threads through to the shared
    :class:`~repro.core.system.CollectiveCoordinator`: a collective that
    has not completed within the deadline of its first join times out
    (the failure-detection signal).  ``recovery`` selects the policy
    (see :func:`resolve_recovery`); with one, a :class:`HealthMonitor`
    turns timeouts + heartbeats into ``chip_dead`` verdicts and tenants
    serve *through* the fault (see docs/faults.md, Detection & recovery).
    """
    spec = spec or SystemSpec()
    policy = resolve_recovery(recovery, deadline_s)
    system = ServingSystem(scenario, spec, scheduler=scheduler,
                           max_workers=max_workers, fabric=fabric,
                           executor=executor, deadline_s=deadline_s,
                           recovery=policy)
    metrics = MetricsHook()
    system.engine.accept_hook(metrics)   # engine-level only (no fusing)
    if faults:
        plan = {name: [(s_to_ps(t), a,
                        s_to_ps(arg) if a == "transient" else arg)
                       for (t, a, arg) in acts]
                for name, acts in faults.items()}
        targets = (system.cores + system.programs + system.servers
                   + system.fabric.fault_targets())
        unknown = set(plan) - {c.name for c in targets}
        if unknown:
            raise ValueError(
                f"fault plan targets unknown components "
                f"{sorted(unknown)}; serving targets are chipN.core / "
                f"chipN.prog / tenantN.server, and fabric.* link/DMA "
                f"targets require fabric='event' (this run uses "
                f"{system.fabric.name!r})")
        inj = FaultInjector(plan)
        for comp in targets:
            comp.accept_hook(inj)
        inj.arm(targets)   # actions apply on schedule even on idle targets
        if policy is not None:
            system.note_failover_plans(_fault_candidates(faults))

    end_ps = system.run(until_s=until_s)
    time_s = ps_to_s(end_ps)

    per_request = []
    e2e, queue_t, prefill_t, decode_t = [], [], [], []
    tenant_e2e: typing.List[list] = [[] for _ in system.servers]
    offered = completed = in_flight = queued = dropped = 0
    for tid, server in enumerate(system.servers):
        for uid in sorted(server.recs):
            rec = server.recs[uid]
            offered += 1
            if rec.dropped_ps is not None:
                dropped += 1
                continue
            if rec.done_ps is None:
                if rec.admit_ps is None:
                    queued += 1
                else:
                    in_flight += 1
                continue
            completed += 1
            q = rec.admit_ps - rec.arrival_ps
            p = rec.first_ps - rec.admit_ps
            d = rec.done_ps - rec.first_ps
            e2e.append(rec.done_ps - rec.arrival_ps)
            tenant_e2e[tid].append(rec.done_ps - rec.arrival_ps)
            queue_t.append(q)
            prefill_t.append(p)
            decode_t.append(d)
            per_request.append({
                "tenant": tid, "uid": uid,
                "arrival_s": ps_to_s(rec.arrival_ps),
                "queue_s": ps_to_s(q), "prefill_s": ps_to_s(p),
                "decode_s": ps_to_s(d),
                "e2e_s": ps_to_s(rec.done_ps - rec.arrival_ps),
                "prompt_len": rec.prompt_len,
                "decode_len": rec.decode_len,
            })

    busy = max((metrics.busy_ps[c.name] for c in system.cores), default=0)
    span_s = max((float(r.arrival_ps) for t in scenario.tenants
                  for r in t.requests), default=0.0) / 1e12

    # Availability accounting: an outage window opens at an abort and
    # closes at the next completed iteration (still open at the end of
    # serving counts in full).  The serving span is per tenant, last
    # request stamp (done / dropped / arrival) -- trailing deadline
    # no-op events must not dilute availability.
    tenant_outage_s, tenant_avail, outage_windows = [], [], []
    in_out_done = out_done = 0
    in_out_span_ps = out_span_ps = 0
    for server in system.servers:
        span_ps = max((max(rec.done_ps or 0, rec.dropped_ps or 0,
                           rec.arrival_ps)
                       for rec in server.recs.values()), default=0)
        windows = list(server.outages)
        if server._outage_start is not None:
            windows.append((server._outage_start, max(span_ps,
                                                      server._outage_start)))
        outage_ps = sum(e - s for s, e in windows)
        tenant_outage_s.append(ps_to_s(outage_ps))
        tenant_avail.append(1.0 - outage_ps / span_ps if span_ps else 1.0)
        outage_windows.append([[ps_to_s(s), ps_to_s(e)] for s, e in windows])
        in_out_span_ps += outage_ps
        out_span_ps += span_ps - outage_ps
        for rec in server.recs.values():
            if rec.done_ps is None:
                continue
            # half-open [start, end): the completion that closes an
            # outage window is the restore moment, counted outside
            if any(s <= rec.done_ps < e for s, e in windows):
                in_out_done += 1
            else:
                out_done += 1

    return ServeReport(
        time_s=time_s,
        events=system.engine.events_processed,
        devices=len(system.programs),
        tenants=len(system.servers),
        offered=offered,
        completed=completed,
        in_flight=in_flight,
        queued=queued,
        offered_rps=offered / span_s if span_s else 0.0,
        goodput_rps=completed / time_s if time_s else 0.0,
        p50_s=_pctile_ps(e2e, 50.0),
        p99_s=_pctile_ps(e2e, 99.0),
        mean_s=ps_to_s(int(sum(e2e) / len(e2e))) if e2e else 0.0,
        max_s=ps_to_s(max(e2e)) if e2e else 0.0,
        queue_mean_s=ps_to_s(int(sum(queue_t) / len(queue_t))) if queue_t else 0.0,
        prefill_mean_s=ps_to_s(int(sum(prefill_t) / len(prefill_t))) if prefill_t else 0.0,
        decode_mean_s=ps_to_s(int(sum(decode_t) / len(decode_t))) if decode_t else 0.0,
        iterations=sum(s.iterations for s in system.servers),
        peak_slots=[s.ledger.peak for s in system.servers],
        tenant_p50_s=[_pctile_ps(v, 50.0) for v in tenant_e2e],
        tenant_p99_s=[_pctile_ps(v, 99.0) for v in tenant_e2e],
        collectives_completed=system.coordinator.completed,
        compute_busy_s=busy / 1e12,
        compute_util=(busy / 1e12) / time_s if time_s else 0.0,
        link_report=system.fabric.link_report(),
        fabric=system.fabric.name,
        link_utilization=system.fabric.link_utilization(end_ps or None),
        per_request=per_request,
        collective_timeouts=len(system.coordinator.timed_out),
        retries=sum(s.retries for s in system.servers),
        dropped=dropped,
        recoveries=sum(s.recoveries for s in system.servers),
        rejoins=sum(s.rejoins for s in system.servers),
        chip_deaths=system.monitor.deaths if system.monitor else 0,
        tenant_outage_s=tenant_outage_s,
        tenant_availability=tenant_avail,
        outage_windows=outage_windows,
        goodput_in_outage_rps=(in_out_done / ps_to_s(in_out_span_ps)
                               if in_out_span_ps else 0.0),
        goodput_outside_outage_rps=(out_done / ps_to_s(out_span_ps)
                                    if out_span_ps else 0.0),
        scheduler=system.engine.scheduler.name,
        executor=(system.engine.scheduler.executor.name
                  if getattr(system.engine.scheduler, "executor", None)
                  is not None else "none"),
    )


# ---------------------------------------------------------------------------
# Scenario builders (sweepable: return None when the topology can't host)
# ---------------------------------------------------------------------------

def _dense_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-dense", family="dense",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000)


def _moe_model(d_model: int = 1024, layers: int = 8) -> ModelConfig:
    return ModelConfig(name="serve-moe", family="moe",
                       num_layers=layers, d_model=d_model,
                       num_heads=max(1, d_model // 128), d_ff=4 * d_model,
                       vocab_size=32000, num_experts=8, experts_per_token=2)


def build_scenario(spec: SystemSpec, name: str = "serving",
                   arrival: str = "poisson", rate_rps: float = 500.0,
                   duration_s: float = 0.02, seed: int = 0,
                   tenants: int = 2, slots: int = 4,
                   prompt_range: typing.Tuple[int, int] = (16, 64),
                   decode_range: typing.Tuple[int, int] = (4, 12),
                   moe: bool = False,
                   model: ModelConfig = None) -> typing.Optional[ServingScenario]:
    """Place ``tenants`` tenants on contiguous row-blocks of pod 0 and
    attach seeded open-loop traces.  Returns None when pod 0 hasn't a
    row per tenant (sweep grids skip the combo, same contract as the
    collective scenario builders in tools/sweep.py)."""
    if arrival not in GENERATORS:
        raise ValueError(f"unknown arrival generator {arrival!r}; "
                         f"have {sorted(GENERATORS)}")
    y, x = spec.pod_shape[0], spec.pod_shape[1]
    rows_per = y // tenants
    if rows_per < 1:
        return None
    model = model or (_moe_model() if moe else _dense_model())
    specs = []
    for tid in range(tenants):
        devices = tuple(range(tid * rows_per * x, (tid + 1) * rows_per * x))
        times = GENERATORS[arrival](rate_rps, duration_s,
                                    seed=seed * 1000 + tid)
        reqs = make_requests(times, seed=seed * 1000 + tid + 500,
                             prompt_range=prompt_range,
                             decode_range=decode_range)
        specs.append(TenantSpec(name=f"{name}.t{tid}", devices=devices,
                                model=model, slots=slots, requests=reqs))
    return ServingScenario(name=name, tenants=tuple(specs))
