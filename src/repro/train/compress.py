"""Gradient compression for the cross-pod (DCN) reduction.

int8 per-tensor-row quantization with **error feedback** (residual from
step N is added back at step N+1, making compression unbiased over time).
The pod-axis all-reduce then moves 4x fewer bytes over DCN — the slowest
fabric in the multi-pod mesh and the paper's "cross-GPU traffic is the
bottleneck" lesson applied at pod scale.

`compressed_psum` is exact about the wire format: int8 payload + one f32
scale per row, summed in int32 over the pod axis (so it is what a real
int8 DCN all-reduce would compute, not a float psum in disguise).
Used inside shard_map over the "pod" axis (see train/loop.py and
tests/test_compress.py).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp


def quantize(g, axis: int = -1):
    """g f32/bf16 -> (q int8, scale f32 per-row)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32), axis=axis, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g, error):
    """One leaf: returns (dequantized g_hat, new error residual)."""
    g32 = g.astype(jnp.float32) + error
    q, scale = quantize(g32)
    g_hat = dequantize(q, scale)
    return g_hat, g32 - g_hat


def compressed_psum(g, axis_name: str, error=None):
    """int8-on-the-wire psum over `axis_name` (call inside shard_map).

    Every participant quantizes with a *shared* scale (pmax of local
    scales — one tiny f32 pre-exchange), psums int32 counts, dequantizes.
    With error feedback the quantization residual re-enters next step.
    """
    g32 = g.astype(jnp.float32)
    if error is not None:
        g32 = g32 + error
    local_scale = jnp.max(jnp.abs(g32), axis=-1, keepdims=True) / 127.0
    scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-12), axis_name)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / \
        jax.lax.psum(1, axis_name)
    new_error = g32 - dequantize(q, scale)
    return mean, new_error


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes(tree) -> typing.Tuple[int, int]:
    """(compressed, uncompressed) DCN bytes per all-reduce of this tree."""
    comp = unc = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        rows = n // (leaf.shape[-1] if leaf.ndim else 1)
        comp += n + 4 * max(1, rows)        # int8 payload + f32 row scales
        unc += n * 4
    return comp, unc
