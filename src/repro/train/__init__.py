from . import checkpoint, compress, data, loop, optim
from .optim import OptConfig, init_state, adamw_update
from .checkpoint import CheckpointManager
from .data import DataConfig, SyntheticLM, make_batch

__all__ = ["checkpoint", "compress", "data", "loop", "optim",
           "OptConfig", "init_state", "adamw_update", "CheckpointManager",
           "DataConfig", "SyntheticLM", "make_batch"]
