"""Deterministic synthetic data pipeline (token streams).

Generates reproducible token batches from a seeded Markov-ish stream with
structure (so loss actually decreases during the example runs).  The
pipeline is *shard-aware*: each data-parallel shard can independently
generate exactly its slice of the global batch — `global_batch(step)` and
`host_shard(step, shard, num_shards)` are bit-consistent, which is what
lets an elastic re-mesh resume mid-epoch without a data server
(tests/test_train.py::test_data_shard_consistency).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 97            # ngram period giving learnable structure


class SyntheticLM:
    """tokens[t+1] = (a * tokens[t] + noise) % V with per-sequence keys —
    a next-token distribution a model can learn, cheap to generate."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg

    def _seq(self, seq_key: np.random.Generator) -> np.ndarray:
        c = self.cfg
        a = 1 + seq_key.integers(0, c.structure)
        toks = np.empty(c.seq_len + 1, np.int32)
        toks[0] = seq_key.integers(0, c.vocab_size)
        noise = seq_key.integers(0, 3, size=c.seq_len)
        for t in range(c.seq_len):
            toks[t + 1] = (a * int(toks[t]) + 1 + int(noise[t])) % c.vocab_size
        return toks

    def _batch_rows(self, step: int, rows) -> dict:
        c = self.cfg
        toks = np.stack([
            self._seq(np.random.default_rng((c.seed, step, int(r))))
            for r in rows])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def global_batch(self, step: int) -> dict:
        return self._batch_rows(step, range(self.cfg.global_batch))

    def host_shard(self, step: int, shard: int, num_shards: int) -> dict:
        c = self.cfg
        per = c.global_batch // num_shards
        return self._batch_rows(step, range(shard * per, (shard + 1) * per))


def make_batch(cfg, cell, step: int = 0, seed: int = 0) -> dict:
    """Concrete (numpy) batch for a ModelConfig x ShapeCell — used by the
    examples and integration tests (adds modality stubs)."""
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=cell.seq_len,
                    global_batch=cell.global_batch, seed=seed)
    if cfg.family == "vlm":
        text = cell.seq_len - cfg.num_patches
        dc = dataclasses.replace(dc, seq_len=text)
    batch = SyntheticLM(dc).global_batch(step)
    rng = np.random.default_rng((seed, step, 7))
    if cfg.family == "vlm":
        batch["patches"] = rng.normal(
            0, 0.02, (cell.global_batch, cfg.num_patches, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rng.normal(
            0, 0.02, (cell.global_batch, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32)
    return batch
