"""Fault-tolerant training loop (restart, elastic re-mesh, stragglers).

The control plane a 1000-node trainer needs, exercised end-to-end on CPU:

* **checkpoint/restart** — periodic sharded saves (atomic, optionally
  async); on any step failure the loop restores the latest durable
  checkpoint and replays from there (the data pipeline is step-keyed and
  deterministic, so replay is exact);
* **failure detection** — a pluggable ``health_check(step)`` callback
  models the heartbeat/collective-timeout signal (the simulator's
  CollectiveCoordinator deadline produces the same signal for the
  what-if studies in benchmarks/fault_tolerance.py);
* **elastic re-mesh** — on a permanent device loss the loop rebuilds a
  smaller mesh (dropping a DP replica), re-device_puts the state with
  the same PartitionSpecs, scales the batch, and continues;
* **straggler mitigation** — a per-step deadline; steps exceeding it are
  counted and surface in metrics (on real hardware the policy triggers
  backup-replica execution; the policy itself is testable here).
"""
from __future__ import annotations

import dataclasses
import time
import typing

import jax
import numpy as np

from repro.models import api
from repro.models.base import ModelConfig
from repro.sharding import specs, umode
from . import optim
from .checkpoint import CheckpointManager
from .data import DataConfig, SyntheticLM


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    step_deadline_s: float = 60.0
    log_every: int = 10


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    final_step: int
    losses: typing.List[float]
    restarts: int
    remesh_events: int
    straggler_steps: int
    final_loss: float


def build(cfg: ModelConfig, mesh, opt_cfg: optim.OptConfig,
          rng=None):
    """Init sharded state + jitted step for (cfg, mesh)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    step_fn, state_sh_fn, batch_sh_fn = umode.make_train_step(
        cfg, mesh, opt_cfg)
    params = api.init(rng, cfg)
    state = optim.init_state(params)
    st_sh = state_sh_fn(jax.eval_shape(lambda: state))
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, st_sh)
    jitted = jax.jit(step_fn, donate_argnums=(0,))
    return state, jitted, st_sh, batch_sh_fn


def run(cfg: ModelConfig, mesh, data_cfg: DataConfig,
        opt_cfg: optim.OptConfig = None, loop_cfg: LoopConfig = None,
        fault_schedule: typing.Dict[int, Exception] = None,
        remesh_schedule: typing.Dict[int, typing.Any] = None,
        verbose: bool = True) -> LoopReport:
    """Run the loop. ``fault_schedule`` injects an exception *before* the
    given step executes (simulating a node failure mid-run);
    ``remesh_schedule`` maps step -> new mesh (elastic shrink/grow)."""
    opt_cfg = opt_cfg or optim.OptConfig(total_steps=loop_cfg.total_steps
                                         if loop_cfg else 100)
    loop_cfg = loop_cfg or LoopConfig()
    fault_schedule = dict(fault_schedule or {})
    remesh_schedule = dict(remesh_schedule or {})
    data = SyntheticLM(data_cfg)
    ckpt = CheckpointManager(loop_cfg.ckpt_dir, async_save=loop_cfg.async_ckpt)

    state, jitted, st_sh, _ = build(cfg, mesh, opt_cfg)
    start = 0
    restored, manifest = ckpt.restore(shardings=st_sh) \
        if ckpt.latest_step() is not None else (None, None)
    if restored is not None:
        state = restored
        start = int(manifest["step"])
        if verbose:
            print(f"[loop] restored from step {start}")

    losses: typing.List[float] = []
    restarts = remesh_events = stragglers = 0
    step = start
    while step < loop_cfg.total_steps:
        if step in remesh_schedule:
            mesh = remesh_schedule.pop(step)
            state_host = jax.device_get(state)
            state, jitted, st_sh, _ = build(cfg, mesh, opt_cfg)
            state = jax.tree.map(
                lambda x, s: jax.device_put(np.asarray(x), s),
                state_host, st_sh)
            remesh_events += 1
            if verbose:
                print(f"[loop] elastic re-mesh at step {step} -> "
                      f"{dict(mesh.shape)}")
        try:
            if step in fault_schedule:
                raise fault_schedule.pop(step)
            batch = data.global_batch(step)
            t0 = time.time()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if dt > loop_cfg.step_deadline_s:
                stragglers += 1
            losses.append(loss)
            if verbose and step % loop_cfg.log_every == 0:
                print(f"[loop] step {step} loss {loss:.4f} "
                      f"({dt * 1e3:.0f} ms)")
            step += 1
            if step % loop_cfg.ckpt_every == 0:
                ckpt.save(step, state)
        except Exception as e:  # noqa: BLE001 — node failure path
            restarts += 1
            if verbose:
                print(f"[loop] step {step} FAILED ({e}); restoring")
            ckpt.wait()
            restored, manifest = ckpt.restore(shardings=st_sh)
            if restored is None:
                state, jitted, st_sh, _ = build(cfg, mesh, opt_cfg)
                step = 0
            else:
                state = restored
                step = int(manifest["step"])
    ckpt.wait()
    return LoopReport(steps_run=len(losses), final_step=step, losses=losses,
                      restarts=restarts, remesh_events=remesh_events,
                      straggler_steps=stragglers,
                      final_loss=losses[-1] if losses else float("nan"))
