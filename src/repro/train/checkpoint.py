"""Fault-tolerant sharded checkpointing.

Design (mirrors what production multi-pod trainers need):

* every array leaf is written as a raw .npy under a step directory, one
  file per *shard owner* (here: single-host CPU writes whole arrays; on a
  real pod each host writes only its addressable shards — the layout and
  manifest already carry shard metadata for that);
* a JSON manifest (tree structure, dtypes, shapes, step, sharding specs)
  is written LAST, then the step directory is atomically renamed from
  ``step_N.tmp`` to ``step_N`` — a crashed save can never be mistaken
  for a complete one;
* `latest_step()` scans for complete checkpoints only, so restart after
  failure resumes from the last durable step (the restart path in
  train/loop.py);
* optional async mode: the save runs on a worker thread over a snapshot
  (jax.device_get taken synchronously), overlapping I/O with step N+1 —
  `wait()` joins before the next save or shutdown.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import typing

import jax
import numpy as np


def _flatten(tree, prefix=""):
    """Flatten a pytree of arrays into {path: leaf} with /-joined keys."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return _fix_lists(tree)


def _fix_lists(node):
    if isinstance(node, dict):
        keys = list(node)
        if keys and all(k.isdigit() for k in keys):
            return [_fix_lists(node[str(i)]) for i in range(len(keys))]
        return {k: _fix_lists(v) for k, v in node.items()}
    return node


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False) -> None:
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: typing.Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extra: dict = None) -> str:
        self.wait()
        snapshot = jax.device_get(state)       # sync snapshot; I/O may be async
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, snapshot, extra or {}),
                daemon=True)
            self._thread.start()
            return self._final_path(step)
        return self._write(step, snapshot, extra or {})

    def _final_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, snapshot, extra: dict) -> str:
        final = self._final_path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(snapshot)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for path, leaf in flat.items():
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":        # npy has no bf16: store bits
                arr = arr.view(np.uint16)
            fname = path.replace("/", ".") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                   # atomic completeness marker
        self._gc()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._final_path(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> typing.List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name,
                                                "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> typing.Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int = None, shardings=None):
        """Load a checkpoint; with `shardings`, place shards directly
        (each leaf jax.device_put with its NamedSharding)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = self._final_path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, info in manifest["leaves"].items():
            arr = np.load(os.path.join(path, info["file"]))
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            flat[key] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest
