"""Optimizer substrate: AdamW + grad clipping + LR schedules + TrainState.

No external optimizer deps — implemented over raw pytrees.  Adam moments
are f32 regardless of param dtype (mixed-precision convention: bf16
params/grads, f32 optimizer state); the moment trees share the params'
PartitionSpecs so FSDP shards them identically (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: typing.Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # "cosine" | "constant" | "linear"


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        decay = (1.0 - frac) if cfg.schedule == "linear" else \
            0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def init_state(params) -> dict:
    """TrainState pytree: {params, mu, nu, step}."""
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "params": params,
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _is_matrix(path_leaf) -> bool:
    return path_leaf.ndim >= 2


def adamw_update(state: dict, grads, cfg: OptConfig) -> typing.Tuple[dict, dict]:
    """One AdamW step. Returns (new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _is_matrix(p):                      # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(state["params"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    state = {"params": new_p, "mu": new_m, "nu": new_v, "step": step}
    return state, {"grad_norm": gnorm, "lr": lr}
