"""Timeline simulation driver: replay a compiled program on the system model.

This is the MGSim use-case end to end: take the *machine-level program*
(post-SPMD HLO of the real JAX computation), turn it into per-device op
traces, and replay them on the component/connection system model.  The
output is what the paper's case study needs: end-to-end time, per-link
traffic, utilization, and what-if answers for stragglers/failures.

Device-count control: simulating all 256/512 chips is exact but O(chips)
events on a single host core.  ``device_limit`` simulates a representative
closed subgroup (complete replica groups only) and is validated to give
identical per-device timing for SPMD traces (every device runs the same
program; contention *within* a ring is modeled analytically inside
``Topology.collective_time_s``, so a subgroup that contains whole groups
reproduces full-system timing exactly — asserted in
``tests/test_sim_system.py::test_subgroup_timing_invariant``).
"""
from __future__ import annotations

import dataclasses
import typing

from .hlo import HloCost, analyze
from .hooks import FaultInjector, MetricsHook
from .hw import SystemSpec, s_to_ps
from .system import System
from .trace import build_runops


@dataclasses.dataclass
class SimReport:
    time_s: float
    events: int
    devices: int
    devices_done: int
    devices_aborted: int
    collectives_completed: int
    collective_timeouts: int
    compute_busy_s: float          # max over simulated cores
    compute_util: float            # busy / end-to-end (bottleneck core)
    link_report: dict
    fabric: str = "analytic"       # which interconnect backend priced it
    link_utilization: dict = dataclasses.field(default_factory=dict)
    scheduler: str = "serial"      # which engine scheduler produced this
    executor: str = "none"         # where grouped rounds ran (threads /
                                   # procs; "none" for the serial scheduler)
    batch_widths: typing.List[int] = dataclasses.field(default_factory=list)
    window_widths: typing.List[int] = dataclasses.field(default_factory=list)

    # Execution artifacts (how the engine drained the queue) are excluded:
    # summaries must be bit-identical across schedulers AND executors,
    # and the parametrized determinism tests compare exactly this dict.
    _EXECUTION_FIELDS = ("scheduler", "executor", "batch_widths",
                         "window_widths")

    def summary(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if k not in self._EXECUTION_FIELDS}


def _select_devices(cost: HloCost, total: int,
                    device_limit: typing.Optional[int]) -> typing.List[int]:
    """Pick a closed set of devices covering complete replica groups."""
    if device_limit is None or device_limit >= total:
        return list(range(total))
    chosen: set = set()
    for rec in cost.collectives:
        for g in rec.groups:
            if chosen.union(g) and len(chosen | set(g)) <= device_limit:
                chosen |= set(g)
            if len(chosen) >= device_limit:
                break
    if not chosen:
        chosen = set(range(min(device_limit, total)))
    # close over groups: any group touching a chosen device joins fully
    changed = True
    while changed:
        changed = False
        for rec in cost.collectives:
            for g in rec.groups:
                s = set(g)
                if s & chosen and not s <= chosen:
                    chosen |= s
                    changed = True
    return sorted(chosen)


def simulate(hlo_text: str = None, cost: HloCost = None,
             spec: SystemSpec = None, parallel: bool = False,
             scheduler: str = None, max_workers: int = 4,
             fabric: str = None, executor: str = None,
             device_limit: typing.Optional[int] = 32,
             dtype_bits: int = 16, repeat_cap: int = 64,
             faults: dict = None, deadline_s: float = None,
             until_s: float = None) -> SimReport:
    """Simulate one compiled step on the modeled machine.

    ``scheduler``: engine scheduler name ("serial" | "batch" |
    "lookahead"); defaults to "serial".  The legacy ``parallel=True``
    knob maps to "batch" with a ``DeprecationWarning``.  All schedulers
    produce bit-identical ``SimReport.summary()``s.

    ``executor``: where round schedulers run grouped work ("threads" |
    "procs"); defaults to "threads".  "procs" executes handlers in
    shard-resident worker processes (real cores, no GIL) and is
    bit-identical too -- engine-level hook state is merged back at the
    end of the run (hooks that define ``merge_shard``).  Ignored by the
    serial scheduler.

    ``fabric``: interconnect backend name ("analytic" | "event");
    defaults to ``spec.fabric``.  See docs/fabric.md.

    ``faults``: {component_name: [(time_s, action, arg), ...]} — forwarded
    to :class:`FaultInjector` (times converted to ps; a ``"transient"``
    action's duration arg is in seconds and converted too).  With the
    event fabric the plan may also target links / DMA engines by name,
    e.g. ``{"fabric.pod0.ici[0,1]+x": [(0.0, "slow", 8.0)]}`` for a
    degraded (straggler) link.  Full plan grammar: docs/faults.md.
    """
    assert (hlo_text is None) != (cost is None), "pass hlo_text xor cost"
    if cost is None:
        cost = analyze(hlo_text)
    spec = spec or SystemSpec()
    system = System(spec, parallel=parallel, deadline_s=deadline_s,
                    scheduler=scheduler, max_workers=max_workers,
                    fabric=fabric, executor=executor)
    metrics = MetricsHook()
    # Engine-level hook only: it already sees busy intervals + requests,
    # and hooks attached directly to connections would mark them
    # stateful_send, fusing clusters and shrinking engine parallelism.
    system.engine.accept_hook(metrics)
    if faults:
        plan = {name: [(s_to_ps(t), a,
                        s_to_ps(arg) if a == "transient" else arg)
                       for (t, a, arg) in acts]
                for name, acts in faults.items()}
        targets = (system.cores + system.programs
                   + system.fabric.fault_targets())
        unknown = set(plan) - {c.name for c in targets}
        if unknown:
            raise ValueError(
                f"fault plan targets unknown components "
                f"{sorted(unknown)}; chips are chipN.core / chipN.prog, "
                f"and fabric.* link/DMA targets require fabric='event' "
                f"(this run uses {system.fabric.name!r})")
        inj = FaultInjector(plan)
        for comp in targets:
            comp.accept_hook(inj)
        inj.arm(targets)   # actions apply on schedule even on idle targets

    runops = build_runops(cost, dtype_bits=dtype_bits, repeat_cap=repeat_cap)
    devices = _select_devices(cost, spec.total_chips, device_limit)
    system.load_trace(runops, devices)
    result = system.run(until_s=until_s)

    busy = max((metrics.busy_ps[c.name] for c in system.cores), default=0)
    t = result["time_s"]
    return SimReport(
        time_s=t,
        events=result["events"],
        devices=len(devices),
        devices_done=result["devices_done"],
        devices_aborted=result["devices_aborted"],
        collectives_completed=result["collectives_completed"],
        collective_timeouts=result["collective_timeouts"],
        compute_busy_s=busy / 1e12,
        compute_util=(busy / 1e12) / t if t else 0.0,
        link_report=system.fabric.link_report(),
        fabric=system.fabric.name,
        link_utilization=system.fabric.link_utilization(
            s_to_ps(t) if t else None),
        scheduler=system.engine.scheduler.name,
        executor=(system.engine.scheduler.executor.name
                  if getattr(system.engine.scheduler, "executor", None)
                  is not None else "none"),
        batch_widths=system.engine.batch_widths,
        window_widths=system.engine.window_widths,
    )


def what_if_straggler(cost: HloCost, spec: SystemSpec, device: int = 0,
                      slow_factor: float = 2.0, device_limit: int = 32,
                      scheduler: str = None, executor: str = None,
                      fabric: str = None,
                      max_workers: int = 4) -> typing.Tuple[SimReport, SimReport]:
    """Paper-style what-if: one chip at `slow_factor`x — whole-system cost.
    Scheduler/executor/fabric pass straight through to :func:`simulate`
    (the what-if answer is bit-identical under all of them)."""
    base = simulate(cost=cost, spec=spec, device_limit=device_limit,
                    scheduler=scheduler, executor=executor, fabric=fabric,
                    max_workers=max_workers)
    slow = simulate(cost=cost, spec=spec, device_limit=device_limit,
                    scheduler=scheduler, executor=executor, fabric=fabric,
                    max_workers=max_workers,
                    faults={f"chip{device}.core": [(0.0, "slow", slow_factor)]})
    return base, slow


def what_if_failure(cost: HloCost, spec: SystemSpec, device: int = 0,
                    fail_at_s: float = 0.0, deadline_s: float = 0.5,
                    device_limit: int = 32, scheduler: str = None,
                    executor: str = None, fabric: str = None,
                    max_workers: int = 4) -> SimReport:
    """Kill one chip; collectives time out via the coordinator deadline —
    the failure-detection signal the fault-tolerant trainer reacts to."""
    return simulate(cost=cost, spec=spec, device_limit=device_limit,
                    deadline_s=deadline_s, scheduler=scheduler,
                    executor=executor, fabric=fabric, max_workers=max_workers,
                    faults={f"chip{device}.prog": [(fail_at_s, "fail", None)]})
