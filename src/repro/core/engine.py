"""Event-driven simulation engine with conservative parallel execution.

Serial mode processes events strictly in ``(time, component_rank, seq)``
order.  Parallel mode implements the paper's conservative scheme (DP-5):
all events sharing the earliest timestamp are grouped by component, the
groups are executed concurrently (a component's state is only touched by
its own group), and newly produced events are committed in a
deterministic order afterwards.  The result is **bit-identical** to
serial execution -- the property MGSim insists on, and which
``tests/test_sim_engine.py`` asserts.

Batch widths (events per timestamp) are recorded so we can report the
Fig. 2 analog: how much parallelism a conservative engine can exploit.
"""
from __future__ import annotations

import collections
import concurrent.futures
import threading
import typing

from .event import Event, EventQueue
from .hooks import Hookable, EVENT_START, EVENT_END


class Engine(Hookable):
    def __init__(self, parallel: bool = False, max_workers: int = 4) -> None:
        super().__init__()
        self.queue = EventQueue()
        self.now = 0
        self.parallel = parallel
        self.max_workers = max_workers
        self._components: list = []
        self._in_batch = False
        self._pending: list = []           # (creator_rank, creation_idx, event)
        self._creation_idx = 0
        self._pending_lock = threading.Lock()
        self.events_processed = 0
        self.batch_widths: list = []       # Fig. 2 analog data
        self._pool = None

    # -- registration ---------------------------------------------------------
    def register(self, item) -> typing.Any:
        """Register a component or connection; assigns deterministic rank."""
        item.engine = self
        item.rank = len(self._components)
        self._components.append(item)
        return item

    # -- scheduling -------------------------------------------------------------
    def post(self, event: Event) -> None:
        assert event.time >= self.now, "cannot schedule into the past"
        if self._in_batch:
            with self._pending_lock:
                idx = self._creation_idx
                self._creation_idx += 1
            self._pending.append((getattr(event.component, "rank", 0), idx, event))
        else:
            self.queue.push(event)

    def dispatch_request(self, dst, request) -> None:
        """Deliver a request to dst as an ordinary event (same timestamp)."""
        self.post(Event(time=self.now, component=dst, kind="request",
                        payload=request))

    # -- execution ----------------------------------------------------------------
    def _handle_one(self, event: Event) -> None:
        comp = event.component
        self.invoke_hooks(EVENT_START, self.now, event)
        comp.invoke_hooks(EVENT_START, self.now, event)
        if not getattr(comp, "fault_failed", False):
            comp.handle(event)
        comp.invoke_hooks(EVENT_END, self.now, event)
        self.invoke_hooks(EVENT_END, self.now, event)
        self.events_processed += 1

    def _run_batch(self, batch: list) -> None:
        """Execute one same-timestamp batch (conservative parallelism)."""
        groups = collections.defaultdict(list)
        for ev in batch:
            groups[getattr(ev.component, "rank", 0)].append(ev)
        ordered_ranks = sorted(groups)
        self.batch_widths.append(len(batch))

        self._in_batch = True
        self._pending = []
        self._creation_idx = 0

        def run_group(rank):
            for ev in groups[rank]:
                self._handle_one(ev)

        if self.parallel and len(ordered_ranks) > 1:
            if self._pool is None:
                self._pool = concurrent.futures.ThreadPoolExecutor(self.max_workers)
            list(self._pool.map(run_group, ordered_ranks))
        else:
            for rank in ordered_ranks:
                run_group(rank)

        self._in_batch = False
        # Commit new events in deterministic order regardless of thread
        # interleaving: sort by (creator rank, event fields) -- creation_idx
        # is thread-racy by design, so it must NOT drive ordering.
        self._pending.sort(key=lambda t: (t[0], t[2].time, t[2].kind, _payload_key(t[2])))
        for _, _, ev in self._pending:
            self.queue.push(ev)
        self._pending = []

    def run(self, until_ps: int = None) -> int:
        """Run until the queue drains (or past ``until_ps``); returns end time."""
        while self.queue:
            t = self.queue.peek_time()
            if until_ps is not None and t > until_ps:
                break
            self.now = t
            self._run_batch(self.queue.pop_batch())
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
        return self.now


def _payload_key(ev: Event):
    """Stable tiebreaker for committing same-rank events."""
    p = ev.payload
    rid = getattr(p, "rid", None)
    if rid is not None:
        return (0, rid)
    try:
        return (1, hash(p) if p.__hash__ else 0)
    except TypeError:
        return (1, 0)
