"""MGSim-TPU: the paper's simulator core, adapted to multi-pod TPU systems.

Four subsystems per paper Sec 4.1 — events, components, request/connection,
hooks — plus the TPU adaptation layers: chip/topology/system models, the
machine-level HLO analyzer (DP-1), the trace builder and the timeline
simulator + roofline report the assignment's perf loop runs on.
"""
from .event import (Event, EventQueue, ShardedEventQueue, LocalQueue,
                    EmptyQueueError)
from .engine import (Engine, Scheduler, RoundScheduler, SCHEDULERS,
                     make_scheduler, register_scheduler, SerialScheduler,
                     BatchParallelScheduler, LookaheadScheduler,
                     BoundedLagScheduler,
                     Executor, EXECUTORS, make_executor, register_executor,
                     ThreadExecutor, ProcExecutor)
from .component import Component, Port
from .connection import (Connection, LagNode, LinkConnection,
                         LimitedConnection, Request)
from .hooks import (Hook, HookCtx, Hookable, Tracer, MetricsHook, StallHook,
                    FaultInjector, EVENT_START, EVENT_END, REQ_SEND,
                    REQ_DELIVER, BUSY_INTERVAL)
from .hw import ChipSpec, SystemSpec, SINGLE_POD, MULTI_POD, DTYPE_BYTES, s_to_ps, ps_to_s
from .topology import Topology, parse_replica_groups
from .chip import TensorCore, HbmController, ComputeJob
from .system import System, DeviceProgram, CollectiveCoordinator
from .hlo import HloModule, HloCost, CollectiveRecord, analyze
from .trace import build_runops
from .simulate import SimReport, simulate, what_if_straggler, what_if_failure
from .roofline import (RooflineTerms, build_terms, collective_sim_time,
                       model_flops_train, model_flops_prefill,
                       model_flops_decode, attention_flops, format_table)

__all__ = [
    "Event", "EventQueue", "ShardedEventQueue", "LocalQueue",
    "EmptyQueueError", "Engine", "Scheduler",
    "RoundScheduler", "SCHEDULERS", "make_scheduler", "register_scheduler",
    "Executor", "EXECUTORS", "make_executor", "register_executor",
    "ThreadExecutor", "ProcExecutor",
    "SerialScheduler", "BatchParallelScheduler", "LookaheadScheduler",
    "BoundedLagScheduler",
    "Component", "Port",
    "Connection", "LagNode", "LinkConnection", "LimitedConnection", "Request",
    "Hook", "HookCtx", "Hookable", "Tracer", "MetricsHook", "StallHook",
    "FaultInjector", "EVENT_START", "EVENT_END", "REQ_SEND", "REQ_DELIVER",
    "BUSY_INTERVAL",
    "ChipSpec", "SystemSpec", "SINGLE_POD", "MULTI_POD", "DTYPE_BYTES",
    "s_to_ps", "ps_to_s",
    "Topology", "parse_replica_groups",
    "TensorCore", "HbmController", "ComputeJob",
    "System", "DeviceProgram", "CollectiveCoordinator",
    "HloModule", "HloCost", "CollectiveRecord", "analyze", "build_runops",
    "SimReport", "simulate", "what_if_straggler", "what_if_failure",
    "RooflineTerms", "build_terms", "collective_sim_time",
    "model_flops_train", "model_flops_prefill", "model_flops_decode",
    "attention_flops", "format_table",
]
