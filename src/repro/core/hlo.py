"""HLO analyzer: parse ``compiled.as_text()`` into a machine-level cost model.

This is the DP-1 ("simulate the machine-level program") piece of the
adaptation: instead of GCN3 binaries we analyze the **post-SPMD,
post-optimization XLA HLO module** -- the exact program a TPU core would
execute.  We parse every computation, then walk the entry computation
accumulating:

* FLOPs (``dot``/``convolution`` exactly from shapes + contracting dims;
  elementwise ops approximately as one FLOP/element);
* HBM bytes: operand + output sizes of **top-level** (fusion-boundary)
  instructions only -- fusion internals never touch HBM;
* collectives: kind, payload bytes, materialized replica groups.

Crucially, ``while`` loops are scaled by their inferred **trip count**
(XLA's own ``cost_analysis`` counts loop bodies exactly once -- measured
in this repo; see DESIGN.md -- which would undercount an 80-layer scanned
transformer by 80x).  Trip counts are inferred from the loop condition
``compare(iv, constant(N)), direction=LT`` pattern that jax.lax.scan /
fori_loop always produce, combined with the induction-variable start
value from the init tuple.
"""
from __future__ import annotations

import dataclasses
import math
import re
import typing

from .hw import DTYPE_BYTES
from .topology import parse_replica_groups

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

# e.g. "bf16[32,64]{1,0}" or "f32[]" or "(f32[2]{0}, s32[])" or "u32[1]{0}"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\((.*)$", re.S)
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _split_instruction(line: str):
    """Split 'name = TYPE opcode(operands...), attrs' robustly.

    TYPE may be a tuple '(a, b, ...)' (bracket-matched) possibly holding
    '/*index=N*/' comments (already stripped by the caller) — a plain
    regex over it breaks, which silently drops every multi-element
    ``while`` op and loses all loop trip counts.
    """
    m = _NAME_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, rest = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    m2 = _OPCODE_RE.match(rest)
    if m2 is None:
        return None
    return name, type_str, m2.group(1), m2.group(2)


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: typing.Tuple[int, ...]

    @property
    def numel(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.numel * DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(text: str) -> typing.List[Shape]:
    """All array shapes in a type string (tuples yield several)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype = m.group(1)
        if dtype not in DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append(Shape(dtype, dims))
    return out


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shapes: typing.List[Shape]        # output shapes (tuple -> several)
    operands: typing.List[str]
    attrs: str
    raw_operands: str = ""            # verbatim text inside opcode(...)

    def constant_value(self) -> typing.Optional[int]:
        if self.opcode != "constant":
            return None
        m = re.fullmatch(r"\s*(-?\d+)\s*", self.raw_operands)
        return int(m.group(1)) if m else None

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_numel(self) -> int:
        return sum(s.numel for s in self.shapes)


@dataclasses.dataclass
class Computation:
    name: str
    instructions: typing.List[Instruction]
    by_name: typing.Dict[str, Instruction]


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    op_name: str
    payload_bytes: int          # B convention per topology.collective_time_s
    operand_bytes: int
    output_bytes: int
    groups: typing.List[typing.List[int]]
    count: float = 1.0          # scaled by while trip counts

    @property
    def group_size(self) -> int:
        return len(self.groups[0]) if self.groups else 1


@dataclasses.dataclass
class TraceOp:
    """One entry in the device-level op trace (program order)."""
    kind: str                   # 'compute' | 'collective'
    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective: CollectiveRecord = None
    repeat: float = 1.0         # how many times this op executes (trip counts)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: typing.List[CollectiveRecord] = dataclasses.field(default_factory=list)
    trace: typing.List[TraceOp] = dataclasses.field(default_factory=list)
    unknown_trip_counts: int = 0

    @property
    def collective_bytes(self) -> float:
        return sum(c.payload_bytes * c.count for c in self.collectives)

    def collective_bytes_by_kind(self) -> dict:
        out: dict = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.payload_bytes * c.count
        return out


# Opcodes that move no data / do no work at runtime
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
    "opt-barrier", "domain", "add-dependency", "custom-call",
}
# Control-flow / call-like
_CALL_OPS = {"fusion", "call", "while", "conditional", "async-start"}


class HloModule:
    def __init__(self, text: str) -> None:
        self.computations: typing.Dict[str, Computation] = {}
        self.entry: str = None
        self._parse(text)
        self._cost_memo: dict = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur_name, cur_entry, instrs = None, False, []
        for line in text.splitlines():
            if cur_name is None:
                # computation headers sit at column 0 and end with "{";
                # params may contain nested parens, so match loosely.
                if (line and not line[0].isspace() and "->" in line
                        and line.rstrip().endswith("{")):
                    head = line.split("(", 1)[0].strip()
                    cur_entry = head.startswith("ENTRY")
                    cur_name = head.replace("ENTRY", "").strip().lstrip("%")
                    instrs = []
                continue
            stripped = line.strip()
            if stripped.startswith("}"):
                comp = Computation(cur_name, instrs,
                                   {i.name: i for i in instrs})
                self.computations[cur_name] = comp
                if cur_entry:
                    self.entry = cur_name
                cur_name = None
                continue
            split = _split_instruction(_COMMENT_RE.sub("", line))
            if split is None:
                continue
            name, type_str, opcode, rest = split
            # operands run until the matching close-paren of the opcode call
            depth, idx = 1, 0
            while idx < len(rest) and depth:
                if rest[idx] == "(":
                    depth += 1
                elif rest[idx] == ")":
                    depth -= 1
                idx += 1
            operand_str, attrs = rest[:idx - 1], rest[idx:]
            operands = _OPERAND_RE.findall(operand_str)
            instrs.append(Instruction(name, opcode, parse_shapes(type_str),
                                      operands, attrs, raw_operands=operand_str))

    # ------------------------------------------------------------------
    def _called(self, instr: Instruction, key: str) -> str:
        m = re.search(key + r"=%?([\w.\-]+)", instr.attrs)
        return m.group(1) if m else None

    def _operand_shape(self, comp: Computation, operand_name: str) -> typing.List[Shape]:
        ins = comp.by_name.get(operand_name)
        return ins.shapes if ins else []

    def _dot_flops(self, comp: Computation, instr: Instruction) -> float:
        lhs = self._operand_shape(comp, instr.operands[0])
        if not lhs or not instr.shapes:
            return 0.0
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
        cdims = [int(d) for d in m.group(1).split(",")] if m and m.group(1) else []
        k = 1
        for d in cdims:
            if d < len(lhs[0].dims):
                k *= lhs[0].dims[d]
        return 2.0 * instr.out_numel * k

    def _conv_flops(self, comp: Computation, instr: Instruction) -> float:
        rhs = self._operand_shape(comp, instr.operands[1]) if len(instr.operands) > 1 else []
        if not rhs or not instr.shapes:
            return 0.0
        m = re.search(r"dim_labels=\w*_(\w+)->", instr.attrs)
        out_ch = 1
        if m:
            labels = m.group(1)
            if "o" in labels and len(rhs[0].dims) == len(labels):
                out_ch = rhs[0].dims[labels.index("o")]
        per_out = rhs[0].numel / max(1, out_ch)
        g = re.search(r"feature_group_count=(\d+)", instr.attrs)
        groups = int(g.group(1)) if g else 1
        return 2.0 * instr.out_numel * per_out / groups

    def _chase(self, comp: Computation, name: str,
               fusion_ctx) -> typing.Tuple[Computation, typing.Optional[Instruction]]:
        """Follow copy/convert chains and parameter->fusion-operand links."""
        ins = comp.by_name.get(name)
        for _ in range(64):
            if ins is None:
                return comp, None
            if ins.opcode in ("copy", "convert", "bitcast") and ins.operands:
                ins = comp.by_name.get(ins.operands[0])
                continue
            if ins.opcode == "parameter" and fusion_ctx is not None:
                outer_comp, fusion_ins = fusion_ctx
                try:
                    idx = int(ins.raw_operands.strip())
                except ValueError:
                    return comp, ins
                if idx >= len(fusion_ins.operands):
                    return comp, ins
                comp, fusion_ctx = outer_comp, None
                ins = comp.by_name.get(fusion_ins.operands[idx])
                continue
            return comp, ins
        return comp, ins

    def _find_loop_compare(self, cond: Computation):
        """Locate compare(iv, constant) in the loop condition, looking
        through one level of fusion (XLA wraps the compare in kLoop)."""
        sites = [(cond, ins, None) for ins in cond.instructions
                 if ins.opcode == "compare"]
        for ins in cond.instructions:
            if ins.opcode == "fusion":
                callee = self.computations.get(self._called(ins, "calls"))
                if callee:
                    sites += [(callee, fin, (cond, ins))
                              for fin in callee.instructions
                              if fin.opcode == "compare"]
        for site_comp, cmp_ins, fusion_ctx in sites:
            d = re.search(r"direction=(\w+)", cmp_ins.attrs)
            direction = d.group(1) if d else None
            bound, iv_index = None, None
            for op in cmp_ins.operands:
                _, src = self._chase(site_comp, op, fusion_ctx)
                if src is None:
                    continue
                if src.opcode == "constant" and src.constant_value() is not None:
                    bound = src.constant_value()
                elif src.opcode == "get-tuple-element":
                    m = re.search(r"index=(\d+)", src.attrs)
                    if m:
                        iv_index = int(m.group(1))
            if bound is not None and direction in ("LT", "GT", "LE", "GE", "NE"):
                return bound, iv_index, direction
        return None

    def _infer_trip_count(self, instr: Instruction,
                          comp: Computation) -> typing.Optional[float]:
        """Trips of a ``while``: find ``compare(gte(iv), constant(N))`` in
        the condition, then the induction start in the init tuple.
        jax.lax.scan / fori_loop always lower to this shape."""
        cond = self.computations.get(self._called(instr, "condition"))
        if cond is None:
            return None
        found = self._find_loop_compare(cond)
        if found is None:
            return None
        bound, iv_index, direction = found
        start = 0
        if iv_index is not None and instr.operands:
            _, init = self._chase(comp, instr.operands[0], None)
            if init is not None and init.opcode == "tuple" and iv_index < len(init.operands):
                _, src = self._chase(comp, init.operands[iv_index], None)
                if src is not None and src.constant_value() is not None:
                    start = src.constant_value()
        trips = bound - start
        if direction in ("LE", "GE"):
            trips += 1
        return float(max(1, abs(trips)))

    # ------------------------------------------------------------------
    def _computation_flops(self, name: str) -> float:
        """Total FLOPs *inside* a computation (fusion bodies): dots/convs
        exact, elementwise 1/elem; no bytes (internal traffic is VMEM)."""
        if ("flops", name) in self._cost_memo:
            return self._cost_memo[("flops", name)]
        comp = self.computations.get(name)
        total = 0.0
        if comp is not None:
            for ins in comp.instructions:
                if ins.opcode == "dot":
                    total += self._dot_flops(comp, ins)
                elif ins.opcode == "convolution":
                    total += self._conv_flops(comp, ins)
                elif ins.opcode in ("fusion", "call", "map", "reduce", "reduce-window"):
                    callee = self._called(ins, "calls") or self._called(ins, "to_apply")
                    if callee:
                        mult = ins.out_numel if ins.opcode in ("map",) else 1
                        total += self._computation_flops(callee) * max(1, mult)
                    if ins.opcode in ("reduce", "reduce-window"):
                        total += ins.out_numel
                elif ins.opcode == "while":
                    body = self._called(ins, "body")
                    trips = self._infer_trip_count(ins, comp) or 1.0
                    total += trips * self._computation_flops(body)
                elif ins.opcode not in _FREE_OPS:
                    total += ins.out_numel
        self._cost_memo[("flops", name)] = total
        return total

    def _slice_read_bytes(self, callee_name: str):
        """For a fusion body: map param index -> billed read bytes when
        that parameter is consumed ONLY by dynamic-slice/gather ops (XLA
        reads the slice, not the buffer — billing the full operand makes
        a scan that slices its stacked carry look 80x more expensive).
        Returns {param_idx: sliced_bytes}."""
        if ("slices", callee_name) in self._cost_memo:
            return self._cost_memo[("slices", callee_name)]
        comp = self.computations.get(callee_name)
        out: dict = {}
        if comp is not None:
            pname_to_idx = {}
            for ins in comp.instructions:
                if ins.opcode == "parameter":
                    try:
                        pname_to_idx[ins.name] = int(ins.raw_operands.strip())
                    except ValueError:
                        pass
            sliced: dict = {}
            full: set = set()
            for ins in comp.instructions:
                if ins.opcode == "parameter":
                    continue
                for op in ins.operands:
                    if op not in pname_to_idx:
                        continue
                    idx = pname_to_idx[op]
                    if ins.opcode in ("dynamic-slice", "gather"):
                        sliced[idx] = sliced.get(idx, 0) + ins.out_bytes
                    else:
                        full.add(idx)
            out = {i: b for i, b in sliced.items() if i not in full}
        self._cost_memo[("slices", callee_name)] = out
        return out

    def _has_dus(self, callee_name: str) -> bool:
        key = ("dus", callee_name)
        if key not in self._cost_memo:
            comp = self.computations.get(callee_name)
            self._cost_memo[key] = bool(comp) and any(
                i.opcode == "dynamic-update-slice" for i in comp.instructions)
        return self._cost_memo[key]

    def cost(self, comp_name: str = None, _depth: int = 0) -> HloCost:
        """Walk a computation at fusion-boundary granularity."""
        name = comp_name or self.entry
        comp = self.computations[name]
        cost = HloCost()
        for ins in comp.instructions:
            if ins.opcode in _FREE_OPS or ins.opcode.endswith("-done"):
                continue
            if ins.opcode.startswith(COLLECTIVE_OPS):
                kind = next(k for k in COLLECTIVE_OPS if ins.opcode.startswith(k))
                groups = parse_replica_groups(ins.attrs, op=ins.name)
                if kind == "collective-permute" and not groups:
                    # permutes carry source_target_pairs, not replica_groups;
                    # all pairs shift concurrently -> one synchronized group
                    pairs = re.findall(r"\{(\d+),(\d+)\}", ins.attrs)
                    members = sorted({int(x) for p in pairs for x in p})
                    if members:
                        groups = [members]
                in_bytes = sum(s.bytes for op in ins.operands
                               for s in self._operand_shape(comp, op))
                out_bytes = ins.out_bytes
                payload = out_bytes if kind == "all-gather" else in_bytes
                rec = CollectiveRecord(kind, ins.name, payload, in_bytes,
                                       out_bytes, groups)
                cost.collectives.append(rec)
                cost.trace.append(TraceOp("collective", ins.name,
                                          collective=rec))
                continue
            if ins.opcode == "while":
                body = self._called(ins, "body")
                trips = self._infer_trip_count(ins, comp)
                if trips is None:
                    trips = 1.0
                    cost.unknown_trip_counts += 1
                sub = self.cost(body, _depth + 1)
                cost.flops += trips * sub.flops
                cost.hbm_bytes += trips * sub.hbm_bytes
                cost.unknown_trip_counts += sub.unknown_trip_counts
                for c in sub.collectives:
                    c2 = dataclasses.replace(c, count=c.count * trips)
                    cost.collectives.append(c2)
                for top in sub.trace:
                    cost.trace.append(dataclasses.replace(
                        top, repeat=top.repeat * trips,
                        collective=dataclasses.replace(
                            top.collective, count=top.collective.count * trips)
                        if top.collective else None))
                continue
            if ins.opcode == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
                names = _OPERAND_RE.findall(branches[0]) if branches else []
                if not names:
                    tc = self._called(ins, "true_computation")
                    fc = self._called(ins, "false_computation")
                    names = [n for n in (tc, fc) if n]
                if names:  # worst-case branch
                    subs = [self.cost(n, _depth + 1) for n in names]
                    worst = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                    cost.flops += worst.flops
                    cost.hbm_bytes += worst.hbm_bytes
                    cost.collectives.extend(worst.collectives)
                    cost.trace.extend(worst.trace)
                continue
            if ins.opcode == "call":
                callee = self._called(ins, "to_apply")
                if callee:
                    sub = self.cost(callee, _depth + 1)
                    cost.flops += sub.flops
                    cost.hbm_bytes += sub.hbm_bytes
                    cost.collectives.extend(sub.collectives)
                    cost.trace.extend(sub.trace)
                continue
            # ---- ordinary top-level (fusion-boundary) instruction ----
            flops = 0.0
            if ins.opcode == "dot":
                flops = self._dot_flops(comp, ins)
            elif ins.opcode == "convolution":
                flops = self._conv_flops(comp, ins)
            elif ins.opcode == "fusion":
                callee = self._called(ins, "calls")
                if callee:
                    flops = self._computation_flops(callee)
            elif ins.opcode in ("reduce", "reduce-window", "sort", "scatter",
                                "gather", "select-and-scatter"):
                flops = ins.out_numel
            else:
                flops = ins.out_numel  # elementwise-ish
            per_op = [sum(s.bytes for s in self._operand_shape(comp, op))
                      for op in ins.operands]
            inplace_capable = ins.opcode == "dynamic-update-slice"
            if ins.opcode == "fusion":
                callee = self._called(ins, "calls")
                if callee:
                    for idx, b in self._slice_read_bytes(callee).items():
                        if idx < len(per_op):
                            per_op[idx] = min(per_op[idx], b)
                    inplace_capable = self._has_dus(callee)
            elif ins.opcode in ("dynamic-slice", "gather") and per_op:
                per_op[0] = min(per_op[0], 2 * ins.out_bytes)
            in_bytes = sum(per_op)
            hbm = in_bytes + ins.out_bytes
            # In-place update aliasing (dynamic-update-slice and fusions
            # CONTAINING one): XLA updates the buffer in place, so true
            # traffic is ~2x the small update, not read+write of the full
            # operand.  Signature: the op can update in place AND one
            # operand == output shape and >> the rest (scan-carry stacks,
            # KV-cache writes).  Without this, a depth-L scan bills L^2
            # slice copies and decode bills a full cache copy per layer.
            if per_op and inplace_capable:
                biggest = max(per_op)
                rest = in_bytes - biggest
                if (biggest == ins.out_bytes and biggest > (1 << 20)
                        and biggest >= 8 * max(rest, 1)):
                    hbm = 2 * rest + min(biggest, 2 * max(rest, 1))
            cost.flops += flops
            cost.hbm_bytes += hbm
            cost.trace.append(TraceOp("compute", ins.name, flops=flops,
                                      hbm_bytes=hbm))
        return cost


def analyze(hlo_text: str) -> HloCost:
    return HloModule(hlo_text).cost()
