"""BatchParallelScheduler -- the paper's DP-5 conservative scheme.

All events sharing the earliest timestamp form one batch; the batch is
grouped into component clusters and the groups run concurrently on a
thread pool (a cluster's state is only touched by its own group).
Newly created events all commit afterwards in serial post order, so the
result is bit-identical to serial execution.

Grouping is by ``Engine.compute_clusters`` (the ``RoundScheduler``
default) rather than raw component rank: components sharing a stateful
connection (``LinkConnection`` occupancy, attached hooks) mutate that
connection's state from inside their handlers, so they must not run on
different threads even at the same timestamp.

Limitation this scheduler inherits from the paper's scheme: it only
parallelizes *exact* timestamp ties.  Traces whose per-component op
latencies diverge degrade to batch width 1 -- that is what
:class:`repro.core.engine.lookahead.LookaheadScheduler` fixes.
"""
from __future__ import annotations

from .base import RoundScheduler, register_scheduler


class BatchParallelScheduler(RoundScheduler):
    name = "batch"
    use_pool = True

    # RoundScheduler defaults provide the rest of DP-5: one-tick windows
    # (same-timestamp batches) with every post deferred to the commit,
    # per-cluster grouping, and the cluster-sharded event queue.
    # ``bounded_lag`` stays False: the paper's scheme is *defined* by
    # the global same-timestamp barrier -- removing it turns this into
    # the bounded scheduler (``scheduler="bounded"``), which subsumes
    # batch whenever per-cluster horizons are wanted.


register_scheduler("batch", BatchParallelScheduler)
