"""Pluggable executor backends for the round schedulers.

``Executor`` decides *where* a round's grouped cluster work runs --
``threads`` (compatibility default, GIL-bound) or ``procs`` (one worker
process per bucket, shard-resident state, real cores).  The registry
mirrors the scheduler and fabric ones; see docs/engine.md
("Executors") for the residency contract and how to register a third
backend.
"""
from .base import Executor, EXECUTORS, make_executor, register_executor
from .threads import ThreadExecutor
from .procs import ProcExecutor

__all__ = [
    "Executor", "EXECUTORS", "make_executor", "register_executor",
    "ThreadExecutor", "ProcExecutor",
]
