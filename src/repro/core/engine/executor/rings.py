"""Shared-memory SPSC ring buffers for the procs executor's round traffic.

A pipe round trip on a loaded host costs ~100-400us in wakeup latency
and syscall overhead -- paid *twice per round* per worker by the procs
executor, which is exactly the "round-barrier tax" the bounded-lag
scheduler attacks from the scheduling side.  This module attacks the
transport side: each parent<->worker direction becomes one
single-producer / single-consumer byte ring over
``multiprocessing.shared_memory``, so handing a round's message to a
spinning peer costs two memcpys and a pair of counter stores instead of
a syscall + scheduler wakeup.

Layout of one ring (one direction)::

    [ tail u64 | pad | head u64 | pad | data[capacity] ]

``tail`` counts total bytes ever written (producer-owned), ``head``
total bytes ever read (consumer-owned); both are monotonic, so
fullness is ``tail - head`` with no empty/full ambiguity, and each
cache line has exactly one writer.  Frames are ``u32 length`` +
payload, written as a circular byte stream -- a frame larger than the
remaining (or even total) capacity simply streams through the ring in
chunks while the consumer drains it, so capacity only affects speed,
never correctness.

Progress/visibility contract: CPython executes the data copy before
the counter store (bytecode order) and both sides run under their own
GIL, which on the strongly-ordered platforms the fork start method
exists on (POSIX) makes the counter publication act as the release of
the preceding copy.  Waiting sides spin briefly, then back off to
micro-sleeps; a ``deadcheck`` callback (checked on the slow path) lets
the parent turn a dead worker into an exception instead of a hang.

``Ring`` objects are created by the parent *before* forking; the child
inherits the mapping.  Only the creating side should ``unlink``.
"""
from __future__ import annotations

import os
import struct
import time
import typing

try:                                        # gate: absent on some platforms
    from multiprocessing import shared_memory as _shm
except ImportError:                         # pragma: no cover - exotic builds
    _shm = None

_TAIL_OFF = 0
_HEAD_OFF = 64
_DATA_OFF = 128
_U64 = struct.Struct("<Q")
_LEN = struct.Struct("<I")

DEFAULT_CAPACITY = 1 << 20                  # 1 MiB per direction

# Busy-waiting only pays when the peer can actually run on another CPU;
# on a single-CPU host a spinning waiter blocks the very process it is
# waiting for until the scheduler preempts it, so yield immediately.
_HOT_SPINS = 2000 if (os.cpu_count() or 1) > 1 else 0
_sched_yield = getattr(os, "sched_yield", None) or (lambda: time.sleep(0))


def available() -> bool:
    """True when shared-memory rings can be used on this host."""
    return _shm is not None


class PeerGone(RuntimeError):
    """Raised by a blocking ring operation when ``deadcheck`` reports
    the other side of the ring is gone."""


class Ring:
    """One SPSC byte ring.  Exactly one process calls ``send_bytes``,
    exactly one calls ``recv_bytes`` (they may be the same process only
    in tests).  ``deadcheck`` -- if set -- is invoked on the blocking
    slow path and should raise :class:`PeerGone` when the peer died."""

    __slots__ = ("shm", "capacity", "_data", "_buf", "tail", "head",
                 "deadcheck", "_owner")

    def __init__(self, capacity: int = DEFAULT_CAPACITY, name: str = None):
        if _shm is None:                    # pragma: no cover - gated earlier
            raise RuntimeError("multiprocessing.shared_memory unavailable")
        self.capacity = capacity
        if name is None:
            self.shm = _shm.SharedMemory(create=True,
                                         size=_DATA_OFF + capacity)
            self._owner = True
            buf = self.shm.buf
            _U64.pack_into(buf, _TAIL_OFF, 0)
            _U64.pack_into(buf, _HEAD_OFF, 0)
        else:                               # attach (non-fork peers)
            self.shm = _shm.SharedMemory(name=name)
            self._owner = False
        self._buf = self.shm.buf
        self._data = self.shm.buf[_DATA_OFF:_DATA_OFF + capacity]
        # Local mirrors of the counters this side owns/last observed --
        # the shared copies are only touched to publish/refresh.
        self.tail = _U64.unpack_from(self._buf, _TAIL_OFF)[0]
        self.head = _U64.unpack_from(self._buf, _HEAD_OFF)[0]
        self.deadcheck: typing.Optional[typing.Callable] = None

    # -- blocking helpers --------------------------------------------------
    def _wait(self, spins: int) -> int:
        """One step of the spin -> yield -> micro-sleep backoff; returns
        the incremented spin counter.  Checks ``deadcheck`` once the
        wait leaves the hot spin (a dead peer never publishes again)."""
        if spins < _HOT_SPINS:
            return spins + 1
        if self.deadcheck is not None and spins % 64 == 0:
            self.deadcheck()
        if spins < _HOT_SPINS + 500:
            _sched_yield()                  # cede the CPU to the peer
        else:
            time.sleep(0.00005 if spins < _HOT_SPINS + 4000 else 0.0005)
        return spins + 1

    # -- producer side -----------------------------------------------------
    def send_bytes(self, payload: bytes) -> None:
        # One frame, one publish: the length-prefix concat is cheaper
        # than a second publish + the consumer waking up between them.
        self._write(_LEN.pack(len(payload)) + payload)

    def _write(self, data) -> None:
        buf, cap = self._data, self.capacity
        tail = self.tail
        n = len(data)
        head = _U64.unpack_from(self._buf, _HEAD_OFF)[0]
        pos = tail % cap
        if cap - (tail - head) >= n and cap - pos >= n:
            buf[pos:pos + n] = data          # contiguous, fits: fast path
            tail += n
            self.tail = tail
            _U64.pack_into(self._buf, _TAIL_OFF, tail)   # publish
            return
        mv = memoryview(data)
        spins = 0
        while mv.nbytes:
            head = _U64.unpack_from(self._buf, _HEAD_OFF)[0]
            free = cap - (tail - head)
            if not free:
                spins = self._wait(spins)
                continue
            spins = 0
            k = min(free, mv.nbytes)
            pos = tail % cap
            first = min(k, cap - pos)
            buf[pos:pos + first] = mv[:first]
            if k > first:
                buf[:k - first] = mv[first:k]
            tail += k
            self.tail = tail
            _U64.pack_into(self._buf, _TAIL_OFF, tail)   # publish
            mv = mv[k:]

    # -- consumer side -----------------------------------------------------
    def recv_bytes(self) -> bytes:
        buf, cap = self._data, self.capacity
        head = self.head
        pos = head % cap
        if cap - pos >= 4 and \
                _U64.unpack_from(self._buf, _TAIL_OFF)[0] - head >= 4:
            n = _LEN.unpack_from(buf, pos)[0]
            if cap - pos - 4 >= n and \
                    _U64.unpack_from(self._buf, _TAIL_OFF)[0] - head >= 4 + n:
                out = bytes(buf[pos + 4:pos + 4 + n])    # fast path
                self.head = head + 4 + n
                _U64.pack_into(self._buf, _HEAD_OFF, self.head)  # publish
                return out
        n = _LEN.unpack(self._read(4))[0]
        return self._read(n)

    def poll(self) -> bool:
        """True when at least one byte is ready (non-blocking)."""
        return _U64.unpack_from(self._buf, _TAIL_OFF)[0] > self.head

    def _read(self, n: int) -> bytes:
        out = bytearray(n)
        buf, cap = self._data, self.capacity
        head = self.head
        got = 0
        spins = 0
        while got < n:
            tail = _U64.unpack_from(self._buf, _TAIL_OFF)[0]
            avail = tail - head
            if not avail:
                spins = self._wait(spins)
                continue
            spins = 0
            k = min(avail, n - got)
            pos = head % cap
            first = min(k, cap - pos)
            out[got:got + first] = buf[pos:pos + first]
            if k > first:
                out[got + first:got + k] = buf[:k - first]
            head += k
            self.head = head
            _U64.pack_into(self._buf, _HEAD_OFF, head)   # publish
            got += k
        return bytes(out)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        self._data.release()
        self._buf.release()
        self.shm.close()

    def unlink(self) -> None:
        if self._owner:
            self.shm.unlink()


class RingPair:
    """The parent's view of one worker's duplex channel: ``req`` is
    written by the parent and drained by the worker, ``rsp`` the
    reverse.  Created before the fork; the child reuses the same object
    through the inherited mapping."""

    __slots__ = ("req", "rsp")

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.req = Ring(capacity)
        self.rsp = Ring(capacity)

    def close(self) -> None:
        self.req.close()
        self.rsp.close()

    def unlink(self) -> None:
        self.req.unlink()
        self.rsp.unlink()


# -- IPC microbenchmarks ------------------------------------------------------

def _echo_child_rings(pair: "RingPair") -> None:  # pragma: no cover - child
    import os
    try:
        while True:
            msg = pair.req.recv_bytes()
            if not msg:
                break
            pair.rsp.send_bytes(msg)
    finally:
        os._exit(0)


def ring_rtt_us(reps: int = 400, size: int = 256) -> float:
    """Median-free best-effort ring round-trip latency in microseconds:
    one ``size``-byte message to a forked echo child and back, averaged
    over ``reps`` round trips (first quarter discarded as warmup)."""
    import multiprocessing
    if not available() or \
            "fork" not in multiprocessing.get_all_start_methods():
        return float("nan")
    mp = multiprocessing.get_context("fork")
    pair = RingPair(capacity=1 << 16)
    proc = mp.Process(target=_echo_child_rings, args=(pair,), daemon=True)
    proc.start()
    payload = b"x" * size
    try:
        for _ in range(reps // 4):          # warmup
            pair.req.send_bytes(payload)
            pair.rsp.recv_bytes()
        t0 = time.perf_counter()
        for _ in range(reps):
            pair.req.send_bytes(payload)
            pair.rsp.recv_bytes()
        dt = time.perf_counter() - t0
    finally:
        pair.req.send_bytes(b"")
        proc.join(timeout=5)
        if proc.is_alive():                 # pragma: no cover - defensive
            proc.terminate()
        pair.close()
        pair.unlink()
    return dt / reps * 1e6


def _echo_child_pipe(conn) -> None:  # pragma: no cover - child
    import os
    try:
        while True:
            msg = conn.recv_bytes()
            if not msg:
                break
            conn.send_bytes(msg)
    finally:
        os._exit(0)


def pipe_rtt_us(reps: int = 400, size: int = 256) -> float:
    """Pipe round-trip latency in microseconds, same protocol as
    :func:`ring_rtt_us` so the two numbers are directly comparable --
    this is the per-round tax the rings remove."""
    import multiprocessing
    if "fork" not in multiprocessing.get_all_start_methods():
        return float("nan")
    mp = multiprocessing.get_context("fork")
    parent, child = mp.Pipe(duplex=True)
    proc = mp.Process(target=_echo_child_pipe, args=(child,), daemon=True)
    proc.start()
    child.close()
    payload = b"x" * size
    try:
        for _ in range(reps // 4):          # warmup
            parent.send_bytes(payload)
            parent.recv_bytes()
        t0 = time.perf_counter()
        for _ in range(reps):
            parent.send_bytes(payload)
            parent.recv_bytes()
        dt = time.perf_counter() - t0
    finally:
        parent.send_bytes(b"")
        proc.join(timeout=5)
        if proc.is_alive():                 # pragma: no cover - defensive
            proc.terminate()
        parent.close()
    return dt / reps * 1e6
