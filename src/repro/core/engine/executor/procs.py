"""Process-backed executor: shard-resident state on real cores.

The GIL makes ``threads`` a correctness backend, not a speed one, for
pure-Python handlers.  ``procs`` converts the schedulers' architectural
parallelism into wall-clock the way partitioned simulators do (ACALSim;
Huerta 2025): partition state, keep it partitioned, exchange messages.

**Topology.**  ``prepare`` forks one long-lived worker process per
bucket (``processes = min(max_workers, os.cpu_count())`` -- more
workers than cores just adds scheduling noise) *after*
``compute_clusters``, so every worker starts with a bit-identical
replica of the fully wired component graph.  A cluster is pinned to
worker ``cluster_id % processes`` for the whole run -- the same sticky
assignment the thread pool uses -- and from then on that worker's
replica of the cluster's components is the *authoritative* one: the
parent's copies go stale until the end-of-run state sync.

**Per round** (plain-pickled envelopes of ints/strings/bytes; carried
over shared-memory rings when :mod:`rings` is available, else over the
worker's duplex pipe -- same frames either way):

* parent -> worker: the window's event entries for each of the worker's
  clusters -- ``(sid, window_end, (time, rank, seq, kind, payload-ref)
  tuples)`` groups, plus the wave's shared per-cluster ``horizons``
  list (``None`` under a global-barrier scheduler).  Per-group window
  ends are what lets the bounded-lag scheduler run clusters at
  *different* horizons within one exchange.
* worker: runs the ordinary ``_GroupCtx`` machinery (local side-heap,
  generation bookkeeping, strict-window guard) over its clusters;
  handlers mutate shard-resident state with no locks and no GIL
  contention.
* worker -> parent: per cluster ``(executed, max_time, posts)`` where
  posts are ``(commit stamp, intra-handler idx, event coordinates)`` --
  beyond-window posts and cross-cluster sends only; in-window local
  events never leave the worker.
* parent: rebuilds the posts as events and runs the unchanged commit --
  stamp-sort, push per destination shard -- so seq assignment, and
  therefore the simulation, stays bit-identical to serial.

**Payloads stay shard-resident too.**  Event payloads (requests,
routing stubs) reference live simulation objects, so shipping them is
the protocol's only nontrivial serialization -- and it is mostly
avoided:

* a post whose destination cluster lives in the *same* worker parks its
  payload in that worker's payload cache and sends only the cache key
  (``("L", key)``) -- zero pickling for the dominant
  own-cluster-beyond-window traffic;
* posts to *other* workers batch their payloads into one
  :mod:`wire`-encoded blob per destination worker per round
  (references encode as ranks, so the blob decodes against any
  replica); the parent routes the blob to its destination unopened,
  piggybacked on the next round message, and entries reference items as
  ``("B", src worker, blob seq, index)``;
* the few parent-born payloads (initial trace events) ship
  individually as ``("P", bytes)``.

**End of run.**  Each worker ships ``shard_state()`` for the components
it owns (references encoded as ranks, so parent-graph identity is
preserved) plus any engine-level hooks that declare ``merge_shard``;
the parent applies both.  Hooks without ``merge_shard`` (e.g. Tracer)
keep only parent-side observations -- see docs/engine.md for the exact
residency rules.

A worker that dies mid-run surfaces as a ``RuntimeError`` naming the
worker, never a hang: on the pipe transport each child closes every
pipe end it does not own, so the parent sees EOF the moment the
process exits; on the ring transport every blocking ring operation
runs a liveness ``deadcheck`` (the parent polls the worker process,
the worker polls its parent pid) once it leaves the hot spin.
Worker-side exceptions (including the lookahead strict-window guard)
travel back with their traceback and re-raise in the parent.
"""
from __future__ import annotations

import multiprocessing
import os
import traceback

from . import rings, wire
from .base import Executor, register_executor
from ...event import Event

_plain_dumps = wire.plain_dumps
_plain_loads = wire.plain_loads


class _Ref:
    """Parent-side stand-in for a payload that lives in a worker: the
    parent routes the reference, never the object."""

    __slots__ = ("ref",)

    def __init__(self, ref) -> None:
        self.ref = ref

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"_Ref({self.ref!r})"


class _WorkerState:
    """Shard-worker side of the protocol (lives in the forked child)."""

    def __init__(self, sched, wid: int, nprocs: int, send) -> None:
        from ..base import _GroupCtx      # late: avoid import cycle
        self._GroupCtx = _GroupCtx
        self.sched = sched
        self.eng = sched.engine
        self.wid = wid
        self.nprocs = nprocs
        self.send = send                  # reply-bytes sink (ring or pipe)
        self.ctxs: dict = {}              # cluster id -> _GroupCtx (lazy)
        self.local: dict = {}             # key -> parked own-cluster payload
        self.local_seq = 0
        self.blob_seq = 0
        self.blobs: dict = {}             # (src wid, seq) -> [payloads, n]
        # Mergeable hooks accumulate into fresh replicas: the fork
        # carried the parent's pre-run state, and merging that baseline
        # back would double-count it once per worker.  Engine-level
        # hooks fire in every worker; component/connection-level hooks
        # fire only in the item's owning worker (a hooked connection is
        # stateful_send, hence fused with its endpoints), so swapping
        # the owned items' lists covers every firing exactly once.
        hooks = self.eng._hooks
        self.merge_idx = [i for i, h in enumerate(hooks)
                          if hasattr(h, "merge_shard")]
        for i in self.merge_idx:
            hooks[i] = hooks[i].fresh_shard()
        self.comp_merge: list = []        # (rank, hook index) pairs
        for comp in self.eng._components:
            if comp.cluster_id % nprocs != wid or not comp.hooks_active:
                continue
            comp_hooks = comp._hooks
            for i, h in enumerate(comp_hooks):
                if hasattr(h, "merge_shard"):
                    comp_hooks[i] = h.fresh_shard()
                    self.comp_merge.append((comp.rank, i))

    # -- payload refs ------------------------------------------------------
    def _resolve(self, pref):
        if pref is None:
            return None
        tag = pref[0]
        if tag == "L":                    # parked in this worker earlier
            return self.local.pop(pref[1])
        if tag == "B":                    # item of a routed blob
            slot = self.blobs[(pref[1], pref[2])]
            payload = slot[0][pref[3]]
            slot[1] -= 1
            if not slot[1]:
                del self.blobs[(pref[1], pref[2])]
            return payload
        return wire.loads(pref[1], self.eng)          # "P": parent-born

    def _decode_entries(self, wire_entries) -> list:
        comps = self.eng._components
        resolve = self._resolve
        return [(t, 0, rank, seq,
                 Event(t, comps[rank], kind, resolve(pref), seq))
                for t, rank, seq, kind, pref in wire_entries]

    def _encode_posts(self, posts, cross: dict) -> list:
        """Posts -> wire tuples; payloads park locally or join the
        per-destination-worker blob batches in ``cross``."""
        wid = self.wid
        nprocs = self.nprocs
        out = []
        for entry, idx, ev in posts:
            comp = ev.component
            p = ev.payload
            if p is None:
                pref = None
            elif comp.cluster_id % nprocs == wid:
                key = self.local_seq = self.local_seq + 1
                self.local[key] = p
                pref = ("L", key)
            else:
                dst = comp.cluster_id % nprocs
                batch = cross.get(dst)
                if batch is None:
                    # Each destination's batch gets its own blob seq:
                    # (src wid, seq) must stay unique across *all*
                    # blobs, because the parent pools them under that
                    # key when materializing stranded references after
                    # a partial run.
                    seq = self.blob_seq = self.blob_seq + 1
                    batch = cross[dst] = (seq, [])
                pref = ("B", wid, batch[0], len(batch[1]))
                batch[1].append(p)
            out.append(((entry[0], entry[1], entry[2], entry[3]), idx,
                        (ev.time, comp.rank, ev.kind, pref)))
        return out

    # -- message handlers --------------------------------------------------
    def round(self, groups, blobs, horizons) -> None:
        for src_wid, seq, blob_bytes, count in blobs:
            self.blobs[(src_wid, seq)] = [wire.loads(blob_bytes, self.eng),
                                          count]
        out = []
        cross: dict = {}
        for sid, wend, wire_entries in groups:
            ctx = self.ctxs.get(sid)
            if ctx is None:
                ctx = self.ctxs[sid] = self._GroupCtx(self.sched, sid)
            ctx.begin(wend, self._decode_entries(wire_entries))
            ctx.horizons = horizons       # bounded lag: target-cluster guard
            ctx.execute()
            posts = self._encode_posts(ctx.posts, cross)
            ctx.posts.clear()
            out.append((sid, ctx.executed, ctx.max_time, posts))
        wired = [(dst, seq, wire.dumps(batch, self.eng), len(batch))
                 for dst, (seq, batch) in cross.items()]
        self.send(_plain_dumps(("D", out, wired)))

    def collect(self) -> None:
        state = {c.rank: c.shard_state() for c in self.eng._components
                 if c.cluster_id % self.nprocs == self.wid}
        hooks = [(i, self.eng._hooks[i]) for i in self.merge_idx]
        comp_hooks = [(rank, i, self.eng._components[rank]._hooks[i])
                      for rank, i in self.comp_merge]
        # Ship the unconsumed payload caches too: a partial run
        # (``until_ps``) leaves committed events in the *parent* queue
        # whose payloads still live here -- the parent materializes
        # those references so a later run (with fresh workers) finds
        # real objects, not dangling cache keys.
        stranded_blobs = {k: v[0] for k, v in self.blobs.items()}
        self.send(wire.dumps(
            ("S", state, hooks, comp_hooks, self.local, stranded_blobs),
            self.eng))


def _worker_main(sched, wid: int, nprocs: int, child_ends, parent_ends,
                 ring_pairs):
    """Shard worker loop (runs in the forked child)."""
    for p in parent_ends:
        p.close()
    for i, c in enumerate(child_ends):
        if i != wid:
            c.close()
    conn = child_ends[wid]
    ring = None
    if ring_pairs is not None:
        for i, pair in enumerate(ring_pairs):
            if i != wid:
                pair.close()
        ring = ring_pairs[wid]
        ppid = os.getppid()

        def _parent_gone() -> None:
            # An orphaned worker must not spin on a ring no one feeds;
            # the pipe transport gets this for free via EOF.
            if os.getppid() != ppid:
                os._exit(1)

        ring.req.deadcheck = ring.rsp.deadcheck = _parent_gone
        recv, send = ring.req.recv_bytes, ring.rsp.send_bytes
    else:
        recv, send = conn.recv_bytes, conn.send_bytes
    state = _WorkerState(sched, wid, nprocs, send)
    try:
        while True:
            try:
                msg = _plain_loads(recv())
            except EOFError:
                break
            op = msg[0]
            try:
                if op == "R":             # one round's window slices
                    state.round(msg[1], msg[2], msg[3])
                elif op == "C":           # end of run: ship shard state
                    state.collect()
                elif op == "Q":
                    break
            except BaseException:
                send(_plain_dumps(("E", traceback.format_exc())))
    except (BrokenPipeError, KeyboardInterrupt):
        pass
    finally:
        conn.close()
        os._exit(0)


class ProcExecutor(Executor):
    name = "procs"
    inline_rounds = False                 # state is shard-resident

    def __init__(self, max_workers: int = 4) -> None:
        super().__init__(max_workers)
        # Clamped again per run to the cluster count in prepare() --
        # an idle worker would still hold a full forked replica.
        self._max_procs = max(1, min(max_workers, os.cpu_count() or 1))
        self.processes = self._max_procs
        self._procs: list = []
        self._conns: list = []
        self._rings = None                # list[RingPair] when in use
        self.transport = "pipes"
        self._msgs: dict = {}             # reused per-round send buffer
        self._pending_blobs: dict = {}    # dst wid -> blobs awaiting routing

    # -- lifecycle --------------------------------------------------------
    def prepare(self, ctxs: list) -> None:
        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "executor='procs' requires the fork start method (POSIX); "
                "use executor='threads' on this platform")
        mp = multiprocessing.get_context("fork")
        nprocs = self.processes = max(1, min(self._max_procs, len(ctxs)))
        pipes = [mp.Pipe(duplex=True) for _ in range(nprocs)]
        parent_ends = [p for p, _ in pipes]
        child_ends = [c for _, c in pipes]
        self._conns = parent_ends
        self._procs = []
        self._pending_blobs = {}
        # Round traffic rides shared-memory rings when the host has
        # them (created before the fork so children inherit the
        # mapping); the pipes stay open as the fallback transport and
        # for EOF-based death detection in either direction.
        self._rings = ([rings.RingPair() for _ in range(nprocs)]
                       if rings.available() else None)
        self.transport = "rings" if self._rings else "pipes"
        for wid in range(nprocs):
            proc = mp.Process(
                target=_worker_main,
                args=(self.scheduler, wid, nprocs, child_ends, parent_ends,
                      self._rings),
                daemon=True, name=f"shard-worker-{wid}")
            proc.start()
            self._procs.append(proc)
        for c in child_ends:
            c.close()
        if self._rings:
            for wid, pair in enumerate(self._rings):
                pair.req.deadcheck = pair.rsp.deadcheck = \
                    self._make_deadcheck(wid)

    def _make_deadcheck(self, wid: int):
        def check() -> None:
            if not self._procs[wid].is_alive():
                raise rings.PeerGone(wid)
        return check

    def run_round(self, tasks: list, nev: int) -> None:
        eng = self.scheduler.engine
        comps = eng._components
        nprocs = self.processes
        msgs = self._msgs
        msgs.clear()
        for ctx in tasks:
            group = (ctx.group_id, ctx.window_end,
                     _encode_entries(ctx._adopted, eng))
            msgs.setdefault(ctx.group_id % nprocs, []).append(group)
        ctxs = {ctx.group_id: ctx for ctx in tasks}
        # All ctxs of a wave share one horizons list (None under a
        # global-barrier scheduler); ship it once per worker message.
        horizons = tasks[0].horizons
        pending = self._pending_blobs
        for wid, groups in msgs.items():
            self._send(wid, ("R", groups, pending.pop(wid, ()), horizons))
        for wid in msgs:
            reply = self._recv(wid)
            if reply[0] == "E":
                raise RuntimeError(
                    f"executor worker {wid} failed:\n{reply[1]}")
            for dst_wid, seq, blob, count in reply[2]:
                pending.setdefault(dst_wid, []).append(
                    (wid, seq, blob, count))
            for sid, executed, max_time, posts in reply[1]:
                ctx = ctxs[sid]
                ctx.executed = executed
                ctx.max_time = max_time
                ctx.posts = [
                    (stamp, idx,
                     Event(t, comps[rank], kind,
                           None if pref is None else _Ref(pref)))
                    for stamp, idx, (t, rank, kind, pref) in posts]

    def finalize(self, failed: bool = False) -> None:
        try:
            if not failed and self._conns:
                self._collect()
        finally:
            for wid in range(len(self._conns)):
                try:
                    self._send(wid, ("Q",))
                except (OSError, RuntimeError):
                    pass
            for proc in self._procs:
                proc.join(timeout=5)
                if proc.is_alive():       # pragma: no cover - defensive
                    proc.terminate()
            for conn in self._conns:
                conn.close()
            if self._rings:
                for pair in self._rings:
                    pair.close()
                    pair.unlink()
            self._procs = []
            self._conns = []
            self._rings = None

    def _collect(self) -> None:
        """Sync shard-resident state (and mergeable engine hooks) back
        onto the parent replica, then materialize any payload
        references still queued (a partial run leaves beyond-horizon
        events in the parent queue whose payloads die with the
        workers)."""
        eng = self.scheduler.engine
        comps = eng._components
        for wid in range(len(self._conns)):
            self._send(wid, ("C",))
        caches: dict = {}                 # wid -> leftover local cache
        blob_items: dict = {}             # (src wid, seq) -> payload list
        for wid in range(len(self._conns)):
            msg = wire.loads(self._recv_raw(wid), eng)
            if msg[0] == "E":
                raise RuntimeError(
                    f"executor worker {wid} failed during state "
                    f"collection:\n{msg[1]}")
            _, state, hooks, comp_hooks, local, blobs = msg
            caches[wid] = local
            blob_items.update(blobs)
            for rank, item_state in state.items():
                comps[rank].apply_shard_state(item_state)
            for i, hook in hooks:
                eng._hooks[i].merge_shard(hook)
            for rank, i, hook in comp_hooks:
                comps[rank]._hooks[i].merge_shard(hook)
        # Blobs the parent was still holding for routing decode here.
        for pending in self._pending_blobs.values():
            for src, seq, blob, count in pending:
                blob_items[(src, seq)] = wire.loads(blob, eng)
        self._pending_blobs.clear()
        self._materialize_refs(eng, caches, blob_items)

    def _materialize_refs(self, eng, caches: dict, blob_items: dict) -> None:
        """Replace worker-cache payload references on still-queued
        events with the shipped-back objects (decoded against the
        parent replica, so a future run re-encodes them normally)."""
        nprocs = self.processes
        for shard in eng.queue._shards:
            for entry in shard:
                ev = entry[4]
                p = ev.payload
                if type(p) is not _Ref:
                    continue
                ref = p.ref
                if ref[0] == "L":
                    wid = ev.component.cluster_id % nprocs
                    ev.payload = caches[wid].pop(ref[1])
                else:                     # ("B", src wid, seq, idx)
                    ev.payload = blob_items[(ref[1], ref[2])][ref[3]]

    # -- transport helpers -------------------------------------------------
    def _send(self, wid: int, msg) -> None:
        if self._rings is not None:
            try:
                self._rings[wid].req.send_bytes(_plain_dumps(msg))
            except rings.PeerGone:
                self._died(wid)
            return
        try:
            self._conns[wid].send_bytes(_plain_dumps(msg))
        except OSError:
            self._died(wid)

    def _recv(self, wid: int):
        return _plain_loads(self._recv_raw(wid))

    def _recv_raw(self, wid: int) -> bytes:
        if self._rings is not None:
            try:
                return self._rings[wid].rsp.recv_bytes()
            except rings.PeerGone:
                self._died(wid)
        try:
            return self._conns[wid].recv_bytes()
        except (EOFError, OSError):
            self._died(wid)

    def _died(self, wid: int):
        proc = self._procs[wid]
        proc.join(timeout=1)
        raise RuntimeError(
            f"executor worker {wid} (pid {proc.pid}) died mid-run "
            f"(exit code {proc.exitcode}); simulation state for its "
            f"shards is lost -- rerun with executor='threads' to debug "
            f"the failing handler in-process")

    def describe(self) -> dict:
        return {"name": self.name, "max_workers": self.max_workers,
                "processes": self.processes, "transport": self.transport}


def _encode_entries(entries, eng) -> list:
    """Window entries -> wire tuples.  ``gen`` is dropped (globally
    queued entries always carry generation 0); worker-born payloads
    pass through as references, parent-born ones are wire-encoded."""
    out = []
    for e in entries:
        ev = e[4]
        p = ev.payload
        if p is None:
            pref = None
        elif type(p) is _Ref:
            pref = p.ref
        else:
            pref = ("P", wire.dumps(p, eng))
        out.append((e[0], e[2], e[3], ev.kind, pref))
    return out


register_executor("procs", ProcExecutor)
