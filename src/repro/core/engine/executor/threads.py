"""Thread-pool executor -- the compatibility default.

Exactly PR 4's pool, relocated behind the executor interface: a
long-lived ``ThreadPoolExecutor`` with sticky ``cluster_id %
max_workers`` buckets, so a cluster always executes on the same worker
thread and its components never migrate.  State stays in the one shared
address space, which is what lets the scheduler keep its adaptive
merged / degenerate inline paths (``inline_rounds = True``).

The pool only engages when the round is wide enough to amortize the
~100us dispatch (``pool_min_events``) AND spans more than one bucket;
narrower grouped rounds run inline on the scheduler thread.  Under
CPython's GIL pure-Python handlers gain nothing physical from the pool
either way -- the regime where threads *do* scale is GIL-releasing
handlers / free-threaded builds; for real cores today use
``executor="procs"``.
"""
from __future__ import annotations

import concurrent.futures

from .base import Executor, register_executor


def _run_chunk(chunk) -> None:
    for ctx in chunk:
        ctx.execute()


class ThreadExecutor(Executor):
    name = "threads"
    inline_rounds = True

    def __init__(self, max_workers: int = 4) -> None:
        super().__init__(max_workers)
        self._pool = None
        self._buckets: list = []

    def prepare(self, ctxs: list) -> None:
        self._buckets = [[] for _ in range(max(1, self.max_workers))]

    def run_round(self, tasks: list, nev: int) -> None:
        sched = self.scheduler
        nworkers = self.max_workers
        if (sched.use_pool and nworkers > 1 and len(tasks) > 1
                and nev >= sched.pool_min_events):
            if self._pool is None:
                self._pool = concurrent.futures.ThreadPoolExecutor(nworkers)
            buckets = self._buckets
            for b in buckets:
                b.clear()
            for ctx in tasks:           # sticky cluster -> worker
                buckets[ctx.group_id % nworkers].append(ctx)
            list(self._pool.map(_run_chunk, [b for b in buckets if b]))
        else:
            for ctx in tasks:
                ctx.execute()

    def finalize(self, failed: bool = False) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    def describe(self) -> dict:
        return {"name": self.name, "max_workers": self.max_workers}


register_executor("threads", ThreadExecutor)
