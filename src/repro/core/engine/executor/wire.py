"""Wire format shared by process-backed executors.

Events, requests and handler payloads freely reference simulation
objects -- components, connections, ports, the engine itself.  Shipping
them between the scheduler process and a shard worker must NOT copy
that graph: both sides hold a structurally identical replica (the
worker is forked from the parent after ``compute_clusters``), so a
reference is encoded as a *coordinate* into the replica:

* a registered item  -> its ``rank`` (``Engine.register`` order);
* a port             -> ``(owner rank, port name)``;
* the engine         -> a singleton tag.

Everything else in a payload (``Request`` envelopes, ``_Xmit`` routing
stubs, plain tuples/dataclasses) is serialized by value -- payloads are
small, and cross-boundary *identity* of those values is never load
bearing: by the component rules (DP-2/DP-3) a handler only reaches
other components through requests, and requests address their
destination explicitly by reference (here: by rank).

``dumps``/``loads`` are the only entry points; both take the engine
whose replica anchors the coordinates.  Payload bytes produced against
one replica decode against any other replica of the same engine, so a
worker-pickled payload blob can be routed through the parent and
delivered to a different worker untouched -- the parent never decodes
payloads it only forwards (see the reference protocol in
``executor.procs``: ``_Ref`` stubs plus per-destination blob bytes).
"""
from __future__ import annotations

import io
import pickle

from ...component import Port, Registered


class _WirePickler(pickle.Pickler):
    def __init__(self, file, engine) -> None:
        super().__init__(file, protocol=pickle.HIGHEST_PROTOCOL)
        self._engine = engine

    def persistent_id(self, obj):
        # ``obj.engine is not None`` distinguishes a *registered* item
        # (rank is meaningful) from a loose instance, which serializes
        # by value like any other object.
        if isinstance(obj, Registered) and obj.engine is not None:
            return ("r", obj.rank)
        if isinstance(obj, Port):
            return ("p", obj.owner.rank, obj.name)
        if obj is self._engine:
            return ("e",)
        return None


class _WireUnpickler(pickle.Unpickler):
    def __init__(self, file, engine) -> None:
        super().__init__(file)
        self._engine = engine

    def persistent_load(self, pid):
        tag = pid[0]
        if tag == "r":
            return self._engine._components[pid[1]]
        if tag == "p":
            return self._engine._components[pid[1]].port(pid[2])
        if tag == "e":
            return self._engine
        raise pickle.UnpicklingError(f"unknown persistent id {pid!r}")


def dumps(obj, engine) -> bytes:
    buf = io.BytesIO()
    _WirePickler(buf, engine).dump(obj)
    return buf.getvalue()


def loads(data: bytes, engine):
    return _WireUnpickler(io.BytesIO(data), engine).load()


def plain_dumps(obj) -> bytes:
    """Protocol-envelope encoding: plain pickle for messages that by
    construction carry only ints/floats/strings/bytes/tuples (round
    framing, horizons, pre-encoded payload blobs) -- never simulation
    references.  One definition so the pipe and shared-memory ring
    transports speak byte-identical frames."""
    return pickle.dumps(obj, pickle.HIGHEST_PROTOCOL)


def plain_loads(data: bytes):
    return pickle.loads(data)
