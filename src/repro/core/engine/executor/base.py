"""Executor interface + registry (mirrors the scheduler/fabric ones).

A :class:`~repro.core.engine.base.RoundScheduler` decides *what* runs
each round -- the window, the per-cluster grouping, the commit order.
An :class:`Executor` decides *where* the grouped work runs:

* ``threads`` -- the compatibility default: a ``ThreadPoolExecutor``
  with sticky ``cluster_id % max_workers`` buckets.  Correct always;
  under CPython's GIL pure-Python handlers gain no physical speedup.
* ``procs``   -- one long-lived worker *process* per bucket.  Each
  cluster's components are shard-resident: handlers run on the worker's
  replica (real cores, no GIL), and only compact per-round messages
  cross the boundary -- window event entries in, ``(commit stamps,
  beyond-window posts, cross-cluster sends)`` out.  See
  ``repro.core.engine.executor.procs``.

A third backend is one :func:`register_executor` call away (see
docs/engine.md, "Executors").
"""
from __future__ import annotations

import typing


class Executor:
    """Strategy object that runs one round's grouped cluster contexts.

    Lifecycle: the scheduler resolves its ``executor_spec`` in
    ``prepare()`` (one executor instance per ``run``), calls
    :meth:`prepare` once, :meth:`run_round` once per grouped round, and
    :meth:`finalize` in the run's ``finally`` block.

    ``inline_rounds`` declares whether the scheduler thread may execute
    events itself (the adaptive merged / degenerate serial-equivalent
    paths).  Executors with shard-resident state must say ``False``:
    every handler activation has to happen where the component's
    authoritative state lives, so *all* rounds -- however narrow --
    route through :meth:`run_round`.
    """

    name = "abstract"
    inline_rounds = True

    def __init__(self, max_workers: int = 4) -> None:
        self.max_workers = max_workers
        self.scheduler = None

    def bind(self, scheduler) -> "Executor":
        self.scheduler = scheduler
        return self

    def prepare(self, ctxs: list) -> None:
        """Called once per run, after clusters + contexts exist."""

    def run_round(self, tasks: list, nev: int) -> None:
        """Execute one grouped round: every context in ``tasks`` has
        adopted its window slice (``ctx.begin``); on return each must
        carry ``executed`` / ``max_time`` / ``posts`` exactly as
        ``_GroupCtx.execute`` leaves them."""
        raise NotImplementedError

    def finalize(self, failed: bool = False) -> None:
        """Tear down after a run.  ``failed`` is True when the run is
        unwinding an exception -- skip result collection, just release
        resources."""

    def describe(self) -> dict:
        return {"name": self.name}


EXECUTORS: dict = {}


def register_executor(name: str, factory) -> None:
    """Make ``Engine(executor=name)`` resolve to ``factory(max_workers=N)``."""
    EXECUTORS[name] = factory


def make_executor(spec, max_workers: int = 4) -> Executor:
    """Resolve an executor name (or pass through an instance)."""
    if isinstance(spec, Executor):
        return spec
    try:
        factory = EXECUTORS[spec]
    except KeyError:
        raise ValueError(f"unknown executor {spec!r}; "
                         f"available: {sorted(EXECUTORS)}") from None
    return factory(max_workers=max_workers)
