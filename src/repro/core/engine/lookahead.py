"""LookaheadScheduler -- conservative PDES with an auto-derived window.

Executes *all* events in ``[t, t + lookahead)`` concurrently, not just
exact timestamp ties.  Safety argument (classic conservative parallel
discrete-event simulation, cf. ACALSim / Huerta 2025):

* Components are partitioned into *clusters*: a connection whose send
  path is zero-latency or mutates shared state fuses with its endpoint
  owners, and components declaring a shared ``cluster_affinity`` fuse
  with each other (``Engine.compute_clusters`` -- the event fabric uses
  affinity to make each chip's DMA engine + ICI links one cluster while
  its latency-carrying bus keeps distinct chips, the pod DCN/bisection
  links and the controller un-fused).  Within a cluster execution is
  sequential in (time, rank, seq) order -- exactly serial's relative
  order for those components.
* Across clusters, events can only be created by ``Connection.send``,
  which posts both the deliver event and the destination's request event
  ``transfer_time >= min_latency_ps`` in the future.  With ``lookahead =
  min over non-fused connections of min_latency_ps``, no event executed
  inside the window can target another cluster before the window ends --
  so clusters cannot observe each other mid-window and any execution
  interleaving yields the serial result.
* The commit phase orders newly created events by the serial post-order
  stamp, so global seq assignment (the last tie-break) matches serial.

A cross-cluster post inside the window raises ``RuntimeError`` rather
than silently corrupting determinism.
"""
from __future__ import annotations

from .base import RoundScheduler, register_scheduler

_INF = float("inf")


class LookaheadScheduler(RoundScheduler):
    name = "lookahead"
    use_pool = True
    strict_window = True
    record_window_widths = True
    # In-window events a cluster schedules for itself run locally; the
    # cluster fusion of zero-latency connections keeps that serial-ordered.
    defer_all_posts = False

    def __init__(self, max_workers: int = 4, lookahead_ps: int = None) -> None:
        super().__init__(max_workers)
        self.lookahead_ps = lookahead_ps    # None -> derive from topology
        self.window_ps = None               # resolved at run() time

    def prepare(self) -> None:
        super().prepare()                   # clusters + sharded queue + ctxs
        if self.lookahead_ps is not None:
            self.window_ps = self.lookahead_ps
        else:
            auto = self.engine.min_cross_cluster_latency_ps()
            # No cross-cluster channel => clusters never interact and the
            # window is unbounded; a zero/negative derivation degrades to
            # one-tick windows (same-timestamp batches).
            self.window_ps = (None if auto is None else max(1, auto))

    def window_end(self, t: int):
        return _INF if self.window_ps is None else t + self.window_ps

    def describe(self) -> dict:
        d = super().describe()
        d["window_ps"] = self.window_ps
        return d


register_scheduler("lookahead", LookaheadScheduler)


class BoundedLagScheduler(LookaheadScheduler):
    """Lookahead without the global round barrier.

    Identical conservative-PDES safety story, but the one topology-wide
    window (``min over non-fused connections of min_latency_ps``) is
    replaced by per-cluster horizons derived from the *cluster graph*
    (``Engine.cluster_graph``): a cluster only synchronizes with the
    clusters it actually exchanges events with, so decoupled subsystems
    -- distinct tenants, compute islands between collectives, separate
    pods -- advance independently instead of paying one global sync
    point per window tick.  Bit-identity to serial is kept by staging
    cross-wave posts with their serial post-order stamps and assigning
    seqs only at each shard's flush (see
    ``RoundScheduler._run_bounded``).

    ``lookahead_ps`` is ignored in this mode: the per-edge latencies in
    the cluster graph *are* the lookahead, edge by edge.
    """

    name = "bounded"
    bounded_lag = True


register_scheduler("bounded", BoundedLagScheduler)
