"""SerialScheduler -- the determinism oracle.

Processes events strictly in ``(time, component_rank, seq)`` order with
no pending buffers, no worker pool and no commit phase: every post goes
straight onto the global queue and receives its seq immediately.  This
is the reference semantics every other scheduler must reproduce
bit-identically (asserted by ``tests/test_sim_engine.py``).

The run loop installs a guarded direct-push sink (``guarded_push``):
in-thread posts keep the "cannot schedule into the past" causality
assert but skip the foreign-post lock (serial execution is
single-threaded by definition).  Note the engine's thread contract is
unchanged by this: ``Engine.post`` from a foreign thread is safe
against *other foreign threads* (the ``_post_lock`` fallback) and
against an idle engine, but has never been safe concurrent with an
actively draining run -- the run loop's pops do not take the lock, in
any scheduler, so mid-run foreign posting was and is unsupported
(post, then run -- see the foreign-thread stress tests).
"""
from __future__ import annotations

from .base import Scheduler, guarded_push, register_scheduler


class SerialScheduler(Scheduler):
    name = "serial"

    def run(self, until_ps: int = None) -> int:
        eng = self.engine
        queue = eng.queue
        tls = eng._tls
        prev_sink = getattr(tls, "sink", None)
        tls.sink = guarded_push(eng, queue)
        try:
            while queue:
                t = queue.peek_time()
                if until_ps is not None and t > until_ps:
                    break
                eng.now = t
                batch = queue.pop_batch()
                eng.batch_widths.append(len(batch))
                for ev in batch:
                    eng._handle_one(ev)
                eng.events_processed += len(batch)
        finally:
            tls.sink = prev_sink
        return eng.now


register_scheduler("serial", SerialScheduler)
