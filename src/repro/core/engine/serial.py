"""SerialScheduler -- the determinism oracle.

Processes events strictly in ``(time, component_rank, seq)`` order with
no pending buffers, no worker pool and no commit phase: every post goes
straight onto the global queue and receives its seq immediately.  This
is the reference semantics every other scheduler must reproduce
bit-identically (asserted by ``tests/test_sim_engine.py``).
"""
from __future__ import annotations

from .base import Scheduler, register_scheduler


class SerialScheduler(Scheduler):
    name = "serial"

    def run(self, until_ps: int = None) -> int:
        eng = self.engine
        queue = eng.queue
        while queue:
            t = queue.peek_time()
            if until_ps is not None and t > until_ps:
                break
            eng.now = t
            batch = queue.pop_batch()
            eng.batch_widths.append(len(batch))
            for ev in batch:
                eng._handle_one(ev)
            eng.events_processed += len(batch)
        return eng.now


register_scheduler("serial", SerialScheduler)
