"""Engine core + pluggable scheduler interface.

The engine owns the registered components, the global event queue, the
hook lists and the simulation clock; *how* events are drained is the job
of a :class:`Scheduler`.  Three ship with the repo:

* ``serial``     -- strict (time, rank, seq) order; the determinism oracle
  (:mod:`repro.core.engine.serial`).
* ``batch``      -- the paper's DP-5 conservative scheme: all events at
  the earliest timestamp run concurrently, grouped per component
  (:mod:`repro.core.engine.batch`).
* ``lookahead``  -- conservative PDES with a safe time window derived
  from the minimum cross-cluster connection latency; exploits
  parallelism even when per-component timestamps diverge
  (:mod:`repro.core.engine.lookahead`).

All three must produce bit-identical simulation results; the parametrized
determinism tests in ``tests/test_sim_engine.py`` assert it.  A fourth
scheduler is one :func:`register_scheduler` call away (see
``docs/engine.md``).

Thread-safety contract: during a round, worker threads post events
through a thread-local sink owned by the worker's own group context --
no shared mutable state.  Posts from *foreign* threads (or outside a
round) fall back to the global queue under ``_post_lock``; engine-level
hooks always fire under ``_hook_lock``.

Hot-path design (the allocation-lean event core):

* Events are ``__slots__`` objects stamped in place -- no
  ``dataclasses.replace`` copy per push.
* Registered items are guaranteed to carry ``rank`` / ``cluster_id`` /
  ``fault_failed`` (class-level defaults on Component/Connection), so
  dispatch reads plain attributes, never ``getattr`` fallbacks.
* Hook dispatch is gated on the cached ``hooks_active`` flag: a
  hook-free event pays one predicate check instead of four
  ``invoke_hooks`` calls.
* Round schedulers swap the engine's queue for a
  :class:`~repro.core.event.ShardedEventQueue` (one shard per cluster):
  windows pop per shard, already partitioned and sorted, and the commit
  phase routes posts per destination shard -- only *cross-cluster*
  traffic is ever merged, and then only with the posts of that one
  shard (see the seq-locality argument on ``ShardedEventQueue``).
* Per-cluster :class:`_GroupCtx` objects and the executor backend live
  for the whole ``run`` (reset, not reallocated, each round), with
  sticky ``cluster_id % workers`` worker assignment.

Round schedulers split *what* runs (window, grouping, commit order --
this module) from *where* it runs (an :class:`~repro.core.engine
.executor.Executor` backend): ``executor="threads"`` is the in-process
pool, ``executor="procs"`` pins each cluster to a long-lived worker
process with shard-resident component state.  See
:mod:`repro.core.engine.executor`.
"""
from __future__ import annotations

import threading
import typing
import warnings

from heapq import heapify as _heapify, heappop as _heappop, \
    heappush as _heappush

from ..connection import LagNode
from ..event import Event, EventQueue, LocalQueue, ShardedEventQueue
from ..hooks import Hookable, EVENT_START, EVENT_END
from .executor import make_executor

_INF = float("inf")                         # unbounded window / idle cluster


class LagGraph:
    """The bounded-lag synchronization graph at node granularity.

    The first ``n_clusters`` node indices are the clusters themselves
    (index == cluster id, base = the cluster's earliest pending event);
    indices beyond that are :class:`~repro.core.connection.LagNode`
    refinements whose base is the earliest pending event matching the
    node's predicate (``inf`` when nothing matches).  ``out`` feeds the
    earliest-input-time relaxation; ``horizon_in[c]`` holds only the
    *inter-cluster* in-edges that bound cluster ``c``'s horizon --
    intra-cluster node edges (e.g. a link's queued-transfer node to its
    in-flight node) participate in the relaxation but are not horizons
    themselves.
    """

    __slots__ = ("n_clusters", "n_nodes", "nodes_cluster", "out",
                 "horizon_in", "pred_scans", "plain_nodes")

    def __init__(self, n_clusters, nodes_cluster, out, horizon_in,
                 pred_scans, plain_nodes) -> None:
        self.n_clusters = n_clusters
        self.n_nodes = len(nodes_cluster)
        self.nodes_cluster = nodes_cluster  # node index -> cluster id
        self.out = out                      # node -> [(node, lat)]
        self.horizon_in = horizon_in        # cluster -> [(node, lat)]
        self.pred_scans = pred_scans        # [(cluster, [(node, pred)])]
        self.plain_nodes = plain_nodes      # pred-less nodes: [(node, cluster)]


def guarded_push(engine: "Engine", queue) -> typing.Callable:
    """A post sink that pushes straight onto ``queue`` (no foreign-post
    lock -- the caller's thread owns the run) while keeping the
    "cannot schedule into the past" causality assert.  Reads the clock
    through the thread-local directly, skipping the ``Engine.now``
    property on the hot path."""
    tls = engine._tls
    push = queue.push

    def sink(event: Event) -> None:
        t = getattr(tls, "now", None)
        assert event.time >= (engine._now_global if t is None else t), \
            "cannot schedule into the past"
        push(event)

    return sink


# -- scheduler interface + registry -----------------------------------------

class Scheduler:
    """Strategy object that drains an :class:`Engine`'s event queue.

    Subclasses implement :meth:`run`; they may assume exclusive use of
    the bound engine for the duration of the call.  ``run`` returns the
    timestamp of the last executed event (the simulation end time).
    """

    name = "abstract"

    def __init__(self, max_workers: int = 4) -> None:
        self.max_workers = max_workers
        self.engine: "Engine" = None

    def bind(self, engine: "Engine") -> "Scheduler":
        self.engine = engine
        return self

    def run(self, until_ps: int = None) -> int:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"name": self.name, "max_workers": self.max_workers}


SCHEDULERS: dict = {}


def register_scheduler(name: str, factory) -> None:
    """Make ``Engine(scheduler=name)`` resolve to ``factory(max_workers=N)``."""
    SCHEDULERS[name] = factory


def make_scheduler(spec, max_workers: int = 4, executor=None) -> Scheduler:
    """Resolve a scheduler name (or pass through an instance).

    ``executor`` (name or :class:`~repro.core.engine.executor.Executor`
    instance) selects where round schedulers run grouped work; ``None``
    keeps the scheduler's default (``"threads"``).  The serial
    scheduler executes in-thread and ignores it.
    """
    if isinstance(spec, Scheduler):
        sched = spec
    else:
        try:
            factory = SCHEDULERS[spec]
        except KeyError:
            raise ValueError(f"unknown scheduler {spec!r}; "
                             f"available: {sorted(SCHEDULERS)}") from None
        sched = factory(max_workers=max_workers)
    if executor is not None:
        sched.executor_spec = executor
    return sched


# -- engine ------------------------------------------------------------------

class Engine(Hookable):
    def __init__(self, parallel: bool = False, max_workers: int = 4,
                 scheduler=None, executor=None) -> None:
        super().__init__()
        if parallel:
            warnings.warn(
                "Engine(parallel=True) is deprecated; pass "
                "scheduler='batch' (or 'lookahead') instead",
                DeprecationWarning, stacklevel=2)
        self.queue = EventQueue()
        self._now_global = 0
        self._tls = threading.local()
        self.parallel = parallel            # legacy knob; maps to 'batch'
        self.max_workers = max_workers
        self._components: list = []
        self._post_lock = threading.Lock()
        self._hook_lock = threading.RLock()
        self.events_processed = 0
        self.batch_widths: list = []        # events per execution round
        self.window_widths: list = []       # filled by windowed schedulers
        self.round_group_sizes: list = []   # per-round (cluster, events)
                                            # pairs (only when the scheduler
                                            # sets record_group_sizes; feeds
                                            # the architectural-speedup model
                                            # in benchmarks/fabric_contention)
        if scheduler is None:
            scheduler = "batch" if parallel else "serial"
        self.scheduler = make_scheduler(scheduler, max_workers=max_workers,
                                        executor=executor).bind(self)

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> int:
        """Current simulation time.

        Inside an event handler this is the handled event's timestamp
        (thread-local, so concurrently executing groups each see their
        own local time); outside handlers it is the global clock.
        """
        t = getattr(self._tls, "now", None)
        return self._now_global if t is None else t

    @now.setter
    def now(self, value: int) -> None:
        self._now_global = value

    # -- registration ---------------------------------------------------------
    def register(self, item) -> typing.Any:
        """Register a component or connection; assigns deterministic rank.

        Every registered item is guaranteed a ``rank`` (and a
        ``cluster_id`` once a windowed scheduler runs), so queue and
        dispatch code reads them as plain attributes.
        """
        item.engine = self
        item.rank = len(self._components)
        self._components.append(item)
        return item

    # -- scheduling ------------------------------------------------------------
    def post(self, event: Event) -> None:
        # Sink paths guard against past-time posts themselves (the group
        # contexts assert against the executing event's timestamp), so
        # the hot path pays no ``self.now`` read per post.
        sink = getattr(self._tls, "sink", None)
        if sink is not None:
            sink(event)                     # this worker's own group context
        else:
            assert event.time >= self.now, "cannot schedule into the past"
            with self._post_lock:           # foreign thread / outside a round
                self.queue.push(event)

    # -- hooks ------------------------------------------------------------------
    def invoke_hooks(self, position: str, time: int, item) -> None:
        """Engine-level hooks are shared across worker threads -> locked."""
        if not self.hooks_active:
            return
        with self._hook_lock:
            Hookable.invoke_hooks(self, position, time, item)

    # -- execution ----------------------------------------------------------------
    def _handle_one(self, event: Event) -> None:
        """Run one event's handler with the clock pinned to its timestamp.

        The hook-free fast path (the overwhelmingly common case) is a
        single flag check; any attached hook -- engine- or
        component-level -- routes through the original four-position
        dispatch so tracers and fault injectors observe every event.
        """
        comp = event.component
        tls = self._tls
        prev = getattr(tls, "now", None)
        tls.now = event.time
        try:
            if self.hooks_active or comp.hooks_active:
                self._handle_hooked(event, comp)
            elif not comp.fault_failed:
                if event.kind != "notify_available":
                    comp.handle(event)
                else:
                    # DP-6 wake posted by a capacity-limited connection;
                    # dispatched to the dedicated callback so components
                    # need not pattern-match it inside handle().
                    comp.notify_available(event.payload)
            elif event.kind == "notify_available":
                # the waiter died holding a slot reservation: hand it back
                event.payload.reclaim(comp)
        finally:
            tls.now = prev

    def _handle_hooked(self, event: Event, comp) -> None:
        """Slow path: at least one hook observes this event."""
        self.invoke_hooks(EVENT_START, event.time, event)
        comp.invoke_hooks(EVENT_START, event.time, event)
        if not comp.fault_failed:
            if event.kind == "notify_available":
                comp.notify_available(event.payload)
            else:
                comp.handle(event)
        elif event.kind == "notify_available":
            event.payload.reclaim(comp)
        comp.invoke_hooks(EVENT_END, event.time, event)
        self.invoke_hooks(EVENT_END, event.time, event)

    def run(self, until_ps: int = None) -> int:
        """Drain the queue (or run past ``until_ps``) via the scheduler."""
        return self.scheduler.run(until_ps)

    # -- topology analysis (used by windowed schedulers) ---------------------
    def compute_clusters(self) -> typing.List[int]:
        """Partition registered items into sequential clusters.

        Two fusion rules feed one union-find:

        * A connection is *fused* with all its endpoint owners when its
          send path can create same-time cross-component events (zero
          latency) or mutates shared state senders race on
          (LinkConnection occupancy, attached hooks --
          ``Connection.stateful_send``).
        * Components sharing a non-None ``cluster_affinity`` key are
          fused with each other.  Affinity lets a subsystem declare its
          own sequential islands without wiring artificial zero-latency
          connections -- the event fabric groups each chip's DMA engine
          with that chip's four ICI links this way, so the dominant
          DMA<->own-link traffic stays intra-cluster while distinct
          chips (and the pod DCN/bisection links) parallelize.

        Components inside one cluster must execute sequentially; distinct
        clusters only interact through >= min-latency connections, which
        is what makes the lookahead window safe (fusing more is always
        safe, only slower).

        Returns cluster id per rank and annotates each registered item
        with ``item.cluster_id`` (also its event-queue shard).
        """
        n = len(self._components)
        parent = list(range(n))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        self._fused_connections: set = set()
        affinity_root: dict = {}
        for item in self._components:
            aff = item.cluster_affinity
            if aff is not None:
                union(affinity_root.setdefault(aff, item.rank), item.rank)
            endpoints = getattr(item, "endpoints", None)
            if endpoints is None:
                continue                    # not a connection
            zero_lat = getattr(item, "min_latency_ps", 0) <= 0
            if zero_lat or getattr(item, "stateful_send", False):
                self._fused_connections.add(item.rank)
                for port in endpoints:
                    union(item.rank, port.owner.rank)

        # normalize to dense ids ordered by lowest member rank
        ids: dict = {}
        clusters = []
        for rank in range(n):
            root = find(rank)
            cid = ids.setdefault(root, len(ids))
            clusters.append(cid)
            self._components[rank].cluster_id = cid
        return clusters

    def min_cross_cluster_latency_ps(self) -> typing.Optional[int]:
        """Smallest delay a non-fused connection can impose on a send.

        This is the auto-derived lookahead window: no event executed at
        time t can create a cross-cluster event before ``t + window``.
        ``None`` means no cross-cluster channels exist at all (the window
        is unbounded -- clusters never interact).
        """
        fused = getattr(self, "_fused_connections", set())
        best = None
        for item in self._components:
            if getattr(item, "endpoints", None) is None:
                continue
            if item.rank in fused:
                continue                    # intra-cluster only
            lat = getattr(item, "min_latency_ps", 0)
            if best is None or lat < best:
                best = lat
        return best

    def cluster_graph(self) -> "LagGraph":
        """Directed min-latency graph between clusters -- the bounded-lag
        synchronization graph.

        Where :meth:`min_cross_cluster_latency_ps` collapses the whole
        topology into one number (the global-barrier window), this keeps
        the structure: each non-fused connection declares which cluster
        pairs it can actually carry events between and at what minimum
        delay (:meth:`~repro.core.connection.Connection.cluster_edges`;
        shared buses override the clique default with their routing
        graph).  A cluster's safe horizon is then derived from its
        *in-neighbors* only, so clusters that never exchange events do
        not synchronize at all.

        Edge endpoints may be :class:`~repro.core.connection.LagNode`
        refinements: extra graph nodes covering only the events matching
        the node's predicate, so a connection can promise different
        minimum delays for different event classes within one cluster
        (see the :class:`FabricXbar` link queue/wire split).

        Must be called after :meth:`compute_clusters`.  Parallel edges
        collapse to their minimum; *inter-cluster* latencies clamp to
        >= 1 tick so every horizon strictly exceeds its inputs (progress
        guarantee), while intra-cluster node edges may carry 0.
        """
        fused = getattr(self, "_fused_connections", set())
        ncl = 0
        for item in self._components:
            if item.cluster_id >= ncl:
                ncl = item.cluster_id + 1
        nodes_cluster = list(range(ncl))    # default node per cluster
        preds: list = [None] * ncl
        node_ix: dict = {}                  # id(LagNode) -> node index
        inherit: list = []                  # (node, cluster, author rank)
        edges: list = []                    # (u, v, lat, author rank)

        def resolve(end, author):
            if not isinstance(end, LagNode):
                return end
            ix = node_ix.get(id(end))
            if ix is None:
                ix = len(nodes_cluster)
                node_ix[id(end)] = ix
                nodes_cluster.append(end.cluster)
                preds.append(end.pred)
                if end.inherit_inputs:
                    inherit.append((ix, end.cluster, author))
            return ix

        for item in self._components:
            if getattr(item, "endpoints", None) is None:
                continue
            if item.rank in fused:
                continue
            for src, dst, lat in item.cluster_edges():
                u = resolve(src, item.rank)
                v = resolve(dst, item.rank)
                if u != v:
                    edges.append((u, v, lat, item.rank))
        # A gate node only filters traffic its own connection understands;
        # whatever *other* connections aim at its cluster it must receive
        # unfiltered (copied onto the node, authorship-excluded).
        for ix, cluster, author in inherit:
            edges.extend((u, ix, lat, a) for (u, v, lat, a) in tuple(edges)
                         if v == cluster and a != author)
        best: dict = {}
        for u, v, lat, _a in edges:
            if nodes_cluster[u] != nodes_cluster[v]:
                if lat < 1:
                    lat = 1
            elif lat < 0:
                lat = 0
            key = (u, v)
            cur = best.get(key)
            if cur is None or lat < cur:
                best[key] = lat
        nn = len(nodes_cluster)
        out = [[] for _ in range(nn)]
        horizon_in = [[] for _ in range(ncl)]
        for (u, v), lat in sorted(best.items()):
            out[u].append((v, lat))
            cv = nodes_cluster[v]
            if nodes_cluster[u] != cv:
                horizon_in[cv].append((u, lat))
        by_cluster: dict = {}
        plain: list = []
        for ix in range(ncl, nn):
            if preds[ix] is not None:
                by_cluster.setdefault(nodes_cluster[ix], []).append(
                    (ix, preds[ix]))
            else:
                plain.append((ix, nodes_cluster[ix]))
        return LagGraph(ncl, nodes_cluster, out, horizon_in,
                        sorted(by_cluster.items()), plain)


# -- shared round machinery ---------------------------------------------------

class _GroupCtx:
    """One cluster's execution context, reused across every round.

    Owns a local heap (the cluster's slice of the window plus events its
    handlers push back into it) and a post log whose stamps reproduce the
    order a serial engine would have posted in: (executing event's time,
    snapshot generation, rank, seq, intra-handler index) -- generation
    first among same-time events because serial runs a full snapshot
    round across *all* ranks before any of that round's delay-0 posts.
    Group execution is single-threaded, so none of this needs locks.

    The context is long-lived (allocated once per cluster in
    ``RoundScheduler.prepare``): :meth:`begin` resets it for a round by
    adopting the cluster's shard slice wholesale.
    """

    __slots__ = ("sched", "group_id", "window_end", "horizons", "local",
                 "posts", "executed", "max_time", "_adopted", "_entry",
                 "_post_idx", "_defer", "_strict")

    _IDLE_ENTRY = (0, 0, 0, 0, None)

    def __init__(self, sched: "RoundScheduler", group_id: int) -> None:
        self.sched = sched
        self.group_id = group_id
        self.window_end = 0
        # Per-cluster safe horizons of the current bounded-lag wave
        # (shared list, indexed by cluster id); None under a global
        # barrier, where every cluster shares this context's window_end.
        self.horizons = None
        self.local = LocalQueue()           # in-window posts only (side heap)
        self.posts: list = []               # (entry stamp, idx, event)
        self.executed = 0
        self.max_time = 0
        self._adopted: list = []            # this round's shard slice
        self._entry = self._IDLE_ENTRY      # executing event's heap entry
        self._post_idx = 0
        self._defer = sched.defer_all_posts
        self._strict = sched.strict_window

    def begin(self, window_end, entries: list) -> None:
        """Reset for a new round, adopting the cluster's popped shard
        slice (ascending (time, gen, rank, seq, event) entries).  The
        slice is *iterated in place* during :meth:`execute`; only events
        handlers push back into the window go through the side heap, so
        the common no-local-post round re-pops nothing.

        ``_post_idx`` resets per round, not per event: the commit stamp
        (entry, idx) only ever tie-breaks posts of the *same* executing
        event, so any monotonic idx sequence within the round works.
        """
        self.window_end = window_end
        self._adopted = entries
        self.local.clear()
        self.max_time = 0
        self._post_idx = 0

    def post(self, event: Event) -> None:
        assert event.time >= self._entry[0], "cannot schedule into the past"
        idx = self._post_idx
        self._post_idx = idx + 1
        if event.time < self.window_end:    # in-window: local or unsafe
            if (not self._defer
                    and event.component.cluster_id == self.group_id):
                # Same-timestamp posts inherit creator generation + 1 so
                # they wait for the next snapshot round, like serial;
                # later timestamps start fresh at generation 0.  No stamp
                # needed: local events never reach the commit phase.
                e = self._entry
                self.local.push_new(
                    event, generation=e[1] + 1 if event.time == e[0] else 0)
                return
            if (self._strict
                    and event.component.cluster_id != self.group_id):
                # Global barrier: any cross-cluster post inside the
                # shared window is unsafe.  Bounded lag: unsafe only
                # below the *target's* horizon -- own-window arrival is
                # legitimate when the target lags behind this cluster.
                h = self.horizons
                if h is None or event.time < h[event.component.cluster_id]:
                    self._unsafe_post(event)
        elif self._strict and self.horizons is not None:
            # Beyond own window but possibly below the target's horizon:
            # reachable only through an edge the connection failed to
            # declare in ``cluster_edges`` (or a send cheating its own
            # ``min_latency_ps``) -- fail loudly, never corrupt.
            cid = event.component.cluster_id
            if cid != self.group_id and event.time < self.horizons[cid]:
                self._unsafe_post(event)
        # The executing event's heap entry doubles as the post stamp:
        # (entry, idx) sorts exactly like the serial post order
        # (time, gen, rank, seq, intra-handler index), and the tuple
        # comparison can never reach the entry's event field because
        # seqs are unique -- zero allocations beyond the triple.
        self.posts.append((self._entry, idx, event))

    def _unsafe_post(self, event: Event) -> None:
        h = self.horizons
        bound = (self.window_end if h is None
                 else h[event.component.cluster_id])
        raise RuntimeError(
            f"lookahead safety violation: {event!r} targets another "
            f"cluster before its safe horizon {bound}; route "
            "cross-component traffic through a Connection with latency "
            ">= the engine's lookahead window (and with the edge "
            "declared in cluster_edges under bounded lag)")

    def execute(self) -> "_GroupCtx":
        """Drain the round: a two-stream merge of the adopted slice
        (iterated by index -- it is already sorted) against the
        side heap of events handlers push back into the window.  The
        stream pick compares raw entry tuples; local seqs live above
        ``LOCAL_SEQ_BASE`` so the comparison never reaches the event.

        Event dispatch is inlined (the body of ``Engine._handle_one``)
        with the thread-local clock and sink managed once per round
        instead of once per event -- with ~2-3 events per cluster per
        round, the per-activation wrappers would otherwise rival the
        handlers themselves.
        """
        eng = self.sched.engine
        tls = eng._tls
        prev_sink = getattr(tls, "sink", None)
        prev_now = getattr(tls, "now", None)
        tls.sink = self.post
        hooked = eng._handle_hooked
        adopted = self._adopted
        n_adopted = len(adopted)
        side = self.local._heap
        pop = _heappop
        entry = None
        i = 0
        n = 0
        try:
            while True:
                if side:
                    if i < n_adopted and adopted[i] < side[0]:
                        entry = adopted[i]
                        i += 1
                    else:
                        entry = pop(side)
                elif i < n_adopted:
                    entry = adopted[i]
                    i += 1
                else:
                    break
                self._entry = entry
                ev = entry[4]
                comp = ev.component
                tls.now = entry[0]
                # eng.hooks_active is re-read per event (not hoisted):
                # a handler may attach an engine hook mid-round, and
                # serial would observe the remaining events with it
                if eng.hooks_active or comp.hooks_active:
                    hooked(ev, comp)
                elif not comp.fault_failed:
                    if ev.kind != "notify_available":
                        comp.handle(ev)
                    else:
                        comp.notify_available(ev.payload)
                elif ev.kind == "notify_available":
                    ev.payload.reclaim(comp)
                n += 1
        finally:
            self.executed = n
            if n:
                self.max_time = entry[0]    # merge order => the maximum
            tls.sink = prev_sink
            tls.now = prev_now
        return self


class RoundScheduler(Scheduler):
    """Round-based scheduler: pop a window per shard, run groups, commit.

    Grouping is always by engine cluster (``compute_clusters``; the
    event queue is sharded the same way), so a cluster's window slice
    pops straight out of its own shard.  Subclasses choose the window
    width (:meth:`window_end`); ``use_pool`` turns on parallel worker
    dispatch.  *Where* a grouped round's contexts execute is delegated
    to a pluggable executor backend (``executor_spec``, default
    ``"threads"``; see :mod:`repro.core.engine.executor`) -- this class
    only selects the execution *mode* per round (merged serial-
    equivalent vs grouped) and runs the commit.  The commit phase
    pushes newly created events per destination shard in serial post
    order (stamp order), so all same-(time, rank) tie-breaks -- the
    only place seq is ever consulted -- are identical to serial
    execution, whichever executor ran the round.
    """

    use_pool = False
    strict_window = False
    record_window_widths = False
    # Bounded-lag mode: drop the global round barrier and give every
    # cluster its own conservative horizon derived from the cluster
    # graph (``Engine.cluster_graph``).  ``run`` then dispatches to
    # :meth:`_run_bounded` -- per-cluster windows, stamp-staged commit
    # with seq assignment deferred to each shard's flush.
    bounded_lag = False
    # Executor backend (name or instance) resolved in ``prepare``.  The
    # "threads" default keeps state in-process, which is what allows
    # the adaptive merged/degenerate inline paths below; backends with
    # shard-resident state (``"procs"``) declare ``inline_rounds =
    # False`` and receive every round, however narrow.
    executor_spec = "threads"
    executor = None                         # bound instance, set by prepare
    # Record per-round (cluster id, events) pairs into
    # ``engine.round_group_sizes`` -- the input to the architectural
    # (critical-path) speedup model benchmarks report.  Off by default:
    # long runs would accumulate one tuple per round.
    record_group_sizes = False
    # One-tick windows must defer even same-group posts to the commit
    # phase: a same-time post from a *lower-rank* group (e.g. a
    # zero-latency connection's request) would otherwise be committed
    # while the target group already ran its own same-time self-posts
    # locally -- inverting serial's seq order between the two.  Windowed
    # schedulers instead fuse zero-latency connections into the target's
    # cluster, which keeps in-window local execution serial-ordered.
    defer_all_posts = True
    # Rounds smaller than this run inline on the scheduler thread: pool
    # dispatch costs a fixed ~100us per round, so scattering a dozen
    # events across workers is pure overhead (and under CPython's GIL,
    # pure-Python handlers gain nothing physical from the pool anyway).
    # The pool engages only when a round is wide enough to amortize the
    # dispatch -- the regime where GIL-releasing handlers /
    # free-threaded builds actually scale.
    pool_min_events = 256

    def window_end(self, t: int):
        return t + 1                        # one integer-ps tick

    def group_of(self, component) -> int:
        """The sequential-execution group (== queue shard) of a
        component.  Always its engine cluster."""
        return component.cluster_id

    def prepare(self) -> None:
        """Called once per ``run``: derive clusters, shard the queue,
        build the persistent per-cluster contexts and bring up the
        executor backend."""
        eng = self.engine
        self._cluster_of = eng.compute_clusters()
        nshards = max(1, (max(self._cluster_of) + 1) if self._cluster_of
                      else 1)
        eng.queue = ShardedEventQueue.from_queue(eng.queue, nshards)
        self._ctxs = [_GroupCtx(self, gid) for gid in range(nshards)]
        self._merged = _MergedCtx(self, -1)
        self._merged.push_global = eng.queue.push
        self._commit: list = []             # reused per-round post buffer
        if self.bounded_lag:
            self._lag_graph = eng.cluster_graph()
            self._staged = [[] for _ in range(nshards)]
            self._horizons = [0] * nshards
        self.executor = make_executor(self.executor_spec,
                                      max_workers=self.max_workers)
        self.executor.bind(self)
        self.executor.prepare(self._ctxs)

    def run(self, until_ps: int = None) -> int:
        if self.bounded_lag:
            return self._run_bounded(until_ps)
        eng = self.engine
        self.prepare()
        queue = eng.queue
        ctxs = self._ctxs
        commit = self._commit
        executor = self.executor
        # Only executors whose state lives in this process may let the
        # scheduler thread execute events itself (the merged/degenerate
        # serial-equivalent paths); shard-resident backends must see
        # every round, however narrow.
        inline_ok = executor.inline_rounds
        pool_min = self.pool_min_events
        record_widths = self.record_window_widths
        record_groups = self.record_group_sizes
        tls = eng._tls
        serial_sink = guarded_push(eng, queue)
        # Execution-mode predictor: rounds narrower than pool_min_events
        # run serial-equivalent (merged / degenerate), wider rounds run
        # grouped on the executor.  The mode must be chosen before the
        # pop, so the previous round's width predicts the next -- safe
        # because BOTH modes are bit-exact; a mispredict only costs
        # speed, and the predictor corrects itself on the very next
        # round.
        prefer_merged = inline_ok and pool_min > 1 and not record_groups
        failed = True
        try:
            while queue:
                t = queue.peek_time()
                if until_ps is not None and t > until_ps:
                    break
                eng.now = t
                wend = self.window_end(t)
                if until_ps is not None:
                    wend = min(wend, until_ps + 1)

                if prefer_merged:
                    merged = queue.pop_window_merged(wend)
                    nev = len(merged)
                    prefer_merged = nev < pool_min
                    if nev == 1:
                        # Degenerate: the sink pushes posts straight onto
                        # the (sharded) global queue in post order --
                        # exactly serial semantics.
                        ev = merged[0][4]
                        prev_sink = getattr(tls, "sink", None)
                        tls.sink = serial_sink
                        try:
                            eng._handle_one(ev)
                        finally:
                            tls.sink = prev_sink
                        eng.events_processed += 1
                        eng.batch_widths.append(1)
                        if record_widths:
                            eng.window_widths.append(1)
                        eng.now = ev.time
                        continue
                    # Merged round: ONE group spanning every cluster --
                    # the machinery's base case, serial-equivalent by
                    # construction (see _MergedCtx); beyond-window posts
                    # push themselves straight onto the sharded queue.
                    ctx = self._merged
                    ctx.begin(wend, merged)
                    ctx.execute()
                    eng.events_processed += ctx.executed
                    eng.batch_widths.append(ctx.executed)
                    if record_widths:
                        eng.window_widths.append(ctx.executed)
                    eng.now = ctx.max_time if ctx.max_time > t else t
                    continue

                popped, nev = queue.pop_window_sharded(wend)
                prefer_merged = (inline_ok and nev < pool_min
                                 and not record_groups)

                tasks = []
                for sid, entries in popped:
                    ctx = ctxs[sid]
                    ctx.begin(wend, entries)
                    tasks.append(ctx)

                executor.run_round(tasks, nev)

                executed = 0
                tmax = t
                for ctx in tasks:
                    executed += ctx.executed
                    if ctx.max_time > tmax:
                        tmax = ctx.max_time
                eng.events_processed += executed
                eng.batch_widths.append(executed)
                if record_widths:
                    eng.window_widths.append(executed)
                if record_groups:
                    eng.round_group_sizes.append(
                        tuple((ctx.group_id, ctx.executed)
                              for ctx in tasks))

                # Commit: push this round's posts in serial post (stamp)
                # order.  Each context's log is already stamp-sorted (its
                # execution is sequential), so the combined commit is
                # C-level bulk work: extend the runs together, one
                # near-linear Timsort merge, then push -- ``queue.push``
                # routes each event to its cluster's shard, where the
                # stamp order becomes the same-(time, rank) seq order
                # serial would have produced.  With a single contributing
                # context the sort is skipped outright.
                sources = 0
                for ctx in tasks:
                    if ctx.posts:
                        sources += 1
                        commit.extend(ctx.posts)
                        ctx.posts.clear()
                if commit:
                    if sources > 1:
                        # (entry, idx, event) triples sort by entry then
                        # idx -- the serial post order; seq uniqueness
                        # means the comparison never reaches the event
                        commit.sort()
                    push = queue.push
                    for p in commit:
                        push(p[2])
                    commit.clear()
                eng.now = tmax
            failed = False
        finally:
            executor.finalize(failed=failed)
        return eng.now

    # -- bounded lag ----------------------------------------------------------
    def _compute_horizons(self, lvt: list) -> list:
        """Per-cluster safe execution horizons for one wave.

        ``lvt[i]`` is cluster i's earliest pending event time (shard
        head or staged in-flight post; ``inf`` when idle).  The classic
        conservative earliest-input-time relaxation runs a multi-source
        shortest path over the cluster graph::

            eit[i] = min(lvt[i], min over in-edges j->i of eit[j] + L)

        which bounds, transitively through idle clusters, the earliest
        time *any* chain of future events could make cluster i execute.
        Cluster i may then safely run every event strictly below::

            H[i] = min over in-edges j->i of (eit[j] + L[j->i])

        because an event posted by cluster j executing at ``tau >=
        eit[j]`` arrives at ``tau + L >= H[i]`` -- nothing can appear
        inside the window being executed.  The globally earliest
        cluster always gets ``H > lvt`` (inter-cluster latencies are
        >= 1), so every wave makes progress; clusters with no in-edges
        are unbounded.

        The relaxation runs over the *node-level* graph: beyond the
        per-cluster default nodes (base = lvt), connections may have
        declared predicate-refined nodes whose base is the earliest
        pending event *matching the predicate* -- a link's in-flight
        serialization vs. its still-queued transfer requests, the
        controller's non-completion inputs.  Pred bases come from one
        read-only scan of the owning shard's heap plus its staged
        posts, done only for clusters that declared refinements.
        """
        g = self._lag_graph
        ncl = g.n_clusters
        eit = list(lvt)
        if g.n_nodes > ncl:
            eit.extend(_INF for _ in range(ncl, g.n_nodes))
            for ix, cid in g.plain_nodes:   # pred-less waypoints
                eit[ix] = lvt[cid]
            shards = self.engine.queue._shards  # read-only heap scan
            staged = self._staged
            for cid, members in g.pred_scans:
                for e in shards[cid]:
                    t, ev = e[0], e[4]
                    for ix, pred in members:
                        if t < eit[ix] and pred(ev):
                            eit[ix] = t
                for p in staged[cid]:
                    ev = p[2]
                    t = ev.time
                    for ix, pred in members:
                        if t < eit[ix] and pred(ev):
                            eit[ix] = t
        out_edges = g.out
        heap = [(t, i) for i, t in enumerate(eit) if t != _INF]
        _heapify(heap)
        while heap:
            d, i = _heappop(heap)
            if d > eit[i]:
                continue
            for j, lat in out_edges[i]:
                nd = d + lat
                if nd < eit[j]:
                    eit[j] = nd
                    _heappush(heap, (nd, j))
        horizons = self._horizons
        for i, edges in enumerate(g.horizon_in):
            h = _INF
            for j, lat in edges:
                b = eit[j] + lat
                if b < h:
                    h = b
            horizons[i] = h
        return horizons

    def _run_bounded(self, until_ps: int = None) -> int:
        """Bounded-lag drain: per-cluster windows, no global barrier.

        Each wave computes every cluster's horizon, then runs *all*
        clusters with work below their horizon concurrently -- a
        decoupled cluster may advance far beyond the global floor while
        a laggard catches up, synchronizing only with the clusters it
        actually exchanges events with.

        Bit-identity is preserved by deferring seq assignment: a wave's
        beyond-window / cross-cluster posts are *staged* per destination
        shard still carrying only their serial post-order stamps, and a
        shard's staged posts are flushed (stamp-sorted, seqs assigned,
        pushed) only once the shard's horizon passes their arrival time
        -- at which point conservatism guarantees every same-(time,
        rank) competitor has already been staged, so per-shard seq order
        equals serial's.  Cross-shard seq skew is unobservable (the
        seq-locality argument on ``ShardedEventQueue``).

        The merged / degenerate inline paths are structurally disabled:
        they assign seqs at post time, which is only serial-equivalent
        when all clusters share one floor.  Narrow waves instead run
        grouped-inline on the executor (the thread backend executes
        small rounds on the scheduler thread anyway).
        """
        eng = self.engine
        self.prepare()
        queue = eng.queue
        ctxs = self._ctxs
        staged = self._staged
        executor = self.executor
        record_widths = self.record_window_widths
        record_groups = self.record_group_sizes
        nsh = len(ctxs)
        shard_head = queue.shard_head_time
        pop_shard = queue.pop_shard_window
        push = queue.push
        lvt = [0] * nsh
        now_max = eng._now_global
        failed = True
        try:
            while True:
                floor = _INF
                for sid in range(nsh):
                    t = shard_head(sid)
                    t = _INF if t is None else t
                    for p in staged[sid]:
                        pt = p[2].time
                        if pt < t:
                            t = pt
                    lvt[sid] = t
                    if t < floor:
                        floor = t
                if floor == _INF:
                    break
                if until_ps is not None and floor > until_ps:
                    break
                eng.now = floor
                horizons = self._compute_horizons(lvt)
                if until_ps is not None:
                    cap = until_ps + 1
                    for i in range(nsh):
                        if horizons[i] > cap:
                            horizons[i] = cap

                tasks = []
                nev = 0
                for sid in range(nsh):
                    hzn = horizons[sid]
                    s = staged[sid]
                    if s:
                        due = [p for p in s if p[2].time < hzn]
                        if due:
                            if len(due) == len(s):
                                s.clear()
                            else:
                                s[:] = [p for p in s if p[2].time >= hzn]
                            due.sort()  # stamp order == serial seq order
                            for p in due:
                                push(p[2])
                    entries = pop_shard(sid, hzn)
                    if entries:
                        ctx = ctxs[sid]
                        ctx.begin(hzn, entries)
                        ctx.horizons = horizons
                        tasks.append(ctx)
                        nev += len(entries)
                assert tasks, "bounded-lag wave made no progress"

                executor.run_round(tasks, nev)

                executed = 0
                tmax = floor
                for ctx in tasks:
                    executed += ctx.executed
                    if ctx.max_time > tmax:
                        tmax = ctx.max_time
                eng.events_processed += executed
                eng.batch_widths.append(executed)
                if record_widths:
                    eng.window_widths.append(executed)
                if record_groups:
                    eng.round_group_sizes.append(
                        tuple((ctx.group_id, ctx.executed)
                              for ctx in tasks))

                # Stage (don't push) this wave's posts per destination
                # shard; the flush above assigns seqs when it is safe.
                for ctx in tasks:
                    posts = ctx.posts
                    if posts:
                        for p in posts:
                            staged[p[2].component.cluster_id].append(p)
                        posts.clear()
                if tmax > now_max:
                    now_max = tmax
            failed = False
        finally:
            # Return undelivered staged posts to the queue so pending
            # state is all queue-resident (partial runs resume; the
            # procs backend materializes payload refs off the queue).
            # Safe: at exit nothing executed past ``until_ps`` and
            # every future stamp exceeds the ones flushed here.
            rem = []
            for s in staged:
                rem.extend(s)
                s.clear()
            if rem:
                rem.sort()
                for p in rem:
                    push(p[2])
            executor.finalize(failed=failed)
        eng.now = now_max
        return now_max

    def describe(self) -> dict:
        d = super().describe()
        d["executor"] = (self.executor.describe() if self.executor
                         is not None else self.executor_spec)
        d["bounded_lag"] = self.bounded_lag
        return d


class _MergedCtx(_GroupCtx):
    """Whole-window context for rounds too narrow to pay for grouping.

    One group containing *every* cluster is the base case of the round
    machinery: all in-window posts are same-group, so the LocalQueue's
    generation bookkeeping reproduces serial's snapshot rounds exactly
    and no cross-group commit-order hazard exists -- execution is
    serial-equivalent by construction.  Because a single group's
    execution order *is* the serial post order, beyond-window posts
    skip the commit log entirely and push straight onto the sharded
    queue -- seq assignment at post time equals what a stamp-ordered
    commit would produce.  The unsafe-post guard is structural here:
    with nothing running concurrently there is no determinism to
    corrupt (set ``pool_min_events = 0`` to force grouped execution
    when the diagnostic guard itself is wanted).
    """

    __slots__ = ("push_global",)

    def __init__(self, sched: "RoundScheduler", group_id: int) -> None:
        super().__init__(sched, group_id)
        self.push_global = None             # bound queue.push, set by prepare

    def post(self, event: Event) -> None:
        e = self._entry
        assert event.time >= e[0], "cannot schedule into the past"
        if event.time < self.window_end:
            self.local.push_new(
                event, generation=e[1] + 1 if event.time == e[0] else 0)
        else:
            self.push_global(event)


