"""Engine core + pluggable scheduler interface.

The engine owns the registered components, the global event queue, the
hook lists and the simulation clock; *how* events are drained is the job
of a :class:`Scheduler`.  Three ship with the repo:

* ``serial``     -- strict (time, rank, seq) order; the determinism oracle
  (:mod:`repro.core.engine.serial`).
* ``batch``      -- the paper's DP-5 conservative scheme: all events at
  the earliest timestamp run concurrently, grouped per component
  (:mod:`repro.core.engine.batch`).
* ``lookahead``  -- conservative PDES with a safe time window derived
  from the minimum cross-cluster connection latency; exploits
  parallelism even when per-component timestamps diverge
  (:mod:`repro.core.engine.lookahead`).

All three must produce bit-identical simulation results; the parametrized
determinism tests in ``tests/test_sim_engine.py`` assert it.  A fourth
scheduler is one :func:`register_scheduler` call away (see
``docs/engine.md``).

Thread-safety contract: during a round, worker threads post events
through a thread-local sink owned by the worker's own group context --
no shared mutable state.  Posts from *foreign* threads (or outside a
round) fall back to the global queue under ``_post_lock``; engine-level
hooks always fire under ``_hook_lock``.

Hot-path design (the allocation-lean event core):

* Events are ``__slots__`` objects stamped in place -- no
  ``dataclasses.replace`` copy per push.
* Registered items are guaranteed to carry ``rank`` / ``cluster_id`` /
  ``fault_failed`` (class-level defaults on Component/Connection), so
  dispatch reads plain attributes, never ``getattr`` fallbacks.
* Hook dispatch is gated on the cached ``hooks_active`` flag: a
  hook-free event pays one predicate check instead of four
  ``invoke_hooks`` calls.
* Round schedulers swap the engine's queue for a
  :class:`~repro.core.event.ShardedEventQueue` (one shard per cluster):
  windows pop per shard, already partitioned and sorted, and the commit
  phase routes posts per destination shard -- only *cross-cluster*
  traffic is ever merged, and then only with the posts of that one
  shard (see the seq-locality argument on ``ShardedEventQueue``).
* Per-cluster :class:`_GroupCtx` objects and the executor backend live
  for the whole ``run`` (reset, not reallocated, each round), with
  sticky ``cluster_id % workers`` worker assignment.

Round schedulers split *what* runs (window, grouping, commit order --
this module) from *where* it runs (an :class:`~repro.core.engine
.executor.Executor` backend): ``executor="threads"`` is the in-process
pool, ``executor="procs"`` pins each cluster to a long-lived worker
process with shard-resident component state.  See
:mod:`repro.core.engine.executor`.
"""
from __future__ import annotations

import threading
import typing
import warnings

from heapq import heappop as _heappop

from ..event import Event, EventQueue, LocalQueue, ShardedEventQueue
from ..hooks import Hookable, EVENT_START, EVENT_END
from .executor import make_executor


def guarded_push(engine: "Engine", queue) -> typing.Callable:
    """A post sink that pushes straight onto ``queue`` (no foreign-post
    lock -- the caller's thread owns the run) while keeping the
    "cannot schedule into the past" causality assert.  Reads the clock
    through the thread-local directly, skipping the ``Engine.now``
    property on the hot path."""
    tls = engine._tls
    push = queue.push

    def sink(event: Event) -> None:
        t = getattr(tls, "now", None)
        assert event.time >= (engine._now_global if t is None else t), \
            "cannot schedule into the past"
        push(event)

    return sink


# -- scheduler interface + registry -----------------------------------------

class Scheduler:
    """Strategy object that drains an :class:`Engine`'s event queue.

    Subclasses implement :meth:`run`; they may assume exclusive use of
    the bound engine for the duration of the call.  ``run`` returns the
    timestamp of the last executed event (the simulation end time).
    """

    name = "abstract"

    def __init__(self, max_workers: int = 4) -> None:
        self.max_workers = max_workers
        self.engine: "Engine" = None

    def bind(self, engine: "Engine") -> "Scheduler":
        self.engine = engine
        return self

    def run(self, until_ps: int = None) -> int:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"name": self.name, "max_workers": self.max_workers}


SCHEDULERS: dict = {}


def register_scheduler(name: str, factory) -> None:
    """Make ``Engine(scheduler=name)`` resolve to ``factory(max_workers=N)``."""
    SCHEDULERS[name] = factory


def make_scheduler(spec, max_workers: int = 4, executor=None) -> Scheduler:
    """Resolve a scheduler name (or pass through an instance).

    ``executor`` (name or :class:`~repro.core.engine.executor.Executor`
    instance) selects where round schedulers run grouped work; ``None``
    keeps the scheduler's default (``"threads"``).  The serial
    scheduler executes in-thread and ignores it.
    """
    if isinstance(spec, Scheduler):
        sched = spec
    else:
        try:
            factory = SCHEDULERS[spec]
        except KeyError:
            raise ValueError(f"unknown scheduler {spec!r}; "
                             f"available: {sorted(SCHEDULERS)}") from None
        sched = factory(max_workers=max_workers)
    if executor is not None:
        sched.executor_spec = executor
    return sched


# -- engine ------------------------------------------------------------------

class Engine(Hookable):
    def __init__(self, parallel: bool = False, max_workers: int = 4,
                 scheduler=None, executor=None) -> None:
        super().__init__()
        if parallel:
            warnings.warn(
                "Engine(parallel=True) is deprecated; pass "
                "scheduler='batch' (or 'lookahead') instead",
                DeprecationWarning, stacklevel=2)
        self.queue = EventQueue()
        self._now_global = 0
        self._tls = threading.local()
        self.parallel = parallel            # legacy knob; maps to 'batch'
        self.max_workers = max_workers
        self._components: list = []
        self._post_lock = threading.Lock()
        self._hook_lock = threading.RLock()
        self.events_processed = 0
        self.batch_widths: list = []        # events per execution round
        self.window_widths: list = []       # filled by windowed schedulers
        self.round_group_sizes: list = []   # per-round (cluster, events)
                                            # pairs (only when the scheduler
                                            # sets record_group_sizes; feeds
                                            # the architectural-speedup model
                                            # in benchmarks/fabric_contention)
        if scheduler is None:
            scheduler = "batch" if parallel else "serial"
        self.scheduler = make_scheduler(scheduler, max_workers=max_workers,
                                        executor=executor).bind(self)

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> int:
        """Current simulation time.

        Inside an event handler this is the handled event's timestamp
        (thread-local, so concurrently executing groups each see their
        own local time); outside handlers it is the global clock.
        """
        t = getattr(self._tls, "now", None)
        return self._now_global if t is None else t

    @now.setter
    def now(self, value: int) -> None:
        self._now_global = value

    # -- registration ---------------------------------------------------------
    def register(self, item) -> typing.Any:
        """Register a component or connection; assigns deterministic rank.

        Every registered item is guaranteed a ``rank`` (and a
        ``cluster_id`` once a windowed scheduler runs), so queue and
        dispatch code reads them as plain attributes.
        """
        item.engine = self
        item.rank = len(self._components)
        self._components.append(item)
        return item

    # -- scheduling ------------------------------------------------------------
    def post(self, event: Event) -> None:
        # Sink paths guard against past-time posts themselves (the group
        # contexts assert against the executing event's timestamp), so
        # the hot path pays no ``self.now`` read per post.
        sink = getattr(self._tls, "sink", None)
        if sink is not None:
            sink(event)                     # this worker's own group context
        else:
            assert event.time >= self.now, "cannot schedule into the past"
            with self._post_lock:           # foreign thread / outside a round
                self.queue.push(event)

    # -- hooks ------------------------------------------------------------------
    def invoke_hooks(self, position: str, time: int, item) -> None:
        """Engine-level hooks are shared across worker threads -> locked."""
        if not self.hooks_active:
            return
        with self._hook_lock:
            Hookable.invoke_hooks(self, position, time, item)

    # -- execution ----------------------------------------------------------------
    def _handle_one(self, event: Event) -> None:
        """Run one event's handler with the clock pinned to its timestamp.

        The hook-free fast path (the overwhelmingly common case) is a
        single flag check; any attached hook -- engine- or
        component-level -- routes through the original four-position
        dispatch so tracers and fault injectors observe every event.
        """
        comp = event.component
        tls = self._tls
        prev = getattr(tls, "now", None)
        tls.now = event.time
        try:
            if self.hooks_active or comp.hooks_active:
                self._handle_hooked(event, comp)
            elif not comp.fault_failed:
                if event.kind != "notify_available":
                    comp.handle(event)
                else:
                    # DP-6 wake posted by a capacity-limited connection;
                    # dispatched to the dedicated callback so components
                    # need not pattern-match it inside handle().
                    comp.notify_available(event.payload)
            elif event.kind == "notify_available":
                # the waiter died holding a slot reservation: hand it back
                event.payload.reclaim(comp)
        finally:
            tls.now = prev

    def _handle_hooked(self, event: Event, comp) -> None:
        """Slow path: at least one hook observes this event."""
        self.invoke_hooks(EVENT_START, event.time, event)
        comp.invoke_hooks(EVENT_START, event.time, event)
        if not comp.fault_failed:
            if event.kind == "notify_available":
                comp.notify_available(event.payload)
            else:
                comp.handle(event)
        elif event.kind == "notify_available":
            event.payload.reclaim(comp)
        comp.invoke_hooks(EVENT_END, event.time, event)
        self.invoke_hooks(EVENT_END, event.time, event)

    def run(self, until_ps: int = None) -> int:
        """Drain the queue (or run past ``until_ps``) via the scheduler."""
        return self.scheduler.run(until_ps)

    # -- topology analysis (used by windowed schedulers) ---------------------
    def compute_clusters(self) -> typing.List[int]:
        """Partition registered items into sequential clusters.

        Two fusion rules feed one union-find:

        * A connection is *fused* with all its endpoint owners when its
          send path can create same-time cross-component events (zero
          latency) or mutates shared state senders race on
          (LinkConnection occupancy, attached hooks --
          ``Connection.stateful_send``).
        * Components sharing a non-None ``cluster_affinity`` key are
          fused with each other.  Affinity lets a subsystem declare its
          own sequential islands without wiring artificial zero-latency
          connections -- the event fabric groups each chip's DMA engine
          with that chip's four ICI links this way, so the dominant
          DMA<->own-link traffic stays intra-cluster while distinct
          chips (and the pod DCN/bisection links) parallelize.

        Components inside one cluster must execute sequentially; distinct
        clusters only interact through >= min-latency connections, which
        is what makes the lookahead window safe (fusing more is always
        safe, only slower).

        Returns cluster id per rank and annotates each registered item
        with ``item.cluster_id`` (also its event-queue shard).
        """
        n = len(self._components)
        parent = list(range(n))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        self._fused_connections: set = set()
        affinity_root: dict = {}
        for item in self._components:
            aff = item.cluster_affinity
            if aff is not None:
                union(affinity_root.setdefault(aff, item.rank), item.rank)
            endpoints = getattr(item, "endpoints", None)
            if endpoints is None:
                continue                    # not a connection
            zero_lat = getattr(item, "min_latency_ps", 0) <= 0
            if zero_lat or getattr(item, "stateful_send", False):
                self._fused_connections.add(item.rank)
                for port in endpoints:
                    union(item.rank, port.owner.rank)

        # normalize to dense ids ordered by lowest member rank
        ids: dict = {}
        clusters = []
        for rank in range(n):
            root = find(rank)
            cid = ids.setdefault(root, len(ids))
            clusters.append(cid)
            self._components[rank].cluster_id = cid
        return clusters

    def min_cross_cluster_latency_ps(self) -> typing.Optional[int]:
        """Smallest delay a non-fused connection can impose on a send.

        This is the auto-derived lookahead window: no event executed at
        time t can create a cross-cluster event before ``t + window``.
        ``None`` means no cross-cluster channels exist at all (the window
        is unbounded -- clusters never interact).
        """
        fused = getattr(self, "_fused_connections", set())
        best = None
        for item in self._components:
            if getattr(item, "endpoints", None) is None:
                continue
            if item.rank in fused:
                continue                    # intra-cluster only
            lat = getattr(item, "min_latency_ps", 0)
            if best is None or lat < best:
                best = lat
        return best


# -- shared round machinery ---------------------------------------------------

class _GroupCtx:
    """One cluster's execution context, reused across every round.

    Owns a local heap (the cluster's slice of the window plus events its
    handlers push back into it) and a post log whose stamps reproduce the
    order a serial engine would have posted in: (executing event's time,
    snapshot generation, rank, seq, intra-handler index) -- generation
    first among same-time events because serial runs a full snapshot
    round across *all* ranks before any of that round's delay-0 posts.
    Group execution is single-threaded, so none of this needs locks.

    The context is long-lived (allocated once per cluster in
    ``RoundScheduler.prepare``): :meth:`begin` resets it for a round by
    adopting the cluster's shard slice wholesale.
    """

    __slots__ = ("sched", "group_id", "window_end", "local", "posts",
                 "executed", "max_time", "_adopted", "_entry", "_post_idx",
                 "_defer", "_strict")

    _IDLE_ENTRY = (0, 0, 0, 0, None)

    def __init__(self, sched: "RoundScheduler", group_id: int) -> None:
        self.sched = sched
        self.group_id = group_id
        self.window_end = 0
        self.local = LocalQueue()           # in-window posts only (side heap)
        self.posts: list = []               # (entry stamp, idx, event)
        self.executed = 0
        self.max_time = 0
        self._adopted: list = []            # this round's shard slice
        self._entry = self._IDLE_ENTRY      # executing event's heap entry
        self._post_idx = 0
        self._defer = sched.defer_all_posts
        self._strict = sched.strict_window

    def begin(self, window_end, entries: list) -> None:
        """Reset for a new round, adopting the cluster's popped shard
        slice (ascending (time, gen, rank, seq, event) entries).  The
        slice is *iterated in place* during :meth:`execute`; only events
        handlers push back into the window go through the side heap, so
        the common no-local-post round re-pops nothing.

        ``_post_idx`` resets per round, not per event: the commit stamp
        (entry, idx) only ever tie-breaks posts of the *same* executing
        event, so any monotonic idx sequence within the round works.
        """
        self.window_end = window_end
        self._adopted = entries
        self.local.clear()
        self.max_time = 0
        self._post_idx = 0

    def post(self, event: Event) -> None:
        assert event.time >= self._entry[0], "cannot schedule into the past"
        idx = self._post_idx
        self._post_idx = idx + 1
        if event.time < self.window_end:    # in-window: local or unsafe
            if (not self._defer
                    and event.component.cluster_id == self.group_id):
                # Same-timestamp posts inherit creator generation + 1 so
                # they wait for the next snapshot round, like serial;
                # later timestamps start fresh at generation 0.  No stamp
                # needed: local events never reach the commit phase.
                e = self._entry
                self.local.push_new(
                    event, generation=e[1] + 1 if event.time == e[0] else 0)
                return
            if (self._strict
                    and event.component.cluster_id != self.group_id):
                raise RuntimeError(
                    f"lookahead safety violation: {event!r} targets another "
                    f"cluster inside the window ending at {self.window_end}; "
                    "route cross-component traffic through a Connection with "
                    "latency >= the engine's lookahead window")
        # The executing event's heap entry doubles as the post stamp:
        # (entry, idx) sorts exactly like the serial post order
        # (time, gen, rank, seq, intra-handler index), and the tuple
        # comparison can never reach the entry's event field because
        # seqs are unique -- zero allocations beyond the triple.
        self.posts.append((self._entry, idx, event))

    def execute(self) -> "_GroupCtx":
        """Drain the round: a two-stream merge of the adopted slice
        (iterated by index -- it is already sorted) against the
        side heap of events handlers push back into the window.  The
        stream pick compares raw entry tuples; local seqs live above
        ``LOCAL_SEQ_BASE`` so the comparison never reaches the event.

        Event dispatch is inlined (the body of ``Engine._handle_one``)
        with the thread-local clock and sink managed once per round
        instead of once per event -- with ~2-3 events per cluster per
        round, the per-activation wrappers would otherwise rival the
        handlers themselves.
        """
        eng = self.sched.engine
        tls = eng._tls
        prev_sink = getattr(tls, "sink", None)
        prev_now = getattr(tls, "now", None)
        tls.sink = self.post
        hooked = eng._handle_hooked
        adopted = self._adopted
        n_adopted = len(adopted)
        side = self.local._heap
        pop = _heappop
        entry = None
        i = 0
        n = 0
        try:
            while True:
                if side:
                    if i < n_adopted and adopted[i] < side[0]:
                        entry = adopted[i]
                        i += 1
                    else:
                        entry = pop(side)
                elif i < n_adopted:
                    entry = adopted[i]
                    i += 1
                else:
                    break
                self._entry = entry
                ev = entry[4]
                comp = ev.component
                tls.now = entry[0]
                # eng.hooks_active is re-read per event (not hoisted):
                # a handler may attach an engine hook mid-round, and
                # serial would observe the remaining events with it
                if eng.hooks_active or comp.hooks_active:
                    hooked(ev, comp)
                elif not comp.fault_failed:
                    if ev.kind != "notify_available":
                        comp.handle(ev)
                    else:
                        comp.notify_available(ev.payload)
                elif ev.kind == "notify_available":
                    ev.payload.reclaim(comp)
                n += 1
        finally:
            self.executed = n
            if n:
                self.max_time = entry[0]    # merge order => the maximum
            tls.sink = prev_sink
            tls.now = prev_now
        return self


class RoundScheduler(Scheduler):
    """Round-based scheduler: pop a window per shard, run groups, commit.

    Grouping is always by engine cluster (``compute_clusters``; the
    event queue is sharded the same way), so a cluster's window slice
    pops straight out of its own shard.  Subclasses choose the window
    width (:meth:`window_end`); ``use_pool`` turns on parallel worker
    dispatch.  *Where* a grouped round's contexts execute is delegated
    to a pluggable executor backend (``executor_spec``, default
    ``"threads"``; see :mod:`repro.core.engine.executor`) -- this class
    only selects the execution *mode* per round (merged serial-
    equivalent vs grouped) and runs the commit.  The commit phase
    pushes newly created events per destination shard in serial post
    order (stamp order), so all same-(time, rank) tie-breaks -- the
    only place seq is ever consulted -- are identical to serial
    execution, whichever executor ran the round.
    """

    use_pool = False
    strict_window = False
    record_window_widths = False
    # Executor backend (name or instance) resolved in ``prepare``.  The
    # "threads" default keeps state in-process, which is what allows
    # the adaptive merged/degenerate inline paths below; backends with
    # shard-resident state (``"procs"``) declare ``inline_rounds =
    # False`` and receive every round, however narrow.
    executor_spec = "threads"
    executor = None                         # bound instance, set by prepare
    # Record per-round (cluster id, events) pairs into
    # ``engine.round_group_sizes`` -- the input to the architectural
    # (critical-path) speedup model benchmarks report.  Off by default:
    # long runs would accumulate one tuple per round.
    record_group_sizes = False
    # One-tick windows must defer even same-group posts to the commit
    # phase: a same-time post from a *lower-rank* group (e.g. a
    # zero-latency connection's request) would otherwise be committed
    # while the target group already ran its own same-time self-posts
    # locally -- inverting serial's seq order between the two.  Windowed
    # schedulers instead fuse zero-latency connections into the target's
    # cluster, which keeps in-window local execution serial-ordered.
    defer_all_posts = True
    # Rounds smaller than this run inline on the scheduler thread: pool
    # dispatch costs a fixed ~100us per round, so scattering a dozen
    # events across workers is pure overhead (and under CPython's GIL,
    # pure-Python handlers gain nothing physical from the pool anyway).
    # The pool engages only when a round is wide enough to amortize the
    # dispatch -- the regime where GIL-releasing handlers /
    # free-threaded builds actually scale.
    pool_min_events = 256

    def window_end(self, t: int):
        return t + 1                        # one integer-ps tick

    def group_of(self, component) -> int:
        """The sequential-execution group (== queue shard) of a
        component.  Always its engine cluster."""
        return component.cluster_id

    def prepare(self) -> None:
        """Called once per ``run``: derive clusters, shard the queue,
        build the persistent per-cluster contexts and bring up the
        executor backend."""
        eng = self.engine
        self._cluster_of = eng.compute_clusters()
        nshards = max(1, (max(self._cluster_of) + 1) if self._cluster_of
                      else 1)
        eng.queue = ShardedEventQueue.from_queue(eng.queue, nshards)
        self._ctxs = [_GroupCtx(self, gid) for gid in range(nshards)]
        self._merged = _MergedCtx(self, -1)
        self._merged.push_global = eng.queue.push
        self._commit: list = []             # reused per-round post buffer
        self.executor = make_executor(self.executor_spec,
                                      max_workers=self.max_workers)
        self.executor.bind(self)
        self.executor.prepare(self._ctxs)

    def run(self, until_ps: int = None) -> int:
        eng = self.engine
        self.prepare()
        queue = eng.queue
        ctxs = self._ctxs
        commit = self._commit
        executor = self.executor
        # Only executors whose state lives in this process may let the
        # scheduler thread execute events itself (the merged/degenerate
        # serial-equivalent paths); shard-resident backends must see
        # every round, however narrow.
        inline_ok = executor.inline_rounds
        pool_min = self.pool_min_events
        record_widths = self.record_window_widths
        record_groups = self.record_group_sizes
        tls = eng._tls
        serial_sink = guarded_push(eng, queue)
        # Execution-mode predictor: rounds narrower than pool_min_events
        # run serial-equivalent (merged / degenerate), wider rounds run
        # grouped on the executor.  The mode must be chosen before the
        # pop, so the previous round's width predicts the next -- safe
        # because BOTH modes are bit-exact; a mispredict only costs
        # speed, and the predictor corrects itself on the very next
        # round.
        prefer_merged = inline_ok and pool_min > 1 and not record_groups
        failed = True
        try:
            while queue:
                t = queue.peek_time()
                if until_ps is not None and t > until_ps:
                    break
                eng.now = t
                wend = self.window_end(t)
                if until_ps is not None:
                    wend = min(wend, until_ps + 1)

                if prefer_merged:
                    merged = queue.pop_window_merged(wend)
                    nev = len(merged)
                    prefer_merged = nev < pool_min
                    if nev == 1:
                        # Degenerate: the sink pushes posts straight onto
                        # the (sharded) global queue in post order --
                        # exactly serial semantics.
                        ev = merged[0][4]
                        prev_sink = getattr(tls, "sink", None)
                        tls.sink = serial_sink
                        try:
                            eng._handle_one(ev)
                        finally:
                            tls.sink = prev_sink
                        eng.events_processed += 1
                        eng.batch_widths.append(1)
                        if record_widths:
                            eng.window_widths.append(1)
                        eng.now = ev.time
                        continue
                    # Merged round: ONE group spanning every cluster --
                    # the machinery's base case, serial-equivalent by
                    # construction (see _MergedCtx); beyond-window posts
                    # push themselves straight onto the sharded queue.
                    ctx = self._merged
                    ctx.begin(wend, merged)
                    ctx.execute()
                    eng.events_processed += ctx.executed
                    eng.batch_widths.append(ctx.executed)
                    if record_widths:
                        eng.window_widths.append(ctx.executed)
                    eng.now = ctx.max_time if ctx.max_time > t else t
                    continue

                popped, nev = queue.pop_window_sharded(wend)
                prefer_merged = (inline_ok and nev < pool_min
                                 and not record_groups)

                tasks = []
                for sid, entries in popped:
                    ctx = ctxs[sid]
                    ctx.begin(wend, entries)
                    tasks.append(ctx)

                executor.run_round(tasks, nev)

                executed = 0
                tmax = t
                for ctx in tasks:
                    executed += ctx.executed
                    if ctx.max_time > tmax:
                        tmax = ctx.max_time
                eng.events_processed += executed
                eng.batch_widths.append(executed)
                if record_widths:
                    eng.window_widths.append(executed)
                if record_groups:
                    eng.round_group_sizes.append(
                        tuple((ctx.group_id, ctx.executed)
                              for ctx in tasks))

                # Commit: push this round's posts in serial post (stamp)
                # order.  Each context's log is already stamp-sorted (its
                # execution is sequential), so the combined commit is
                # C-level bulk work: extend the runs together, one
                # near-linear Timsort merge, then push -- ``queue.push``
                # routes each event to its cluster's shard, where the
                # stamp order becomes the same-(time, rank) seq order
                # serial would have produced.  With a single contributing
                # context the sort is skipped outright.
                sources = 0
                for ctx in tasks:
                    if ctx.posts:
                        sources += 1
                        commit.extend(ctx.posts)
                        ctx.posts.clear()
                if commit:
                    if sources > 1:
                        # (entry, idx, event) triples sort by entry then
                        # idx -- the serial post order; seq uniqueness
                        # means the comparison never reaches the event
                        commit.sort()
                    push = queue.push
                    for p in commit:
                        push(p[2])
                    commit.clear()
                eng.now = tmax
            failed = False
        finally:
            executor.finalize(failed=failed)
        return eng.now

    def describe(self) -> dict:
        d = super().describe()
        d["executor"] = (self.executor.describe() if self.executor
                         is not None else self.executor_spec)
        return d


class _MergedCtx(_GroupCtx):
    """Whole-window context for rounds too narrow to pay for grouping.

    One group containing *every* cluster is the base case of the round
    machinery: all in-window posts are same-group, so the LocalQueue's
    generation bookkeeping reproduces serial's snapshot rounds exactly
    and no cross-group commit-order hazard exists -- execution is
    serial-equivalent by construction.  Because a single group's
    execution order *is* the serial post order, beyond-window posts
    skip the commit log entirely and push straight onto the sharded
    queue -- seq assignment at post time equals what a stamp-ordered
    commit would produce.  The unsafe-post guard is structural here:
    with nothing running concurrently there is no determinism to
    corrupt (set ``pool_min_events = 0`` to force grouped execution
    when the diagnostic guard itself is wanted).
    """

    __slots__ = ("push_global",)

    def __init__(self, sched: "RoundScheduler", group_id: int) -> None:
        super().__init__(sched, group_id)
        self.push_global = None             # bound queue.push, set by prepare

    def post(self, event: Event) -> None:
        e = self._entry
        assert event.time >= e[0], "cannot schedule into the past"
        if event.time < self.window_end:
            self.local.push_new(
                event, generation=e[1] + 1 if event.time == e[0] else 0)
        else:
            self.push_global(event)


