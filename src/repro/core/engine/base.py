"""Engine core + pluggable scheduler interface.

The engine owns the registered components, the global event queue, the
hook lists and the simulation clock; *how* events are drained is the job
of a :class:`Scheduler`.  Three ship with the repo:

* ``serial``     -- strict (time, rank, seq) order; the determinism oracle
  (:mod:`repro.core.engine.serial`).
* ``batch``      -- the paper's DP-5 conservative scheme: all events at
  the earliest timestamp run concurrently, grouped per component
  (:mod:`repro.core.engine.batch`).
* ``lookahead``  -- conservative PDES with a safe time window derived
  from the minimum cross-cluster connection latency; exploits
  parallelism even when per-component timestamps diverge
  (:mod:`repro.core.engine.lookahead`).

All three must produce bit-identical simulation results; the parametrized
determinism tests in ``tests/test_sim_engine.py`` assert it.  A fourth
scheduler is one :func:`register_scheduler` call away (see
``docs/engine.md``).

Thread-safety contract: during a round, worker threads post events
through a thread-local sink owned by the worker's own group context --
no shared mutable state.  Posts from *foreign* threads (or outside a
round) fall back to the global queue under ``_post_lock``; engine-level
hooks always fire under ``_hook_lock``.
"""
from __future__ import annotations

import concurrent.futures
import threading
import typing

from ..event import Event, EventQueue, LocalQueue
from ..hooks import Hookable, EVENT_START, EVENT_END


# -- scheduler interface + registry -----------------------------------------

class Scheduler:
    """Strategy object that drains an :class:`Engine`'s event queue.

    Subclasses implement :meth:`run`; they may assume exclusive use of
    the bound engine for the duration of the call.  ``run`` returns the
    timestamp of the last executed event (the simulation end time).
    """

    name = "abstract"

    def __init__(self, max_workers: int = 4) -> None:
        self.max_workers = max_workers
        self.engine: "Engine" = None

    def bind(self, engine: "Engine") -> "Scheduler":
        self.engine = engine
        return self

    def run(self, until_ps: int = None) -> int:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"name": self.name, "max_workers": self.max_workers}


SCHEDULERS: dict = {}


def register_scheduler(name: str, factory) -> None:
    """Make ``Engine(scheduler=name)`` resolve to ``factory(max_workers=N)``."""
    SCHEDULERS[name] = factory


def make_scheduler(spec, max_workers: int = 4) -> Scheduler:
    """Resolve a scheduler name (or pass through an instance)."""
    if isinstance(spec, Scheduler):
        return spec
    try:
        factory = SCHEDULERS[spec]
    except KeyError:
        raise ValueError(f"unknown scheduler {spec!r}; "
                         f"available: {sorted(SCHEDULERS)}") from None
    return factory(max_workers=max_workers)


# -- engine ------------------------------------------------------------------

class Engine(Hookable):
    def __init__(self, parallel: bool = False, max_workers: int = 4,
                 scheduler=None) -> None:
        super().__init__()
        self.queue = EventQueue()
        self._now_global = 0
        self._tls = threading.local()
        self.parallel = parallel            # legacy knob; maps to 'batch'
        self.max_workers = max_workers
        self._components: list = []
        self._post_lock = threading.Lock()
        self._hook_lock = threading.RLock()
        self.events_processed = 0
        self.batch_widths: list = []        # events per execution round
        self.window_widths: list = []       # filled by windowed schedulers
        self.round_group_sizes: list = []   # per-round events per cluster
                                            # (only when the scheduler sets
                                            # record_group_sizes; feeds the
                                            # architectural-speedup model in
                                            # benchmarks/fabric_contention)
        if scheduler is None:
            scheduler = "batch" if parallel else "serial"
        self.scheduler = make_scheduler(scheduler,
                                        max_workers=max_workers).bind(self)

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> int:
        """Current simulation time.

        Inside an event handler this is the handled event's timestamp
        (thread-local, so concurrently executing groups each see their
        own local time); outside handlers it is the global clock.
        """
        t = getattr(self._tls, "now", None)
        return self._now_global if t is None else t

    @now.setter
    def now(self, value: int) -> None:
        self._now_global = value

    # -- registration ---------------------------------------------------------
    def register(self, item) -> typing.Any:
        """Register a component or connection; assigns deterministic rank."""
        item.engine = self
        item.rank = len(self._components)
        self._components.append(item)
        return item

    # -- scheduling ------------------------------------------------------------
    def post(self, event: Event) -> None:
        assert event.time >= self.now, "cannot schedule into the past"
        sink = getattr(self._tls, "sink", None)
        if sink is not None:
            sink(event)                     # this worker's own group context
        else:
            with self._post_lock:           # foreign thread / outside a round
                self.queue.push(event)

    # -- hooks ------------------------------------------------------------------
    def invoke_hooks(self, position: str, time: int, item) -> None:
        """Engine-level hooks are shared across worker threads -> locked."""
        if not self._hooks:
            return
        with self._hook_lock:
            super().invoke_hooks(position, time, item)

    # -- execution ----------------------------------------------------------------
    def _handle_one(self, event: Event) -> None:
        """Run one event's handler with the clock pinned to its timestamp."""
        comp = event.component
        prev = getattr(self._tls, "now", None)
        self._tls.now = event.time
        try:
            self.invoke_hooks(EVENT_START, event.time, event)
            comp.invoke_hooks(EVENT_START, event.time, event)
            if not getattr(comp, "fault_failed", False):
                if event.kind == "notify_available":
                    # DP-6 wake posted by a capacity-limited connection;
                    # dispatched to the dedicated callback so components
                    # need not pattern-match it inside handle().
                    comp.notify_available(event.payload)
                else:
                    comp.handle(event)
            elif event.kind == "notify_available":
                # the waiter died holding a slot reservation: hand it back
                event.payload.reclaim(comp)
            comp.invoke_hooks(EVENT_END, event.time, event)
            self.invoke_hooks(EVENT_END, event.time, event)
        finally:
            self._tls.now = prev

    def run(self, until_ps: int = None) -> int:
        """Drain the queue (or run past ``until_ps``) via the scheduler."""
        return self.scheduler.run(until_ps)

    # -- topology analysis (used by windowed schedulers) ---------------------
    def compute_clusters(self) -> typing.List[int]:
        """Partition registered items into sequential clusters.

        Two fusion rules feed one union-find:

        * A connection is *fused* with all its endpoint owners when its
          send path can create same-time cross-component events (zero
          latency) or mutates shared state senders race on
          (LinkConnection occupancy, attached hooks --
          ``Connection.stateful_send``).
        * Components sharing a non-None ``cluster_affinity`` key are
          fused with each other.  Affinity lets a subsystem declare its
          own sequential islands without wiring artificial zero-latency
          connections -- the event fabric groups each chip's DMA engine
          with that chip's four ICI links this way, so the dominant
          DMA<->own-link traffic stays intra-cluster while distinct
          chips (and the pod DCN/bisection links) parallelize.

        Components inside one cluster must execute sequentially; distinct
        clusters only interact through >= min-latency connections, which
        is what makes the lookahead window safe (fusing more is always
        safe, only slower).

        Returns cluster id per rank and annotates each registered item
        with ``item.cluster_id``.
        """
        n = len(self._components)
        parent = list(range(n))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        self._fused_connections: set = set()
        affinity_root: dict = {}
        for item in self._components:
            aff = getattr(item, "cluster_affinity", None)
            if aff is not None:
                union(affinity_root.setdefault(aff, item.rank), item.rank)
            endpoints = getattr(item, "endpoints", None)
            if endpoints is None:
                continue                    # not a connection
            zero_lat = getattr(item, "min_latency_ps", 0) <= 0
            if zero_lat or getattr(item, "stateful_send", False):
                self._fused_connections.add(item.rank)
                for port in endpoints:
                    union(item.rank, port.owner.rank)

        # normalize to dense ids ordered by lowest member rank
        ids: dict = {}
        clusters = []
        for rank in range(n):
            root = find(rank)
            cid = ids.setdefault(root, len(ids))
            clusters.append(cid)
            self._components[rank].cluster_id = cid
        return clusters

    def min_cross_cluster_latency_ps(self) -> typing.Optional[int]:
        """Smallest delay a non-fused connection can impose on a send.

        This is the auto-derived lookahead window: no event executed at
        time t can create a cross-cluster event before ``t + window``.
        ``None`` means no cross-cluster channels exist at all (the window
        is unbounded -- clusters never interact).
        """
        fused = getattr(self, "_fused_connections", set())
        best = None
        for item in self._components:
            if getattr(item, "endpoints", None) is None:
                continue
            if item.rank in fused:
                continue                    # intra-cluster only
            lat = getattr(item, "min_latency_ps", 0)
            if best is None or lat < best:
                best = lat
        return best


# -- shared round machinery ---------------------------------------------------

class _GroupCtx:
    """One group's execution context for a single round.

    Owns a local heap (the group's slice of the window plus events its
    handlers push back into it) and a post log whose stamps reproduce the
    order a serial engine would have posted in: (executing event's time,
    snapshot generation, rank, seq, intra-handler index) -- generation
    first among same-time events because serial runs a full snapshot
    round across *all* ranks before any of that round's delay-0 posts.
    Group execution is single-threaded, so none of this needs locks.
    """

    __slots__ = ("sched", "group_id", "window_end", "local", "posts",
                 "executed", "max_time", "_exec_key", "_exec_gen",
                 "_post_idx")

    def __init__(self, sched: "RoundScheduler", group_id: int,
                 window_end) -> None:
        self.sched = sched
        self.group_id = group_id
        self.window_end = window_end
        self.local = LocalQueue()
        self.posts: list = []               # (stamp, event)
        self.executed = 0
        self.max_time = 0
        self._exec_key = (0, 0, 0)
        self._exec_gen = 0
        self._post_idx = 0

    def post(self, event: Event) -> None:
        time, rank, seq = self._exec_key
        stamp = (time, self._exec_gen, rank, seq, self._post_idx)
        self._post_idx += 1
        if (not self.sched.defer_all_posts
                and self.sched.group_of(event.component) == self.group_id
                and event.time < self.window_end):
            # Same-timestamp posts inherit creator generation + 1 so they
            # wait for the next snapshot round, like serial; later
            # timestamps start fresh at generation 0.
            gen = self._exec_gen + 1 if event.time == time else 0
            self.local.push_new(event, generation=gen)
        else:
            if (self.sched.strict_window
                    and event.time < self.window_end
                    and self.sched.group_of(event.component) != self.group_id):
                raise RuntimeError(
                    f"lookahead safety violation: {event!r} targets another "
                    f"cluster inside the window ending at {self.window_end}; "
                    "route cross-component traffic through a Connection with "
                    "latency >= the engine's lookahead window")
            self.posts.append((stamp, event))

    def execute(self) -> "_GroupCtx":
        eng = self.sched.engine
        tls = eng._tls
        prev_sink = getattr(tls, "sink", None)
        tls.sink = self.post
        try:
            while self.local:
                gen, ev = self.local.pop()
                self._exec_key = (ev.time, getattr(ev.component, "rank", 0),
                                  ev.seq)
                self._exec_gen = gen
                self._post_idx = 0
                eng._handle_one(ev)
                self.executed += 1
                self.max_time = ev.time     # heap order => non-decreasing
        finally:
            tls.sink = prev_sink
        return self


class RoundScheduler(Scheduler):
    """Round-based executor: pop a window, run groups, commit posts.

    Subclasses choose the window width (:meth:`window_end`) and the
    grouping (:meth:`group_of`); ``use_pool`` turns on the worker pool.
    The commit phase pushes newly created events in serial post order
    (stamp order), so the global seqs -- and therefore all same-(time,
    rank) tie-breaks -- are identical to serial execution.
    """

    use_pool = False
    strict_window = False
    record_window_widths = False
    # Record per-round events-per-cluster tuples (sorted by cluster id,
    # the same order the pool chunks tasks in) into
    # ``engine.round_group_sizes`` -- the input to the architectural
    # (critical-path) speedup model benchmarks report.  Off by default:
    # long runs would accumulate one tuple per round.
    record_group_sizes = False
    # One-tick windows must defer even same-group posts to the commit
    # phase: a same-time post from a *lower-rank* group (e.g. a
    # zero-latency connection's request) would otherwise be committed
    # while the target group already ran its own same-time self-posts
    # locally -- inverting serial's seq order between the two.  Windowed
    # schedulers instead fuse zero-latency connections into the target's
    # cluster, which keeps in-window local execution serial-ordered.
    defer_all_posts = True

    def window_end(self, t: int):
        return t + 1                        # one integer-ps tick

    def group_of(self, component) -> int:
        return getattr(component, "rank", 0)

    def prepare(self) -> None:
        """Called once per ``run`` before the first round."""

    def run(self, until_ps: int = None) -> int:
        eng = self.engine
        self.prepare()
        pool = None
        try:
            while eng.queue:
                t = eng.queue.peek_time()
                if until_ps is not None and t > until_ps:
                    break
                eng.now = t
                wend = self.window_end(t)
                if until_ps is not None:
                    wend = min(wend, until_ps + 1)
                events = eng.queue.pop_window(wend)

                if len(events) == 1 and not self.strict_window:
                    # Degenerate round: no concurrency to set up.  With no
                    # sink installed, posts push straight onto the global
                    # queue in post order -- exactly serial semantics.
                    # Strict schedulers skip this path so the unsafe-post
                    # guard fires regardless of event density.
                    ev = events[0]
                    eng._handle_one(ev)
                    eng.events_processed += 1
                    eng.batch_widths.append(1)
                    if self.record_window_widths:
                        eng.window_widths.append(1)
                    eng.now = ev.time
                    continue

                groups: dict = {}
                for ev in events:
                    gid = self.group_of(ev.component)
                    groups.setdefault(gid, _GroupCtx(self, gid, wend)) \
                          .local.adopt(ev)
                tasks = [groups[g] for g in sorted(groups)]

                if self.use_pool and len(tasks) > 1 and self.max_workers > 1:
                    if pool is None:
                        pool = concurrent.futures.ThreadPoolExecutor(
                            self.max_workers)
                    nchunk = min(self.max_workers, len(tasks))
                    chunks = [tasks[i::nchunk] for i in range(nchunk)]
                    list(pool.map(_run_chunk, chunks))
                else:
                    for ctx in tasks:
                        ctx.execute()

                executed = sum(ctx.executed for ctx in tasks)
                eng.events_processed += executed
                eng.batch_widths.append(executed)
                if self.record_window_widths:
                    eng.window_widths.append(executed)
                if self.record_group_sizes:
                    eng.round_group_sizes.append(
                        tuple(ctx.executed for ctx in tasks))

                posts: list = []
                for ctx in tasks:
                    posts.extend(ctx.posts)
                posts.sort(key=lambda se: se[0])
                for _, ev in posts:
                    eng.queue.push(ev)
                eng.now = max([t] + [ctx.max_time for ctx in tasks])
        finally:
            if pool is not None:
                pool.shutdown()
        return eng.now


def _run_chunk(chunk) -> None:
    for ctx in chunk:
        ctx.execute()
