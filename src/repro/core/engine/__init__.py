"""Pluggable-scheduler simulation engine (see docs/engine.md).

``Engine`` keeps its historical constructor (``parallel=`` maps to the
batch scheduler) plus ``scheduler="serial"|"batch"|"lookahead"`` and
accepts any :class:`Scheduler` instance for custom strategies.
"""
from .base import (Engine, Scheduler, RoundScheduler, SCHEDULERS,
                   make_scheduler, register_scheduler)
from .serial import SerialScheduler
from .batch import BatchParallelScheduler
from .lookahead import LookaheadScheduler

__all__ = [
    "Engine", "Scheduler", "RoundScheduler", "SCHEDULERS",
    "make_scheduler", "register_scheduler",
    "SerialScheduler", "BatchParallelScheduler", "LookaheadScheduler",
]
