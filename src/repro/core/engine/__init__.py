"""Pluggable-scheduler simulation engine (see docs/engine.md).

``Engine`` keeps its historical constructor (``parallel=`` maps to the
batch scheduler) plus ``scheduler="serial"|"batch"|"lookahead"`` and
accepts any :class:`Scheduler` instance for custom strategies.  Round
schedulers additionally take ``executor="threads"|"procs"`` -- where
grouped rounds run (in-process pool vs shard-resident worker
processes; see :mod:`repro.core.engine.executor`).
"""
from .base import (Engine, Scheduler, RoundScheduler, SCHEDULERS,
                   make_scheduler, register_scheduler)
from .executor import (Executor, EXECUTORS, make_executor,
                       register_executor, ThreadExecutor, ProcExecutor)
from .serial import SerialScheduler
from .batch import BatchParallelScheduler
from .lookahead import LookaheadScheduler, BoundedLagScheduler

__all__ = [
    "Engine", "Scheduler", "RoundScheduler", "SCHEDULERS",
    "make_scheduler", "register_scheduler",
    "Executor", "EXECUTORS", "make_executor", "register_executor",
    "ThreadExecutor", "ProcExecutor",
    "SerialScheduler", "BatchParallelScheduler", "LookaheadScheduler",
    "BoundedLagScheduler",
]
