"""Multi-pod TPU topology: intra-pod 2-D ICI torus + inter-pod DCN.

This is the adaptation of the paper's interconnect model (PCIe bus +
RDMA engines between GPUs) to the TPU world:

* within a pod, chips form a 2-D torus of ICI links (4 links/chip,
  ~50 GB/s per direction per link);
* pods are connected over DCN with an aggregate per-pod bandwidth.

Device numbering convention (shared with ``launch/mesh.py``): device
``i`` lives in pod ``i // chips_per_pod``; within the pod, ``x = k % X``,
``y = k // X`` for ``k = i % chips_per_pod`` with pod shape ``(Y, X)``.
The mesh axes map as: "model" -> x rings (contiguous device ids),
"data" -> y rings, "pod" -> DCN.

Collective cost models are analytic (ring / hierarchical / bisection
formulas), validated against hand-computed micro-benchmarks in
``tests/test_sim_topology.py`` -- the Fig. 6-analog "parameter at a
time" fits.  The simulator consumes them through the pluggable
``repro.fabric`` registry: the ``analytic`` backend prices collectives
with these formulas directly (O(1) events each), while the ``event``
backend replays the same decompositions as per-hop transfer events on
link components and uses this module only for geometry
(:meth:`Topology.coords` / :meth:`Topology.classify_group`).  These
formulas remain the parity oracle the event backend is tested against
(``tests/test_fabric.py``).
"""
from __future__ import annotations

import dataclasses
import math
import re
import typing
import warnings

import numpy as np

from .hw import SystemSpec


# --------------------------------------------------------------------------
# replica_groups parsing
# --------------------------------------------------------------------------

_IOTA_RE = re.compile(
    r"replica_groups=\[\s*(\d+)\s*,\s*(\d+)\s*\]"
    r"<=\[([\d,\s]+)\](?:T\(([\d,\s]+)\))?")
_LIST_RE = re.compile(r"replica_groups=\{\{([\d,{}\s]+)\}\}")
_EMPTY_RE = re.compile(r"replica_groups=\{\s*\}")


# Ops already warned about for the `replica_groups={}` shorthand; one
# warning per op name per run, so a sweep over foreign HLO says which
# collectives it priced as free without drowning the log.
_warned_empty_groups: set = set()


def parse_replica_groups(attr: str,
                         op: str = None) -> typing.List[typing.List[int]]:
    """Parse HLO ``replica_groups=`` in both iota and explicit-list forms.

    Iota form: ``[G,S]<=[d0,d1,...]T(p0,p1,...)`` -- reshape iota(prod d)
    to [d...], transpose by perm, flatten, split into G groups of S.

    Returns ``[]`` when the attribute string carries no replica groups at
    all: a collective-permute's ``source_target_pairs`` (``hlo.py`` has
    its own fallback for those), or XLA's ``replica_groups={}``
    "one flat group" shorthand -- the latter is a known limitation: we
    cannot recover the device count here, so such a collective carries
    no groups and is treated as free downstream (the SPMD modules we
    analyze always emit explicit groups).  Because "free" silently
    flatters sweeps over foreign HLO, hitting the shorthand emits a
    once-per-run :class:`UserWarning` naming the op (pass ``op=`` for an
    attributable message).  Both forms are anchored to
    ``replica_groups=`` -- an earlier unanchored parse happily consumed
    ``source_target_pairs`` brace lists, silently defeating the permute
    fallback in ``hlo.py``.  A present but malformed ``replica_groups=``
    raises :class:`ValueError` -- a parse that silently dropped groups
    would misprice every collective downstream.
    """
    m = _IOTA_RE.search(attr)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        flat = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            if sorted(perm) != list(range(len(dims))):
                raise ValueError(
                    f"replica_groups transpose {perm} is not a permutation "
                    f"of {len(dims)} iota dims: {attr!r}")
            flat = flat.transpose(perm)
        flat = flat.reshape(-1)
        if flat.size != g * s:
            raise ValueError(
                f"iota replica_groups promise {g}x{s}={g * s} ids but the "
                f"iota dims {dims} yield {flat.size}: {attr!r}")
        return [flat[i * s:(i + 1) * s].tolist() for i in range(g)]
    m = _LIST_RE.search(attr)
    if m:
        groups = []
        for grp in re.findall(r"\{([\d,\s]+)\}", m.group(0)):
            groups.append([int(x) for x in grp.split(",") if x.strip()])
        if not groups:
            raise ValueError(f"malformed replica_groups list: {attr!r}")
        return groups
    if "replica_groups" in attr:
        if not _EMPTY_RE.search(attr):
            raise ValueError(f"malformed replica_groups attribute: {attr!r}")
        label = op or "<unnamed collective>"
        if label not in _warned_empty_groups:
            _warned_empty_groups.add(label)
            warnings.warn(
                f"replica_groups={{}} on {label}: XLA's one-flat-group "
                "shorthand carries no device count, so this collective "
                "will be priced as FREE (known limitation; emit explicit "
                "replica groups to price it)", UserWarning, stacklevel=2)
    return []


# --------------------------------------------------------------------------
# Topology + coordinates
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Link:
    """A directed ICI link. Not an engine component: byte counters only
    (per-packet events would be prohibitive; occupancy is analytic)."""
    name: str
    bandwidth: float
    bytes_total: float = 0.0


class Topology:
    def __init__(self, spec: SystemSpec) -> None:
        self.spec = spec
        self.Y, self.X = spec.pod_shape
        self.links: dict = {}
        for pod in range(spec.num_pods):
            for y in range(self.Y):
                for x in range(self.X):
                    for d in ("+x", "-x", "+y", "-y"):
                        n = f"pod{pod}.ici[{y},{x}]{d}"
                        self.links[n] = Link(n, spec.chip.ici_link_bandwidth)
        self.dcn = [Link(f"pod{p}.dcn", spec.dcn_bandwidth_per_pod)
                    for p in range(spec.num_pods)]

    def coords(self, device: int) -> tuple:
        cpp = self.spec.chips_per_pod
        pod, k = divmod(device, cpp)
        return pod, k // self.X, k % self.X

    def classify_group(self, group: typing.List[int]) -> str:
        """Classify a replica group by the fabric it exercises."""
        coords = [self.coords(d) for d in group]
        pods = {c[0] for c in coords}
        if len(pods) > 1:
            return "cross_pod"
        ys = {c[1] for c in coords}
        xs = {c[2] for c in coords}
        if len(group) == 1:
            return "self"
        if len(ys) == 1:
            return "ring_x"
        if len(xs) == 1:
            return "ring_y"
        return "block_2d"

    # -- per-link latencies (consumed by the event fabric) ----------------

    def link_latency_s(self, link_name: str) -> float:
        """Propagation latency of one named link: DCN uplinks pay the
        cross-pod one-way latency, ICI links (and the bisection
        aggregates, which stand in for bundles of ICI wrap links) one
        torus hop.  Accepts both bare topology names (``pod0.dcn``,
        ``pod0.ici[0,1]+x``) and event-fabric component names
        (``fabric.pod0.dcn``)."""
        name = link_name[len("fabric."):] \
            if link_name.startswith("fabric.") else link_name
        if name.endswith(".dcn"):
            return self.spec.chip.dcn_latency_s
        return self.spec.chip.ici_hop_latency_s

    def min_link_latency_s(self) -> float:
        """Smallest per-hop latency any fabric link carries.  This bounds
        the latency budget the event fabric may put on its bus legs (and
        therefore the lookahead window its clusters run under): every
        transfer's step latency must cover the request leg plus the
        ack/chunk leg, so no leg may exceed a fraction of this."""
        return min(self.spec.chip.ici_hop_latency_s,
                   self.spec.chip.dcn_latency_s)

    # -- per-collective analytic times (seconds) --------------------------
    # B = full (unsharded-along-group) payload bytes handled per participant,
    # i.e. the operand bytes of the HLO op for all-reduce / all-to-all /
    # collective-permute, and the *output* bytes for all-gather, input
    # bytes for reduce-scatter.

    def _ring_time(self, B: float, n: int, phases: float) -> float:
        """phases = 2 for all-reduce (RS+AG), 1 for AG or RS alone.
        Bidirectional ring: both directions used -> effective 2x link bw."""
        c = self.spec.chip
        bw = 2 * c.ici_link_bandwidth
        steps = phases * (n - 1)
        return phases * (n - 1) / n * B / bw + steps * c.ici_hop_latency_s

    def _block2d_time(self, B: float, n: int, phases: float) -> float:
        """Hierarchical: phase along x rings then y rings (B shrinks by X)."""
        nx = min(self.X, n)
        ny = max(1, n // nx)
        t = self._ring_time(B, nx, phases)
        if ny > 1:
            t += self._ring_time(B / nx, ny, phases)
        return t

    def _alltoall_ring_time(self, B: float, n: int) -> float:
        """Uniform all-to-all on a bidirectional ring: per-link load
        ~ B*(n-1)/8 (avg shortest-path distance n/4 over 2n directed links)."""
        c = self.spec.chip
        return (B * (n - 1) / 8) / c.ici_link_bandwidth + (n / 2) * c.ici_hop_latency_s

    def _alltoall_block_time(self, B: float, n: int) -> float:
        """Bisection-limited uniform all-to-all over a 2-D block."""
        cross = n * B / 2
        return cross / self.spec.bisection_bandwidth_per_pod + \
            (self.X / 2 + self.Y / 2) * self.spec.chip.ici_hop_latency_s

    def _cross_pod_time(self, kind: str, B: float, n: int,
                        n_groups: int) -> float:
        """Groups span pods: hierarchical intra-pod + DCN exchange.

        For the common pod-axis case (each group has one chip per pod),
        every group moves B bytes across DCN simultaneously; the pod's
        aggregate DCN bandwidth is shared by all concurrent groups."""
        c = self.spec.chip
        pods = self.spec.num_pods
        per_pod_members = max(1, n // pods)
        t = 0.0
        eff = 1.0
        if kind in ("all-reduce", "reduce-scatter"):
            eff = 2 * (pods - 1) / pods if kind == "all-reduce" else (pods - 1) / pods
        elif kind in ("all-gather", "all-to-all", "collective-permute"):
            eff = (pods - 1) / pods
        if per_pod_members > 1:
            # intra-pod phase first (reduce-scatter or gather within pod)
            t += self._block2d_time(B, per_pod_members, 1.0)
            B = B / per_pod_members
        dcn_bytes_per_pod = n_groups * B * eff
        t += dcn_bytes_per_pod / self.spec.dcn_bandwidth_per_pod + c.dcn_latency_s
        if per_pod_members > 1 and kind in ("all-reduce", "all-gather"):
            t += self._block2d_time(B * per_pod_members, per_pod_members, 1.0)
        return t

    def price(self, kind: str, bytes_per_shard: float,
              groups: typing.List[typing.List[int]]) -> float:
        """Pure analytic time for one collective op.

        Stateless: never touches the per-link byte counters, so batched
        (vectorized) pricing over a whole grid may call the same
        formulas without mutating fabric occupancy mid-grid.  The
        vectorized mirror lives in :mod:`repro.fabric.pricing`; this
        scalar path is its parity oracle (``tests/test_pricing.py``
        asserts exact float equality).
        """
        if not groups or len(groups[0]) <= 1:
            return 0.0
        return self.price_point(kind, self.classify_group(groups[0]),
                                float(bytes_per_shard), len(groups[0]),
                                n_groups=len(groups))

    def price_point(self, kind: str, cls: str, B: float, n: int,
                    n_groups: int = 1) -> float:
        """Analytic time for one (kind, group-class, bytes, size) point
        with the class given explicitly rather than derived from group
        membership.  This is the scalar oracle the vectorized kernels
        in :mod:`repro.fabric.pricing` are tested against point by
        point: same expression trees, so equality is exact."""
        if n <= 1:
            return 0.0
        if cls == "cross_pod":
            return self._cross_pod_time(kind, B, n, n_groups)
        if kind == "all-reduce":
            return self._ring_time(B, n, 2.0) if cls.startswith("ring") else \
                self._block2d_time(B, n, 2.0)
        if kind in ("all-gather", "reduce-scatter"):
            return self._ring_time(B, n, 1.0) if cls.startswith("ring") else \
                self._block2d_time(B, n, 1.0)
        if kind == "all-to-all":
            return self._alltoall_ring_time(B, n) if cls.startswith("ring") \
                else self._alltoall_block_time(B, n)
        if kind == "collective-permute":
            c = self.spec.chip
            return B / c.ici_link_bandwidth + c.ici_hop_latency_s
        raise ValueError(f"unknown collective kind {kind!r}")

    def debit_links(self, kind: str, bytes_per_shard: float,
                    groups: typing.List[typing.List[int]]) -> None:
        """Charge one collective's traffic to the per-link byte counters
        (the analytic occupancy report).  Explicitly separate from
        :meth:`price` so pricing stays pure; ``collective_time_s``
        composes the two for the live simulation path."""
        if not groups or len(groups[0]) <= 1:
            return
        n = len(groups[0])
        cls = self.classify_group(groups[0])
        B = float(bytes_per_shard)
        if cls == "cross_pod":
            share = B * (len(groups) / max(1, self.spec.num_pods))
            for l in self.dcn:
                l.bytes_total += share
            return
        if kind == "all-reduce":
            per_link = 2 * (n - 1) / n * B / 2
        elif kind in ("all-gather", "reduce-scatter"):
            per_link = (n - 1) / n * B / 2
        elif kind == "all-to-all":
            per_link = B * (n - 1) / 8
        elif kind == "collective-permute":
            per_link = B
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        self._debit_links(groups, cls, per_link)

    def collective_time_s(self, kind: str, bytes_per_shard: float,
                          groups: typing.List[typing.List[int]]) -> float:
        """Time for one collective op; also debits link byte counters."""
        t = self.price(kind, bytes_per_shard, groups)
        self.debit_links(kind, bytes_per_shard, groups)
        return t

    def _debit_links(self, groups, cls, per_link_bytes: float) -> None:
        axis = "x" if cls == "ring_x" or cls == "block_2d" else "y"
        for group in groups:
            for d in group:
                pod, y, x = self.coords(d)
                self.links[f"pod{pod}.ici[{y},{x}]+{axis}"].bytes_total += per_link_bytes

    def link_report(self) -> dict:
        hot = sorted(self.links.values(), key=lambda l: -l.bytes_total)[:8]
        return {
            "hottest_links": [(l.name, l.bytes_total) for l in hot if l.bytes_total],
            "dcn_bytes": [(l.name, l.bytes_total) for l in self.dcn],
        }
