"""Three-term roofline analysis (assignment §Roofline).

For each (architecture × shape × mesh) cell the dry-run produces:

* ``compiled.cost_analysis()``  -> HLO FLOPs + HBM bytes of the
  **per-device** partitioned module (verified in
  ``tests/test_roofline.py::test_cost_analysis_is_per_device``);
* our own HLO parse (:mod:`repro.core.hlo`)  -> collective payload bytes
  per device, *scaled by while-loop trip counts* (XLA's cost_analysis
  counts loop bodies once — our parser is the trustworthy source for
  anything under a ``jax.lax.scan``).

Terms (seconds), following the assignment's definitions with the global/
per-device convention made explicit:

    compute    = FLOPs_global  / (chips * peak)    == flops_per_dev / peak
    memory     = bytes_global  / (chips * hbm_bw)  == bytes_per_dev / hbm_bw
    collective = coll_bytes_per_dev / ici_link_bw  (spec formula)

plus a topology-aware estimate ``collective_sim`` from
:class:`repro.core.topology.Topology`'s analytic ring/torus/DCN models,
which accounts for ring efficiency (n-1)/n factors, bidirectional links
and DCN hops — the number the perf loop actually optimizes against.

MODEL_FLOPS conventions per cell kind:
    train:   6 * N_active * tokens          (fwd 2x + bwd 4x)
    prefill: 2 * N_active * tokens  + attention term
    decode:  2 * N_active * new_tokens (=batch) + KV-read attention term
"""
from __future__ import annotations

import dataclasses
import typing

from .hw import ChipSpec, SystemSpec
from .hlo import HloCost
from .topology import Topology


@dataclasses.dataclass
class RooflineTerms:
    cell: str                      # "arch/shape"
    mesh: str                      # e.g. "(16,16)"
    chips: int
    # raw per-device quantities
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_bytes_by_kind: dict
    # derived times (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0      # spec formula
    t_collective_sim: float = 0.0  # topology-aware analytic estimate
    # usefulness
    model_flops_global: float = 0.0
    useful_ratio: float = 0.0      # MODEL_FLOPS / HLO_FLOPs (global)
    dominant: str = ""
    bound_time: float = 0.0        # max of the three terms
    roofline_fraction: float = 0.0  # t_compute / bound_time (MFU-at-bound)
    notes: str = ""

    def finalize(self, spec: SystemSpec) -> "RooflineTerms":
        c = spec.chip
        self.t_compute = self.flops_per_device / c.peak_bf16_flops
        self.t_memory = self.hbm_bytes_per_device / c.hbm_bandwidth
        self.t_collective = self.coll_bytes_per_device / c.ici_link_bandwidth
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": max(self.t_collective, self.t_collective_sim)}
        self.dominant = max(terms, key=terms.get)
        self.bound_time = max(terms.values())
        if self.bound_time > 0:
            self.roofline_fraction = self.t_compute / self.bound_time
        if self.model_flops_global and self.flops_per_device:
            self.useful_ratio = self.model_flops_global / (
                self.flops_per_device * self.chips)
        return self


def collective_sim_time(cost: HloCost, spec: SystemSpec) -> float:
    """Price every parsed collective with the topology's analytic model."""
    topo = Topology(spec)
    total = 0.0
    for rec in cost.collectives:
        if not rec.groups or len(rec.groups[0]) <= 1:
            continue
        t = topo.collective_time_s(rec.kind, rec.payload_bytes, rec.groups)
        total += t * rec.count
    return total


def build_terms(cell: str, mesh_name: str, chips: int,
                cost_analysis: dict, hlo_cost: HloCost,
                spec: SystemSpec, model_flops_global: float = 0.0,
                notes: str = "") -> RooflineTerms:
    """Assemble roofline terms from the dry-run artifacts.

    ``cost_analysis`` is ``compiled.cost_analysis()`` (per-device module).
    ``hlo_cost`` is our parse of the same module's HLO text; its FLOPs are
    used *only* as a fallback when cost_analysis undercounts loops (we take
    the max — both are per-device quantities for the same program).
    """
    ca_flops = float(cost_analysis.get("flops", 0.0))
    ca_bytes = float(cost_analysis.get("bytes accessed", 0.0))
    flops = max(ca_flops, hlo_cost.flops)
    # bytes: prefer our parse — it scales while-loop bodies by trip count
    # (XLA counts them once) AND credits in-place dynamic-update-slice
    # (XLA bills a full buffer copy); fall back to XLA if parsing found
    # nothing.
    hbm = hlo_cost.hbm_bytes if hlo_cost.hbm_bytes > 0 else ca_bytes
    terms = RooflineTerms(
        cell=cell, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        coll_bytes_per_device=hlo_cost.collective_bytes,
        coll_bytes_by_kind=hlo_cost.collective_bytes_by_kind(),
        model_flops_global=model_flops_global,
        t_collective_sim=collective_sim_time(hlo_cost, spec),
        notes=notes,
    )
    return terms.finalize(spec)


# --------------------------------------------------------------------------
# MODEL_FLOPS helpers
# --------------------------------------------------------------------------

def model_flops_train(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_prefill(n_active_params: float, tokens: float,
                        attn_flops: float = 0.0) -> float:
    return 2.0 * n_active_params * tokens + attn_flops


def model_flops_decode(n_active_params: float, new_tokens: float,
                       kv_read_flops: float = 0.0) -> float:
    return 2.0 * n_active_params * new_tokens + kv_read_flops


def attention_flops(batch: int, seq: int, heads: int, head_dim: int,
                    layers: int, causal: bool = True) -> float:
    """QK^T + PV flops for full attention (training fwd; x3 for bwd)."""
    full = 2.0 * batch * heads * seq * seq * head_dim * 2 * layers
    return full / 2 if causal else full


def fmt_seconds(t: float) -> str:
    if t == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)):
        if t >= scale:
            return f"{t / scale:.3g}{unit}"
    return f"{t:.2e}s"


def format_table(rows: typing.List[RooflineTerms]) -> str:
    hdr = ["cell", "mesh", "chips", "t_compute", "t_memory", "t_coll(spec)",
           "t_coll(sim)", "dominant", "useful", "roofline%"]
    lines = [" | ".join(hdr), " | ".join(["---"] * len(hdr))]
    for r in rows:
        lines.append(" | ".join([
            r.cell, r.mesh, str(r.chips),
            fmt_seconds(r.t_compute), fmt_seconds(r.t_memory),
            fmt_seconds(r.t_collective), fmt_seconds(r.t_collective_sim),
            r.dominant,
            f"{r.useful_ratio:.2f}" if r.useful_ratio else "-",
            f"{100 * r.roofline_fraction:.1f}%",
        ]))
    return "\n".join(lines)
