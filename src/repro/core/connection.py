"""Request-connection system (paper Sec. 4.1, part 3).

Components communicate exclusively by sending :class:`Request` objects
over :class:`Connection` objects.  Connections model the transport --
on-chip fabric (zero/fixed latency), ICI links (latency + serialization
bandwidth + occupancy) and DCN (high latency, pod-aggregate bandwidth).

A connection is itself an engine-registered entity so that deliveries are
ordinary events: the connection schedules a ``deliver`` event addressed
to itself, and on handling it invokes the destination component's
``handle`` with a ``request`` event.  This keeps every state change on
the event timeline (DP-3/DP-4) and lets hooks observe all traffic.

DP-6 (no busy ticking): :class:`LimitedConnection` has a bounded queue;
when full, ``send`` returns ``False`` and the *connection* remembers the
rejected sender, notifying it via ``notify_available`` when space frees
-- senders never poll.
"""
from __future__ import annotations

import typing

from .component import Registered
from .event import Event
from .hooks import Hookable, REQ_SEND, REQ_DELIVER
from .hw import s_to_ps


class Request:
    """One message on a connection.  ``__slots__`` class: requests are
    the densest allocation after events themselves (every transfer, ack
    and chunk on the event fabric is one), so they carry no dict."""

    __slots__ = ("src", "dst", "kind", "size_bytes", "payload")

    def __init__(self, src: typing.Any = None, dst: typing.Any = None,
                 kind: str = "", size_bytes: int = 0,
                 payload: typing.Any = None) -> None:
        self.src = src             # Port
        self.dst = dst             # Component (resolved by the connection)
        self.kind = kind
        self.size_bytes = size_bytes
        self.payload = payload

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Request({self.kind}, {self.size_bytes}B)"


class LagNode:
    """One *refinement node* in the bounded-lag synchronization graph.

    ``Connection.cluster_edges`` may use a LagNode wherever a cluster id
    is expected.  A node belongs to ``cluster`` but represents only the
    subset of that cluster's pending events matched by ``pred`` (an
    ``Event -> bool`` predicate; ``None`` keeps the whole cluster), so
    out-edges leaving the node promise a minimum delay for *that event
    class only* -- e.g. "an in-flight serialization acks after >= ack_ps"
    vs. "a queued transfer request must first serialize".  This is how a
    connection states per-event-kind lookahead that the one-number
    cluster edge cannot express (see ``Engine.cluster_graph``).

    Soundness contract (the author's obligation, backstopped by the
    strict-window guard): every cross-cluster event the connection can
    create must be covered by *some* declared edge whose source node's
    base is <= the causing event's time -- a pred-node path only
    tightens the cover, it must never be the sole cover for traffic its
    pred does not match.

    ``inherit_inputs=True`` additionally copies every edge that *other*
    connections aim at this node's cluster onto the node itself: a gate
    that filters its own connection's inputs still conservatively
    receives everything arriving from connections it knows nothing
    about.
    """

    __slots__ = ("name", "cluster", "pred", "inherit_inputs")

    def __init__(self, name: str, cluster: int, pred=None,
                 inherit_inputs: bool = False) -> None:
        self.name = name
        self.cluster = cluster
        self.pred = pred
        self.inherit_inputs = inherit_inputs

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"LagNode({self.name}, cluster={self.cluster})"


class Connection(Registered, Hookable):
    """Point/multi-point transport with fixed latency (on-chip fabric).

    Connections are engine-registered items like components (the
    :class:`~repro.core.component.Registered` contract guarantees the
    rank / cluster / fault attributes the engine hot path reads)."""

    def __init__(self, name: str, latency_s: float = 0.0) -> None:
        super().__init__()
        self.name = name
        self.latency_ps = s_to_ps(latency_s)
        self.endpoints: list = []

    # -- wiring -------------------------------------------------------------
    def plug(self, port) -> "Connection":
        port.connection = self
        self.endpoints.append(port)
        return self

    # -- scheduler interface -------------------------------------------------
    @property
    def min_latency_ps(self) -> int:
        """Lower bound on the delay any send imposes before the
        destination can observe it; the lookahead window derives from the
        minimum of this over all registered connections."""
        return self.latency_ps

    @property
    def stateful_send(self) -> bool:
        """True when concurrent sends race on shared state, so a windowed
        scheduler must fuse this connection with its endpoint owners into
        one sequential cluster.  A plain connection's send only posts
        events -- unless hooks are attached, which observe send order."""
        return self.hooks_active

    def cluster_edges(self) -> typing.Iterable[tuple]:
        """Directed cluster-graph edges this connection can carry events
        over: ``(src, dst, min_latency_ps)`` triples whose endpoints are
        cluster ids or :class:`LagNode` refinement nodes.

        The bounded-lag scheduler derives each cluster's safe horizon
        from the union of these edges over all non-fused connections
        (see ``Engine.cluster_graph``), so the declaration must be a
        *superset* of the traffic the connection can actually create --
        under-declaring an edge makes the strict-window guard raise at
        the first unsafe post, never silently corrupt determinism.

        The default is the conservative clique over the endpoint
        owners' clusters at ``min_latency_ps``: correct for any
        connection, but shared many-endpoint connections should
        override it with their true routing graph (see
        ``StarConnection`` and ``FabricXbar``) -- a clique through one
        shared bus couples every cluster to the global minimum and
        degenerates bounded lag back into the global barrier.

        Only called after ``Engine.compute_clusters`` has annotated
        ``cluster_id``; self-edges are ignored by the consumer.
        """
        lat = self.min_latency_ps
        cids = sorted({p.owner.cluster_id for p in self.endpoints})
        for a in cids:
            for b in cids:
                if a != b:
                    yield (a, b, lat)

    # -- protocol -----------------------------------------------------------
    def can_accept(self, src_port) -> bool:
        return True

    def transfer_time_ps(self, request: Request) -> int:
        return self.latency_ps

    def _resolve_dst(self, src_port, request: Request) -> None:
        """Point-to-point convenience: with exactly two endpoints the
        destination is implied (requests stay addressed, components keep
        zero references to peers)."""
        if request.dst is None and len(self.endpoints) == 2:
            a, b = self.endpoints
            request.dst = b.owner if a is src_port else a.owner

    def _post_transfer(self, request: Request, arrival_ps: int) -> None:
        """Scheduler-safe commit path: both the connection's deliver event
        and the destination's request event are posted *at send time*, a
        full ``transfer_time >= min_latency_ps`` ahead.  This keeps every
        cross-component event creation behind the connection's latency --
        the invariant the lookahead window is derived from (the old
        deliver-then-dispatch chain created the destination event with
        zero delay from the deliver event, which would force the window
        to zero).

        The deliver event exists purely so connection-attached hooks can
        observe arrival (``REQ_DELIVER``); on a hook-free connection it
        is skipped, halving the event volume on busy transports like the
        event fabric's bus.  (``LimitedConnection`` overrides this: its
        deliver event is load-bearing slot bookkeeping.)"""
        if self.hooks_active:
            self.engine.post(Event(time=arrival_ps, component=self,
                                   kind="deliver", payload=request))
        self.engine.post(Event(time=arrival_ps, component=request.dst,
                               kind="request", payload=request))

    def send(self, src_port, request: Request) -> bool:
        self._resolve_dst(src_port, request)
        if self.hooks_active:
            self.invoke_hooks(REQ_SEND, self.engine.now, request)
        self._post_transfer(request,
                            self.engine.now + self.transfer_time_ps(request))
        return True

    # -- engine interface (connections are event handlers too) ---------------
    def handle(self, event: Event) -> None:
        if event.kind == "deliver":
            # bookkeeping/observation only; the destination's request
            # event was posted at send time (see _post_transfer)
            self.invoke_hooks(REQ_DELIVER, self.engine.now, event.payload)

    def notify_available(self, connection) -> None:  # pragma: no cover
        pass

    def reclaim(self, waiter) -> None:  # pragma: no cover
        """Release any wake reservation held by ``waiter``.  Called by the
        engine when a ``notify_available`` event could not be delivered
        (the waiter failed) so the slot is not stranded.  Default: no-op."""


class LinkConnection(Connection):
    """Bandwidth-limited, serialized link (one message at a time).

    Transfer completes at ``max(now, busy_until) + latency + bytes/bw``.
    Occupancy is tracked so MetricsHook can report per-link utilisation.
    """

    def __init__(self, name: str, bandwidth: float, latency_s: float = 0.0) -> None:
        super().__init__(name, latency_s)
        self.bandwidth = bandwidth           # bytes/s
        self.busy_until_ps = 0
        self.bytes_total = 0

    @property
    def stateful_send(self) -> bool:
        # senders serialize on busy_until_ps -> must share their cluster
        return True

    def serialization_ps(self, size_bytes: int) -> int:
        return s_to_ps(size_bytes / self.bandwidth) if self.bandwidth else 0

    def send(self, src_port, request: Request) -> bool:
        self._resolve_dst(src_port, request)
        if self.hooks_active:
            self.invoke_hooks(REQ_SEND, self.engine.now, request)
        start = max(self.engine.now, self.busy_until_ps)
        done = start + self.serialization_ps(request.size_bytes)
        self.busy_until_ps = done
        self.bytes_total += request.size_bytes
        self._post_transfer(request, done + self.latency_ps)
        return True


class LimitedConnection(LinkConnection):
    """LinkConnection with a bounded in-flight queue (DP-6 notification)."""

    def __init__(self, name: str, bandwidth: float, latency_s: float = 0.0,
                 capacity: int = 4) -> None:
        super().__init__(name, bandwidth, latency_s)
        self.capacity = capacity
        self.in_flight = 0
        self._waiting: list = []   # rejected sender components, FIFO
        self._promised: list = []  # woken waiters holding a slot reservation

    def can_accept(self, src_port) -> bool:
        free = self.capacity - self.in_flight
        if src_port.owner in self._promised:
            return free > 0
        # slots reserved for already-woken waiters are off limits: the
        # wake travels as a posted event, so without the reservation a
        # same-timestamp sender could steal the slot and starve the FIFO
        return free > len(self._promised)

    def send(self, src_port, request: Request) -> bool:
        owner = src_port.owner
        if not self.can_accept(src_port):
            # reject and remember who to notify -- the sender must NOT retry
            # every cycle; it will get a notify_available callback.
            if owner not in self._waiting and owner not in self._promised:
                self._waiting.append(owner)
            return False
        if owner in self._promised:
            self._promised.remove(owner)
        self.in_flight += 1
        return super().send(src_port, request)

    def _post_transfer(self, request: Request, arrival_ps: int) -> None:
        # Only the deliver event is posted at send time: the freed slot
        # must be visible BEFORE the destination handles the arrival (its
        # handler may reply on this very connection), so the request
        # event is dispatched from the deliver handler instead.  That
        # zero-delay cross-component post is safe here because a
        # stateful connection is always fused with its endpoint owners
        # into one sequential cluster.
        self.engine.post(Event(time=arrival_ps, component=self,
                               kind="deliver", payload=request))

    def handle(self, event: Event) -> None:
        if event.kind == "deliver":
            request: Request = event.payload
            self.in_flight -= 1
            if self.hooks_active:
                self.invoke_hooks(REQ_DELIVER, event.time, request)
            self.engine.post(Event(time=event.time,
                                   component=request.dst, kind="request",
                                   payload=request))
            # wake exactly one waiter per freed slot, deterministically
            # FIFO.  The wake is a posted *notification event*, not a
            # synchronous call from this handler: the waiter re-enters
            # through the ordinary event loop (the engine dispatches
            # kind="notify_available" to Component.notify_available), so
            # a waiter may in principle live in another scheduler
            # cluster.  (Today LimitedConnection is stateful_send and
            # therefore fused with its endpoint owners anyway, which is
            # what makes the same-timestamp post window-safe.)  The freed
            # slot is *reserved* for the woken waiter until its next send
            # -- events between the wake and its delivery cannot steal it.
            self._wake_next()
        else:  # pragma: no cover
            super().handle(event)

    def _wake_next(self) -> None:
        if self._waiting and \
                self.in_flight + len(self._promised) < self.capacity:
            waiter = self._waiting.pop(0)
            self._promised.append(waiter)
            self.engine.post(Event(time=self.engine.now,
                                   component=waiter,
                                   kind="notify_available",
                                   payload=self))

    def reclaim(self, waiter) -> None:
        """A promised waiter died before its wake arrived: release the
        reservation and pass the slot to the next FIFO waiter, so a dead
        component cannot strand idle capacity."""
        if waiter in self._promised:
            self._promised.remove(waiter)
        if waiter in self._waiting:
            self._waiting.remove(waiter)
        self._wake_next()
