"""Turn a parsed HLO cost model into a replayable device trace.

The bridge between DP-1 (machine-level program) and the system model:
``HloCost.trace`` is a per-device op list in program order; here we

* expand ``while``-loop repeats (each iteration's collectives must
  synchronize separately -- that ordering is what makes stragglers and
  link contention visible),
* compress runs of consecutive compute ops into single roofline segments
  (they serialize on one TensorCore anyway, so timing is preserved while
  event count drops by ~20x),
* resolve collective payloads + replica groups into :class:`_RunOp`.

``repeat_cap`` bounds trace length for very deep loops: beyond the cap we
fold the remaining iterations' compute into proportionally larger
segments (time-equivalent because iterations are identical), keeping
event counts tractable on the single-core host that runs the simulator.
"""
from __future__ import annotations

import typing

from .hlo import HloCost, TraceOp
from .system import _RunOp

__all__ = ["build_runops", "_RunOp"]


def _segment(ops: typing.List[TraceOp], scale: float = 1.0) -> _RunOp:
    return _RunOp(kind="compute", name=ops[0].name if ops else "seg",
                  flops=scale * sum(o.flops * o.repeat for o in ops),
                  hbm_bytes=scale * sum(o.hbm_bytes * o.repeat for o in ops),
                  tag="compute")


def build_runops(cost: HloCost, dtype_bits: int = 16,
                 repeat_cap: int = 64) -> typing.List[_RunOp]:
    """Flatten HloCost.trace into runnable ops.

    ``HloCost.trace`` already carries per-op ``repeat`` (loop trip counts).
    Consecutive compute ops merge into one segment.  A collective with
    repeat R is emitted min(R, cap) times, with compute segments around it
    scaled so total work matches exactly.
    """
    runops: typing.List[_RunOp] = []
    pending_compute: typing.List[TraceOp] = []

    def flush(scale: float = 1.0) -> None:
        if pending_compute:
            seg = _segment(pending_compute, scale)
            seg.dtype_bits = dtype_bits
            if seg.flops or seg.hbm_bytes:
                runops.append(seg)
            pending_compute.clear()

    for op in cost.trace:
        if op.kind == "compute":
            pending_compute.append(op)
            continue
        rec = op.collective
        reps = max(1, int(round(rec.count)))
        emit = min(reps, repeat_cap)
        scale = reps / emit
        # the compute accumulated so far belongs "before" this collective;
        # within a loop it interleaves -- approximate by splitting evenly
        # across emitted instances (time-equivalent for identical bodies).
        if pending_compute and emit > 1:
            segs = [_segment(pending_compute, 1.0 / emit) for _ in range(emit)]
            pending_compute.clear()
        else:
            flush()
            segs = [None] * emit
        per_shard = rec.payload_bytes
        for i in range(emit):
            if segs[i] is not None:
                segs[i].dtype_bits = dtype_bits
                runops.append(segs[i])
            runops.append(_RunOp(
                kind="collective", name=f"{rec.op_name}",
                coll_kind=rec.kind, bytes=per_shard * scale,
                group=tuple(tuple(g) for g in rec.groups)))
    flush()
    return runops
