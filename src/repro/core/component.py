"""Component system (paper Sec. 4.1, part 2).

Every simulated entity is a :class:`Component`: a TPU TensorCore, an HBM
controller, an ICI router, a collective coordinator, ...  Strict state
encapsulation is the core design rule (DP-2/DP-3):

* a component's state is mutated **only** inside its own ``handle``;
* a component may only schedule events **for itself**
  (:meth:`Component.schedule` hard-codes ``component=self``);
* all inter-component communication goes through
  :class:`repro.core.connection.Connection` objects via ``Request``s.

There is deliberately **no** registry of "other components" on a
component -- it holds only :class:`Port` handles, so it is impossible to
reach across and poke another component's state ("no magic").
"""
from __future__ import annotations

import typing

from .event import Event
from .hooks import Hookable


class Port:
    """One endpoint of a connection, owned by a single component."""

    def __init__(self, owner: "Component", name: str) -> None:
        self.owner = owner
        self.name = name
        self.connection = None  # wired by Connection.plug

    def send(self, request) -> bool:
        if self.connection is None:
            raise RuntimeError(f"port {self.owner.name}.{self.name} is not wired")
        return self.connection.send(self, request)

    def can_send(self) -> bool:
        return self.connection is not None and self.connection.can_accept(self)


class Registered:
    """Contract of an engine-registered item (components *and*
    connections).  These attributes live at class level so every
    registered item is *guaranteed* to carry them -- the engine hot path
    reads ``rank``/``cluster_id``/``fault_failed`` with plain attribute
    access, no getattr fallbacks -- while hook-free, fault-free
    instances pay no per-instance storage.  Any new registrable type
    must mix this in (``Engine.register`` writes ``engine``/``rank``;
    ``Engine.compute_clusters`` writes ``cluster_id``)."""

    # -- shard residency (the ``procs`` executor contract) ---------------
    # Under a process-backed executor each cluster's components live in
    # one long-lived worker process for the whole run: handlers mutate
    # the *worker's* replica, and only compact per-round messages cross
    # the process boundary.  At the end of the run the worker ships each
    # component's mutable state back so the parent replica is faithful
    # again.  ``shard_state`` defines what ships: by default everything
    # in ``__dict__`` except the names in ``shard_state_skip``.
    # References to other registered items / ports / the engine survive
    # the trip as ranks (see ``engine.executor.wire``), so object
    # identity with the parent's graph is preserved.  Items that keep
    # mutable state outside ``__dict__`` (``__slots__`` subclasses) or
    # hold unpicklable values must override these two methods.
    shard_state_skip: frozenset = frozenset(("_hooks",))

    def shard_state(self) -> dict:
        """Mutable state a shard worker must ship back to the parent."""
        skip = self.shard_state_skip
        return {k: v for k, v in self.__dict__.items() if k not in skip}

    def apply_shard_state(self, state: dict) -> None:
        """Adopt state shipped back from this item's shard worker."""
        self.__dict__.update(state)

    engine = None               # set by Engine.register
    rank = 0                    # set by Engine.register (deterministic)
    cluster_id = 0              # set by Engine.compute_clusters: the
                                # sequential-execution group (and event-queue
                                # shard) a windowed scheduler assigns this
                                # item to
    cluster_affinity = None     # optional group key: items sharing a
                                # non-None affinity are fused into one
                                # cluster even without a fusing connection
                                # (subsystems declare their own sequential
                                # islands, e.g. the event fabric's chip
                                # DMA + links)
    # Fault-injection inputs (written by FaultInjector hook, read by the
    # item's own handler / the engine dispatch):
    fault_failed = False
    fault_slow_factor = 1.0


class Component(Registered, Hookable):
    def __init__(self, name: str) -> None:
        super().__init__()
        self.name = name
        self.ports: dict = {}

    # -- wiring -----------------------------------------------------------
    def port(self, name: str) -> Port:
        if name not in self.ports:
            self.ports[name] = Port(self, name)
        return self.ports[name]

    # -- scheduling (self only) -------------------------------------------
    def schedule(self, kind: str, delay_ps: int = 0, payload: typing.Any = None) -> None:
        """Schedule an event for *this* component ``delay_ps`` in the future."""
        assert delay_ps >= 0, "cannot schedule into the past"
        self.engine.post(Event(time=self.engine.now + delay_ps,
                               component=self, kind=kind, payload=payload))

    # -- behaviour ---------------------------------------------------------
    def handle(self, event: Event) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def notify_available(self, connection) -> None:
        """Invoked when a capacity-limited connection frees up (DP-6:
        components never poll; they are notified).  Delivered as a posted
        ``notify_available`` event on the timeline -- the engine routes it
        here -- so waiters may live in other scheduler clusters.
        Default: no-op."""

    # -- convenience --------------------------------------------------------
    def mark_busy(self, start_ps: int, end_ps: int, tag: str) -> None:
        """Report a busy interval to hooks (metrics / utilization)."""
        if self.hooks_active:
            self.invoke_hooks("busy_interval", end_ps,
                              (self, start_ps, end_ps, tag))
        eng = self.engine
        if eng is not None and eng.hooks_active:
            eng.invoke_hooks("busy_interval", end_ps,
                             (self, start_ps, end_ps, tag))

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{type(self).__name__} {self.name}>"
