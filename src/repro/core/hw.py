"""Hardware constants for the modeled TPU system.

The assignment's target is a TPU v5e-class chip:
  * 197 TFLOP/s peak bf16 per chip
  * 819 GB/s HBM bandwidth per chip
  * ~50 GB/s per ICI link (2-D torus, 4 links per chip)

Pods are 16x16 = 256 chips; pods are connected over DCN. All values are
configurable so the same simulator can model other parts (v4, v5p, TRN)
by swapping a ChipSpec/SystemSpec -- the simulator core never hardcodes
these numbers (paper DP-2: open to extension).
"""
from __future__ import annotations

import dataclasses

# Time is tracked in integer picoseconds to keep event ordering exact.
PS_PER_S = 1_000_000_000_000


def s_to_ps(seconds: float) -> int:
    return int(round(seconds * PS_PER_S))


def ps_to_s(ps: int) -> float:
    return ps / PS_PER_S


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip performance envelope."""

    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12     # FLOP/s
    peak_f32_flops: float = 98.5e12     # FLOP/s (half of bf16 MXU rate)
    hbm_bandwidth: float = 819e9        # bytes/s
    hbm_capacity: int = 16 * 1024**3    # bytes
    vmem_capacity: int = 128 * 1024**2  # bytes (v5e ~128MiB VMEM)
    ici_link_bandwidth: float = 50e9    # bytes/s per link per direction
    ici_links: int = 4                  # 2-D torus: +x, -x, +y, -y
    clock_hz: float = 0.94e9            # nominal core clock
    # Fixed overheads (fit once by the micro-benchmarks, Fig.6-analog):
    op_launch_overhead_s: float = 1.2e-6     # per fused-op dispatch
    ici_hop_latency_s: float = 1.0e-6        # per-hop ICI latency
    dcn_latency_s: float = 10.0e-6           # cross-pod one-way latency
    hbm_latency_s: float = 0.6e-6            # first-byte HBM latency

    def flops_for_dtype(self, dtype_bits: int) -> float:
        return self.peak_f32_flops if dtype_bits >= 32 else self.peak_bf16_flops


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A multi-pod system: `num_pods` pods of `pod_shape` torus chips."""

    chip: ChipSpec = dataclasses.field(default_factory=ChipSpec)
    pod_shape: tuple = (16, 16)          # 2-D ICI torus per pod
    num_pods: int = 1
    dcn_bandwidth_per_pod: float = 1.6e12  # bytes/s aggregate per pod
    # (256-chip v5e pod = 64 hosts x ~25 GB/s effective NIC each)
    # Control-plane hop between a device and the collective coordinator
    # (one ICI-hop-class latency each way).  Also the only cross-chip
    # channel in the component graph, so it bounds the conservative
    # lookahead window the parallel engine derives (engine/lookahead.py).
    ctrl_latency_s: float = 1.0e-6
    # Interconnect model: a repro.fabric backend name -- "analytic"
    # (closed-form pricing, the fast path) or "event" (per-hop transfer
    # events with link contention).  See docs/fabric.md.
    fabric: str = "analytic"

    @property
    def chips_per_pod(self) -> int:
        n = 1
        for d in self.pod_shape:
            n *= d
        return n

    @property
    def total_chips(self) -> int:
        return self.chips_per_pod * self.num_pods

    @property
    def bisection_bandwidth_per_pod(self) -> float:
        """2-D torus bisection: 2 * min_dim wrap pairs * 2 dirs * link bw."""
        min_dim = min(self.pod_shape)
        return 2 * min_dim * 2 * self.chip.ici_link_bandwidth


# The production system used throughout the assignment.
SINGLE_POD = SystemSpec(num_pods=1)
MULTI_POD = SystemSpec(num_pods=2)

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0, "opaque": 0,
}
