"""TPU chip components: TensorCore + HBM controller.

These mirror the paper's CU / cache / memory-controller components, at
the granularity XLA actually schedules: fused ops.  A fused op occupies
the TensorCore for ``max(flops/peak, hbm_bytes/bw) + launch_overhead``
(the roofline duration), reports the HBM traffic to the HBM controller
via a request (so HBM occupancy is observable), and answers the
requesting DeviceProgram when done.

Stragglers: the FaultInjector hook sets ``fault_slow_factor`` (read here,
mutated nowhere else) -- compute durations stretch, and collectives that
include this chip stretch with it.  (Interconnect-side stragglers --
degraded links -- live in the fabric components instead:
``repro.fabric.event.FabricLink`` reads the same flag.)
"""
from __future__ import annotations

import dataclasses

from .component import Component
from .connection import Request
from .event import Event
from .hw import ChipSpec, s_to_ps


@dataclasses.dataclass
class ComputeJob:
    flops: float
    hbm_bytes: float
    dtype_bits: int = 16
    tag: str = "compute"
    reply_to: object = None     # DeviceProgram
    token: object = None


class TensorCore(Component):
    def __init__(self, name: str, spec: ChipSpec) -> None:
        super().__init__(name)
        self.spec = spec
        self.busy_until_ps = 0
        self.total_flops = 0.0

    def duration_ps(self, job: ComputeJob) -> int:
        t_compute = job.flops / self.spec.flops_for_dtype(job.dtype_bits)
        t_mem = job.hbm_bytes / self.spec.hbm_bandwidth
        # the slow factor stretches the whole roofline term, not just the
        # flops leg: a throttled chip is slow on memory-bound ops too
        # (dividing only the flops peak made stragglers invisible on any
        # hbm-bound trace)
        return s_to_ps(max(t_compute, t_mem) * self.fault_slow_factor
                       + self.spec.op_launch_overhead_s)

    def handle(self, event: Event) -> None:
        if event.kind == "request":
            job: ComputeJob = event.payload.payload
            now = event.time               # == engine.now inside a handler
            start = max(now, self.busy_until_ps)
            end = start + self.duration_ps(job)
            self.busy_until_ps = end
            self.total_flops += job.flops
            self.mark_busy(start, end, job.tag)
            # tell HBM about the traffic (observable occupancy, DP-4)
            if "hbm" in self.ports and job.hbm_bytes:
                self.port("hbm").send(Request(
                    src=self.port("hbm"), dst=None, kind="traffic",
                    size_bytes=int(job.hbm_bytes)))
            self.schedule("job_done", end - now, payload=job)
        elif event.kind == "job_done":
            job: ComputeJob = event.payload
            self.port("prog").send(Request(
                src=self.port("prog"), dst=job.reply_to, kind="compute_done",
                payload=job.token))


class HbmController(Component):
    """Tracks HBM occupancy from TensorCore traffic requests."""

    def __init__(self, name: str, spec: ChipSpec) -> None:
        super().__init__(name)
        self.spec = spec
        self.bytes_total = 0
        self.busy_until_ps = 0

    def handle(self, event: Event) -> None:
        if event.kind == "request":
            req: Request = event.payload
            self.bytes_total += req.size_bytes
            start = max(event.time, self.busy_until_ps)
            end = start + s_to_ps(req.size_bytes / self.spec.hbm_bandwidth)
            self.busy_until_ps = end
            self.mark_busy(start, end, "hbm")
