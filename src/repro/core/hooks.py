"""Hook system (paper Sec. 4.1, part 4).

Hooks are small pieces of software attached to the engine, components or
connections.  They read (or, for fault injection, perturb) simulation
state without being part of the critical protocol path.  Used here for:
trace collection, performance metrics, stall accounting and fault /
straggler injection -- the same four uses the paper lists.
"""
from __future__ import annotations

import collections
import dataclasses
import typing

from .hw import ps_to_s

# Hook positions
EVENT_START = "event_start"
EVENT_END = "event_end"
REQ_SEND = "request_send"
REQ_DELIVER = "request_deliver"
BUSY_INTERVAL = "busy_interval"   # payload: (component, start_ps, end_ps, tag)


@dataclasses.dataclass(frozen=True)
class HookCtx:
    position: str
    time: int
    item: typing.Any          # Event or Request or tuple
    owner: typing.Any = None  # component/connection the hook fired on


class Hook:
    """Base hook: override ``func``.

    Shard residency (``executor="procs"``): engine-level hooks fire in
    every shard worker on that worker's replica of the hook, so their
    observations end the run partitioned across processes.  A hook that
    defines ``merge_shard(self, replica)`` gets each worker's replica
    merged back into the parent instance at the end of the run (the
    method must be commutative across replicas -- counter sums, maxima).
    Because the workers fork *with* the parent's pre-run state, each
    one swaps the engine-level hook for :meth:`fresh_shard` at startup
    and accumulates only its own observations -- otherwise the fork
    baseline (e.g. a previous run's counters) would merge back once
    per worker.  Hooks without ``merge_shard`` keep only parent-side
    observations under procs; their *side effects on components* (e.g.
    FaultInjector's fault flags) still replicate faithfully, because
    those live in component state, which is shard-resident and synced
    back.  See docs/engine.md.
    """

    def fresh_shard(self) -> "Hook":
        """A zero-state instance for a shard worker to accumulate into.
        The default assumes a zero-argument constructor; mergeable
        hooks with required constructor arguments must override."""
        return type(self)()

    def func(self, ctx: HookCtx) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class Hookable:
    """Mixin giving engine/components/connections a hook list.

    ``hooks_active`` is the hot-path fast flag: hook-free items (the
    overwhelmingly common case -- fault/trace hooks attach to a handful
    of components) pay one attribute check per event instead of four
    ``invoke_hooks`` calls.  It is a class attribute shadowed by an
    instance attribute on the first ``accept_hook``, so the flag costs
    nothing per instance until a hook actually attaches.
    """

    hooks_active = False

    def __init__(self) -> None:
        self._hooks: list = []

    def accept_hook(self, hook: Hook) -> None:
        self._hooks.append(hook)
        self.hooks_active = True

    def invoke_hooks(self, position: str, time: int, item: typing.Any) -> None:
        for h in self._hooks:
            h.func(HookCtx(position=position, time=time, item=item, owner=self))


class Tracer(Hook):
    """Records every hook firing (bounded) -- debugging / validation."""

    def __init__(self, limit: int = 1_000_000) -> None:
        self.records: list = []
        self.limit = limit

    def func(self, ctx: HookCtx) -> None:
        if len(self.records) < self.limit:
            self.records.append(ctx)


class MetricsHook(Hook):
    """Aggregates busy time per component and request bytes per connection."""

    def __init__(self) -> None:
        self.busy_ps = collections.Counter()        # name -> busy picoseconds
        self.busy_by_tag = collections.Counter()    # (name, tag) -> ps
        self.bytes_sent = collections.Counter()     # connection name -> bytes
        self.requests = collections.Counter()       # connection name -> count
        self.end_time_ps = 0

    def func(self, ctx: HookCtx) -> None:
        if ctx.position == BUSY_INTERVAL:
            comp, start, end, tag = ctx.item
            self.busy_ps[comp.name] += end - start
            self.busy_by_tag[(comp.name, tag)] += end - start
            self.end_time_ps = max(self.end_time_ps, end)
        elif ctx.position == REQ_SEND:
            req = ctx.item
            self.bytes_sent[ctx.owner.name] += getattr(req, "size_bytes", 0)
            self.requests[ctx.owner.name] += 1
        if ctx.position in (EVENT_END, REQ_DELIVER):
            self.end_time_ps = max(self.end_time_ps, ctx.time)

    def merge_shard(self, replica: "MetricsHook") -> None:
        """Fold a shard worker's observations into this instance: each
        worker saw a disjoint partition of the events, so counters sum
        and the end time is the max (order-independent across workers)."""
        self.busy_ps.update(replica.busy_ps)
        self.busy_by_tag.update(replica.busy_by_tag)
        self.bytes_sent.update(replica.bytes_sent)
        self.requests.update(replica.requests)
        self.end_time_ps = max(self.end_time_ps, replica.end_time_ps)

    def utilization(self, name: str) -> float:
        if self.end_time_ps == 0:
            return 0.0
        return self.busy_ps[name] / self.end_time_ps

    def summary(self) -> dict:
        return {
            "end_time_s": ps_to_s(self.end_time_ps),
            "busy_s": {k: ps_to_s(v) for k, v in self.busy_ps.items()},
            "bytes_sent": dict(self.bytes_sent),
        }


class StallHook(Hook):
    """Counts stall reasons announced by components (kind='stall')."""

    def __init__(self) -> None:
        self.stalls = collections.Counter()

    def merge_shard(self, replica: "StallHook") -> None:
        self.stalls.update(replica.stalls)

    def func(self, ctx: HookCtx) -> None:
        if ctx.position == EVENT_START and getattr(ctx.item, "kind", "") == "stall":
            self.stalls[ctx.item.payload] += 1


class FaultInjector(Hook):
    """Injects failures / stragglers into components at given times.

    ``plan`` maps component-name -> list of (time_ps, action, arg):
      * ("fail", None)           -- component stops handling events
      * ("drop", None)           -- alias for "fail": events addressed to
                                    the component are dropped on the
                                    floor (the natural reading for a
                                    link: in-flight transfers are lost)
      * ("slow", factor)         -- durations multiplied by factor
      * ("recover", None)        -- undo both
      * ("transient", dur_ps)    -- sugar: "fail" now, auto-"recover"
                                    ``dur_ps`` later (a flapping link /
                                    glitching component).  Anything lost
                                    during the outage stays lost --
                                    under the event fabric's ring
                                    dependency a transient link fault
                                    therefore stalls the whole ring, not
                                    just the sender's chain.

    Targets are chips (``chip3.core`` compute straggler, ``chip3.prog``
    failure) and, under the event fabric, individual interconnect links
    and DMA engines (``fabric.pod0.ici[0,1]+x`` -> a *straggler link*:
    every transfer crossing it stretches by ``factor``).  The full plan
    grammar with worked examples lives in docs/faults.md.
    The injector flips flags that well-behaved components consult inside
    their own ``handle`` -- state is still only mutated by the owner
    (no-magic is preserved: the hook only sets an *input* flag the
    component reads, the same way MGSim injects faults).
    """

    def __init__(self, plan: dict) -> None:
        self.plan = {k: sorted(self._expand(v)) for k, v in plan.items()}

    def arm(self, components: typing.Iterable) -> None:
        """Post a ``fault_wake`` self-event at every plan time of every
        planned target, so actions apply *exactly on schedule* even when
        no other traffic reaches the component.  Without this the lazy
        pop in :meth:`func` only fires at the component's next event --
        a ``recover`` on an idle, failed component (which receives
        nothing: the engine drops its events) would apply late or never.
        The wake rides the normal dispatch path: the hook applies due
        actions at EVENT_START, then the (possibly just-recovered)
        component handles a ``fault_wake`` event it may react to --
        components that don't know the kind ignore it.  Call after the
        targets accepted this hook, before ``engine.run()``."""
        from .event import Event   # local: hooks must not import event at load
        for comp in components:
            for t, _action, _arg in self.plan.get(comp.name, ()):
                comp.engine.post(Event(time=t, component=comp,
                                       kind="fault_wake"))

    @staticmethod
    def _expand(actions):
        out = []
        for t, action, arg in actions:
            if action == "transient":
                out.append((t, "fail", None))
                out.append((t + int(arg), "recover", None))
            elif action == "drop":
                out.append((t, "fail", None))
            else:
                out.append((t, action, arg))
        return out

    def func(self, ctx: HookCtx) -> None:
        if ctx.position != EVENT_START:
            return
        comp = ctx.owner
        name = getattr(comp, "name", None)
        actions = self.plan.get(name)
        if not actions:
            return
        while actions and actions[0][0] <= ctx.time:
            _, action, arg = actions.pop(0)
            if action == "fail":
                comp.fault_failed = True
            elif action == "slow":
                comp.fault_slow_factor = float(arg)
            elif action == "recover":
                comp.fault_failed = False
                comp.fault_slow_factor = 1.0
