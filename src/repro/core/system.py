"""System model builder: an N-pod TPU machine as engine-registered components.

This is the multi-GPU-platform configuration step of the paper (Sec. 4.3)
transplanted to pods: from a :class:`SystemSpec` we instantiate, per chip,
a :class:`TensorCore` + :class:`HbmController` + :class:`DeviceProgram`,
wire them with connections, and add one :class:`CollectiveCoordinator`
reachable from every device.  The interconnect itself is a pluggable
``repro.fabric`` backend installed next to the coordinator (``fabric=``,
default ``SystemSpec.fabric``).  Swapping any piece (a different HBM
model, a 3-D torus, a third fabric) is new wiring here -- zero edits to
components (DP-2).
"""
from __future__ import annotations

import dataclasses
import typing
import warnings

from .chip import HbmController, TensorCore
from .component import Component
from .connection import Connection, Request
from .engine import Engine
from .event import Event
from .hw import SystemSpec, s_to_ps


@dataclasses.dataclass
class DeviceDone:
    device: int
    time_ps: int
    aborted: bool = False


class DeviceProgram(Component):
    """Replays one device's op trace (SPMD: all devices share the trace).

    States: issue next op -> wait for compute_done / collective_done ->
    advance.  The program never touches another component's state: compute
    goes to its TensorCore via a connection, collectives join through the
    coordinator connection (DP-3).
    """

    def __init__(self, name: str, device: int) -> None:
        super().__init__(name)
        self.device = device
        self.trace: list = []           # list of _RunOp (set by System.load)
        self.pc = 0
        self.done = False
        self.aborted = False
        self.finish_ps = 0
        self._coll_occurrence: dict = {}

    def start(self) -> None:
        self.schedule("advance")

    def handle(self, event: Event) -> None:
        if event.kind == "advance":
            self._issue()
        elif event.kind == "request":
            req = event.payload
            if req.kind in ("compute_done", "collective_done"):
                self.pc += 1
                self._issue()
            elif req.kind == "collective_timeout":
                self.aborted = True
                self.done = True
                self.finish_ps = self.engine.now

    def _issue(self) -> None:
        from .chip import ComputeJob  # local import to avoid cycle at module load
        if self.done:
            return
        if self.pc >= len(self.trace):
            self.done = True
            self.finish_ps = self.engine.now
            return
        op = self.trace[self.pc]
        if op.kind == "compute":
            self.port("core").send(Request(
                src=self.port("core"), dst=None, kind="job",
                payload=ComputeJob(flops=op.flops, hbm_bytes=op.hbm_bytes,
                                   dtype_bits=op.dtype_bits, tag=op.tag,
                                   reply_to=self)))
        else:  # collective
            occ = self._coll_occurrence.get(op.name, 0)
            self._coll_occurrence[op.name] = occ + 1
            self.port("coll").send(Request(
                src=self.port("coll"), dst=None, kind="join",
                size_bytes=int(op.bytes),
                payload=(op.name, occ, op.coll_kind, op.bytes, op.group,
                         self.device, self)))


class CollectiveCoordinator(Component):
    """Synchronizes collective ops: waits for every member of a replica
    group, hands the transfer to the fabric backend (over its ``fabric``
    port), and notifies all members when the fabric reports completion.
    A straggler delays its whole group -- the paper's
    cross-device-traffic bottleneck made visible.

    The coordinator is fabric-agnostic: the ``analytic`` backend answers
    after one closed-form delay, the ``event`` backend after its per-hop
    transfer events drain (see ``repro.fabric``).

    ``deadline_s``: if a group's collective has not *completed* within the
    deadline of the first join -- a member never joined (chip death) or
    the fabric transfer stalled (link fault) -- members that did join
    receive ``collective_timeout`` (failure-detection substrate for the
    fault-tolerance studies).  ``collective_done``/``collective_timeout``
    carry the collective key as payload so callers that interleave
    iterations (the serving programs) can discard stale notifications; a
    wired ``health`` port additionally receives a ``timeout_report`` with
    the joined-member roster, which is what a failure detector needs to
    tell "who is missing" from "the transfer died".
    """

    def __init__(self, name: str, deadline_s: float = None) -> None:
        super().__init__(name)
        self.deadline_ps = s_to_ps(deadline_s) if deadline_s else None
        self.pending: dict = {}       # key -> list[(device, program)]
        self.completed = 0
        self.timed_out: list = []

    def handle(self, event: Event) -> None:
        if event.kind == "request":
            req = event.payload
            if req.kind == "join":
                self._join(req)
            elif req.kind == "fabric_done":
                self._complete(req.payload)
        elif event.kind == "deadline":
            key = event.payload
            members = self.pending.pop(key, None)
            if members is None:
                return                # completed within the deadline
            self.timed_out.append(key)
            for _, prog in members:
                self.port("coll").send(Request(
                    src=self.port("coll"), dst=prog,
                    kind="collective_timeout", payload=key))
            health = self.ports.get("health")
            if health is not None and health.connection is not None:
                health.send(Request(
                    src=health, dst=None, kind="timeout_report",
                    payload=(key, tuple(d for d, _ in members))))

    def _join(self, req: Request) -> None:
        name, occ, kind, nbytes, group, device, prog = req.payload
        key = (name, occ, tuple(group))
        members = self.pending.setdefault(key, [])
        if not members and self.deadline_ps:
            self.schedule("deadline", self.deadline_ps, payload=key)
        members.append((device, prog))
        if len(members) == len(group):
            self.port("fabric").send(Request(
                src=self.port("fabric"), dst=None, kind="start",
                size_bytes=int(nbytes),
                payload=(key, kind, nbytes, list(group))))

    def _complete(self, key) -> None:
        members = self.pending.pop(key, None)
        if members is None:
            return                    # timed out before the fabric finished
        self.completed += 1
        for _, prog in members:
            self.port("coll").send(Request(
                src=self.port("coll"), dst=prog, kind="collective_done",
                payload=key))


class StarConnection(Connection):
    """Hub-and-spoke fabric: requests from spokes route to the hub owner
    (the collective coordinator); hub requests carry an explicit dst.
    Routing lives in the connection — components still hold no peer
    references (DP-3)."""

    def __init__(self, name: str, hub_port, latency_s: float = 0.0) -> None:
        super().__init__(name, latency_s)
        self.hub = hub_port
        self.plug(hub_port)

    def _resolve_dst(self, src_port, request) -> None:
        if request.dst is None and src_port is not self.hub:
            request.dst = self.hub.owner

    def cluster_edges(self):
        """Star, not clique: spoke traffic only ever reaches the hub's
        cluster, and hub traffic only ever reaches a spoke's -- two
        spokes never exchange events directly, so bounded-lag horizons
        couple each device cluster to the coordinator alone (two
        control-latency hops apart from each other, not one)."""
        lat = self.min_latency_ps
        hub = self.hub.owner.cluster_id
        for port in self.endpoints:
            spoke = port.owner.cluster_id
            if spoke != hub:
                yield (spoke, hub, lat)
                yield (hub, spoke, lat)


@dataclasses.dataclass
class _RunOp:
    kind: str                   # 'compute' | 'collective'
    name: str = ""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    dtype_bits: int = 16
    tag: str = "compute"
    coll_kind: str = ""
    bytes: float = 0.0
    group: tuple = ()


class System:
    """A complete simulated machine, ready to replay device traces."""

    def __init__(self, spec: SystemSpec, parallel: bool = False,
                 deadline_s: float = None, scheduler=None,
                 max_workers: int = 4, fabric=None, executor=None) -> None:
        from ..fabric import make_fabric   # late: fabric imports core modules
        self.spec = spec
        if parallel:
            warnings.warn(
                "System(parallel=True) is deprecated; pass "
                "scheduler='batch' (or 'lookahead') instead",
                DeprecationWarning, stacklevel=2)
            if scheduler is None:
                scheduler = "batch"
        self.engine = Engine(scheduler=scheduler, max_workers=max_workers,
                             executor=executor)
        self.fabric = make_fabric(fabric or spec.fabric, spec)
        self.topology = self.fabric.topology
        self.programs: typing.List[DeviceProgram] = []
        self.cores: typing.List[TensorCore] = []
        self.hbms: typing.List[HbmController] = []
        self.coordinator = self.engine.register(
            CollectiveCoordinator("coordinator", deadline_s=deadline_s))
        self.fabric.install(self.engine, self.coordinator)
        # The coordinator fabric carries the only cross-chip traffic, so
        # its latency is what the lookahead scheduler's window derives
        # from: per-chip clusters may run ctrl_latency ahead of each other.
        coll_conn = self.engine.register(
            StarConnection("coll_fabric", self.coordinator.port("coll"),
                           latency_s=spec.ctrl_latency_s))
        for d in range(spec.total_chips):
            core = self.engine.register(TensorCore(f"chip{d}.core", spec.chip))
            hbm = self.engine.register(HbmController(f"chip{d}.hbm", spec.chip))
            prog = self.engine.register(DeviceProgram(f"chip{d}.prog", d))
            # on-chip wiring: program<->core, core->hbm
            self.engine.register(Connection(f"chip{d}.bus")).plug(
                prog.port("core")).plug(core.port("prog"))
            self.engine.register(Connection(f"chip{d}.membus")).plug(
                core.port("hbm")).plug(hbm.port("cpu"))
            coll_conn.plug(prog.port("coll"))
            self.programs.append(prog)
            self.cores.append(core)
            self.hbms.append(hbm)

    # ------------------------------------------------------------------
    def load_trace(self, runops: typing.List[_RunOp],
                   devices: typing.Iterable[int] = None) -> None:
        devs = list(devices) if devices is not None else range(len(self.programs))
        # Give the fabric advance notice of every planned collective:
        # transfer-level backends refine their bounded-lag edges from
        # the exact programs these will decompose into.
        for op in runops:
            if op.kind == "collective":
                for g in op.group:
                    if len(g) > 1:
                        self.fabric.note_plan(op.coll_kind, float(op.bytes),
                                              tuple(g))
        for d in devs:
            prog = self.programs[d]
            # per-device group resolution: pick the replica group containing d
            ops = []
            for op in runops:
                if op.kind == "collective":
                    group = next((g for g in op.group if d in g), None)
                    if group is None or len(group) <= 1:
                        continue  # this device does not participate
                    ops.append(dataclasses.replace(op, group=tuple(group)))
                else:
                    ops.append(op)
            prog.trace = ops

    def run(self, until_s: float = None) -> dict:
        for prog in self.programs:
            if prog.trace:
                prog.start()
        until_ps = s_to_ps(until_s) if until_s else None
        self.engine.run(until_ps)
        active = [p for p in self.programs if p.trace]
        finish = [p.finish_ps for p in active if p.done]
        return {
            "time_s": max(finish) / 1e12 if finish else 0.0,
            "devices_done": sum(p.done and not p.aborted for p in active),
            "devices_aborted": sum(p.aborted for p in active),
            "events": self.engine.events_processed,
            "collectives_completed": self.coordinator.completed,
            "collective_timeouts": len(self.coordinator.timed_out),
        }
