"""Event system (paper Sec. 4.1, part 1).

An :class:`Event` marks an update of system state at a particular time.
The engine keeps a priority queue of events ordered by
``(time, component_rank, seq)``:

* ``time``            -- integer picoseconds (exact ordering, no float ties)
* ``component_rank``  -- stable per-component rank, so same-timestamp events
                         group deterministically by component (this grouping
                         is the unit of conservative parallelism, DP-5)
* ``seq``             -- global monotonically increasing schedule order

Events carry an opaque ``kind`` + ``payload``; the owning component's
``handle`` interprets them.  A component can only schedule events for
itself (enforced in :meth:`Component.schedule`), mirroring MGSim's rule
that "a component can only schedule events to itself".
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import typing


@dataclasses.dataclass(frozen=True)
class Event:
    time: int                  # picoseconds
    component: "typing.Any"    # the Component that will handle this event
    kind: str
    payload: typing.Any = None
    seq: int = -1              # filled by the queue

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Event(t={self.time}ps, {getattr(self.component, 'name', '?')}, {self.kind})"


class EventQueue:
    """Min-heap of events keyed (time, component_rank, seq)."""

    def __init__(self) -> None:
        self._heap: list = []
        self._counter = itertools.count()

    def push(self, event: Event) -> Event:
        seq = next(self._counter)
        event = dataclasses.replace(event, seq=seq)
        rank = getattr(event.component, "rank", 0)
        heapq.heappush(self._heap, (event.time, rank, seq, event))
        return event

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def peek_time(self) -> int:
        return self._heap[0][0]

    def pop_batch(self) -> list:
        """Pop *all* events sharing the earliest timestamp.

        Those events are, by construction of the component system,
        mutually independent across components: a handler may only touch
        its own component's state.  This is the conservative-parallel
        batch of DP-5.
        """
        if not self._heap:
            return []
        t = self._heap[0][0]
        batch = []
        while self._heap and self._heap[0][0] == t:
            batch.append(self.pop())
        return batch

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
