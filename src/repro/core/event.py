"""Event system (paper Sec. 4.1, part 1).

An :class:`Event` marks an update of system state at a particular time.
The engine keeps a priority queue of events ordered by
``(time, component_rank, seq)``:

* ``time``            -- integer picoseconds (exact ordering, no float ties)
* ``component_rank``  -- stable per-component rank, so same-timestamp events
                         group deterministically by component (this grouping
                         is the unit of conservative parallelism, DP-5)
* ``seq``             -- global monotonically increasing schedule order

Events carry an opaque ``kind`` + ``payload``; the owning component's
``handle`` interprets them.  A component can only schedule events for
itself (enforced in :meth:`Component.schedule`), mirroring MGSim's rule
that "a component can only schedule events to itself".

Two queue implementations share one entry layout ``(time, generation,
rank, seq, event)`` (generation is 0 for every globally queued event --
it only orders same-timestamp chains inside a :class:`LocalQueue`):

* :class:`EventQueue` -- a single min-heap; what the serial scheduler
  drains and what every engine starts with.
* :class:`ShardedEventQueue` -- one heap *per scheduler cluster*,
  fronted by a small lazily-validated heap of shard head times.  Round
  schedulers swap the engine's queue to this in ``prepare()``: a round's
  window pops straight out of each shard in shard order, already
  partitioned by execution group and already sorted, so no event ever
  funnels through a global heap.  The total order is preserved
  bit-exactly because ``seq`` -- the only cross-shard-unsafe key -- is
  never compared across shards: it tie-breaks same-``(time, rank)``
  entries only, and a rank (a component) lives in exactly one shard.
"""
from __future__ import annotations

import heapq
import itertools
import typing


class EmptyQueueError(IndexError):
    """Raised by ``peek_time`` on an empty queue.

    Subclasses :class:`IndexError` so callers that guarded against the
    old bare ``heap[0]`` failure keep working, but carries an actual
    explanation instead of ``list index out of range``.
    """


class Event:
    """A scheduled state update.  Plain ``__slots__`` class on the hot
    path: the queue stamps ``seq`` in place when the event is pushed
    (exactly once -- events are single-use), so scheduling an event
    allocates one object and zero copies."""

    __slots__ = ("time", "component", "kind", "payload", "seq")

    def __init__(self, time: int, component: "typing.Any", kind: str,
                 payload: typing.Any = None, seq: int = -1) -> None:
        self.time = time               # picoseconds
        self.component = component     # the Component that will handle this
        self.kind = kind
        self.payload = payload
        self.seq = seq                 # stamped by the queue on push

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Event(t={self.time}ps, "
                f"{getattr(self.component, 'name', '?')}, {self.kind})")


class EventQueue:
    """Min-heap of events keyed (time, component_rank, seq)."""

    def __init__(self) -> None:
        self._heap: list = []
        self._counter = itertools.count()

    def push(self, event: Event) -> Event:
        event.seq = seq = next(self._counter)
        comp = event.component
        heapq.heappush(self._heap, (event.time, 0, comp.rank, seq, event))
        return event

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[4]

    def peek_time(self) -> int:
        if not self._heap:
            raise EmptyQueueError("peek_time on an empty event queue")
        return self._heap[0][0]

    def pop_batch(self) -> list:
        """Pop *all* events sharing the earliest timestamp.

        Those events are, by construction of the component system,
        mutually independent across components: a handler may only touch
        its own component's state.  This is the conservative-parallel
        batch of DP-5.
        """
        if not self._heap:
            return []
        return self.pop_window(self._heap[0][0] + 1)

    def pop_window(self, end_time) -> list:
        """Pop every event with ``time < end_time`` in (time, rank, seq)
        order — the unit of work of a lookahead window (conservative
        PDES: the caller guarantees no event created inside the window
        can target another component before ``end_time``)."""
        heap = self._heap
        out = []
        while heap and heap[0][0] < end_time:
            out.append(heapq.heappop(heap)[4])
        return out

    def _take_entries(self) -> list:
        """Drain the raw (time, gen, rank, seq, event) entries (queue
        migration; see :meth:`ShardedEventQueue.from_queue`)."""
        heap, self._heap = self._heap, []
        return heap

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class ShardedEventQueue:
    """Per-cluster shard heaps fronted by a small heap of shard heads.

    ``push`` routes an event to the shard of its component's
    ``cluster_id``; a round scheduler's window pop
    (:meth:`pop_window_sharded`) drains each shard whose head falls
    inside the window and hands the per-shard entry lists straight to
    that cluster's execution context -- no global merge, no re-sort, no
    per-event re-wrapping (the entries double as the local working
    heap, because an ascending list is a valid min-heap).

    **Why this preserves the serial total order bit-exactly.**  The
    global order is ``(time, rank, seq)``.  ``time`` and ``rank`` are
    intrinsic to the event; only ``seq`` is assigned by the queue.  But
    ``seq`` is reached only when ``(time, rank)`` ties -- i.e. between
    two events for the *same component*, which by construction live in
    the *same shard*.  So as long as each shard receives its events in
    serial post order (the commit phase sorts per shard by post stamp),
    cross-shard seq skew is unobservable: any comparison between events
    of different shards is already decided by ``(time, rank)``.

    The head heap is lazy: ``push`` records a shard's head time only
    when it improves, and stale entries are discarded on the next
    ``peek_time``/pop when they no longer match their shard's actual
    head.  Every non-empty shard always has at least one live entry.
    """

    def __init__(self, num_shards: int, counter=None) -> None:
        self.num_shards = num_shards
        self._shards: list = [[] for _ in range(num_shards)]
        self._heads: list = []          # (head_time, shard_id), lazy
        self._counter = counter if counter is not None else itertools.count()
        self._len = 0

    @classmethod
    def from_queue(cls, queue, num_shards: int) -> "ShardedEventQueue":
        """Re-home a queue's pending events into per-cluster shards.

        Accepts a plain :class:`EventQueue` or an already-sharded queue
        (clusters may change between runs); existing seqs and the live
        counter carry over, so pending events keep their serial order.
        """
        q = cls(num_shards, counter=queue._counter)
        shards = q._shards
        n = 0
        for entry in queue._take_entries():
            shards[entry[4].component.cluster_id].append(entry)
            n += 1
        for sid, shard in enumerate(shards):
            if shard:
                heapq.heapify(shard)
                heapq.heappush(q._heads, (shard[0][0], sid))
        q._len = n
        return q

    def push(self, event: Event) -> Event:
        event.seq = seq = next(self._counter)
        comp = event.component
        shard = self._shards[comp.cluster_id]
        time = event.time
        if not shard or time < shard[0][0]:
            heapq.heappush(self._heads, (time, comp.cluster_id))
        heapq.heappush(shard, (time, 0, comp.rank, seq, event))
        self._len += 1
        return event

    def peek_time(self) -> int:
        heads, shards = self._heads, self._shards
        while heads:
            t, sid = heads[0]
            shard = shards[sid]
            if shard and shard[0][0] == t:
                return t
            heapq.heappop(heads)        # stale: head popped or shard drained
        raise EmptyQueueError("peek_time on an empty event queue")

    def shard_head_time(self, sid: int):
        """Earliest pending time in one shard (``None`` when empty).
        Bounded-lag schedulers read every shard's head to compute
        per-cluster lower bounds, bypassing the global head heap."""
        shard = self._shards[sid]
        return shard[0][0] if shard else None

    def pop_shard_window(self, sid: int, end_time) -> list:
        """Pop one shard's events with ``time < end_time`` in
        (time, rank, seq) order -- the bounded-lag feed, where every
        cluster gets its *own* window end instead of a shared one.
        Stale head-heap entries for the shard self-clean on the next
        ``peek_time``; only a (possibly) improved head is re-pushed."""
        shard = self._shards[sid]
        batch = []
        while shard and shard[0][0] < end_time:
            batch.append(heapq.heappop(shard))
        if batch:
            self._len -= len(batch)
            if shard:
                heapq.heappush(self._heads, (shard[0][0], sid))
        return batch

    def pop_window_sharded(self, end_time) -> tuple:
        """Pop every event with ``time < end_time``; returns
        ``([(shard_id, entries), ...], total_events)`` with shards in
        ascending id order and each entries list ascending in
        (time, rank, seq) -- the exact feed a round scheduler's
        per-cluster contexts adopt."""
        heads, shards = self._heads, self._shards
        out = []
        nev = 0
        while heads:
            t, sid = heads[0]
            shard = shards[sid]
            if not shard or shard[0][0] != t:
                heapq.heappop(heads)
                continue
            if t >= end_time:
                break
            batch = []
            while shard and shard[0][0] < end_time:
                batch.append(heapq.heappop(shard))
            nev += len(batch)
            out.append((sid, batch))
            heapq.heappop(heads)
            if shard:
                heapq.heappush(heads, (shard[0][0], sid))
        self._len -= nev
        out.sort()                          # shard ids are unique -> no
        return out, nev                     # tie ever compares the lists

    def pop_window_merged(self, end_time) -> list:
        """Pop every event with ``time < end_time`` into one list,
        sorted in global (time, rank, seq) order -- the feed of a
        merged (serial-equivalent) round.  One allocation, one
        near-linear sort over the per-shard sorted runs."""
        heads, shards = self._heads, self._shards
        out = []
        while heads:
            t, sid = heads[0]
            shard = shards[sid]
            if not shard or shard[0][0] != t:
                heapq.heappop(heads)
                continue
            if t >= end_time:
                break
            while shard and shard[0][0] < end_time:
                out.append(heapq.heappop(shard))
            heapq.heappop(heads)
            if shard:
                heapq.heappush(heads, (shard[0][0], sid))
        self._len -= len(out)
        out.sort()                          # seqs unique -> never compares
        return out                          # the entries' event field

    def pop_window(self, end_time) -> list:
        """Globally (time, rank, seq)-ordered window pop (compatibility
        path; round schedulers use the sharded/merged variants)."""
        return [e[4] for e in self.pop_window_merged(end_time)]

    def pop_batch(self) -> list:
        if not self._len:
            return []
        return self.pop_window(self.peek_time() + 1)

    def pop(self) -> Event:
        t = self.peek_time()            # validates the head heap
        # The head heap orders shards by time only, so a cross-shard
        # time tie must be broken by the actual head entries (rank is
        # the global tie-break and ranks are unique across shards).
        best_sid = -1
        best = None
        for sid, shard in enumerate(self._shards):
            if shard and shard[0][0] == t and (best is None
                                               or shard[0] < best):
                best = shard[0]
                best_sid = sid
        shard = self._shards[best_sid]
        heapq.heappop(shard)
        self._len -= 1
        if shard:                       # stale head entries self-clean
            heapq.heappush(self._heads, (shard[0][0], best_sid))
        return best[4]

    def _take_entries(self) -> list:
        out = []
        for shard in self._shards:
            out.extend(shard)
            shard.clear()
        self._heads.clear()
        self._len = 0
        return out

    def __len__(self) -> int:
        return self._len

    def __bool__(self) -> bool:
        return self._len > 0


class LocalQueue:
    """Per-group working heap used inside one scheduler round.

    Holds the group's slice of a popped window plus any events its own
    handlers schedule back into the window.  Keys are (time, generation,
    rank, seq):

    * ``generation`` reproduces the serial engine's snapshot-round
      semantics for same-timestamp chains: serial pops *all* events at
      time t, runs them in (rank, seq) order, and any delay-0 posts they
      make wait for the next same-t round.  A locally created event at
      its creator's own timestamp therefore carries ``creator's
      generation + 1`` so it sorts after every same-t event of the
      current round regardless of rank; events created for a later
      timestamp reset to generation 0 (serial would see them in that
      timestamp's first snapshot).
    * locally created events draw seqs from a high base so they sort
      *after* every globally assigned seq at the same (time, gen, rank)
      — exactly where serial's monotonically increasing post-time seqs
      would put them.  The disjoint seq ranges also let a group context
      merge this heap against its adopted (globally-stamped) shard
      slice by raw entry comparison.

    The queue is long-lived (one per scheduler cluster) and, in the
    round machinery, holds only the events handlers push back *into*
    the current window -- the popped window itself is iterated in place
    by the group context, so the common no-local-post round never
    re-heaps anything.
    """

    LOCAL_SEQ_BASE = 1 << 60

    __slots__ = ("_heap", "_counter")

    def __init__(self) -> None:
        self._heap: list = []
        self._counter = itertools.count(self.LOCAL_SEQ_BASE)

    def clear(self) -> None:
        self._heap.clear()

    def adopt(self, event: Event) -> None:
        """Add an event already carrying a globally assigned seq."""
        heapq.heappush(self._heap,
                       (event.time, 0, event.component.rank, event.seq, event))

    def push_new(self, event: Event, generation: int = 0) -> Event:
        """Add an event created during this round; assigns a local seq."""
        event.seq = seq = next(self._counter)
        heapq.heappush(self._heap,
                       (event.time, generation, event.component.rank, seq,
                        event))
        return event

    def pop(self) -> tuple:
        """Returns (generation, event) in (time, gen, rank, seq) order."""
        entry = heapq.heappop(self._heap)
        return entry[1], entry[4]

    def peek_time(self) -> int:
        if not self._heap:
            raise EmptyQueueError("peek_time on an empty local queue")
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
