"""Event system (paper Sec. 4.1, part 1).

An :class:`Event` marks an update of system state at a particular time.
The engine keeps a priority queue of events ordered by
``(time, component_rank, seq)``:

* ``time``            -- integer picoseconds (exact ordering, no float ties)
* ``component_rank``  -- stable per-component rank, so same-timestamp events
                         group deterministically by component (this grouping
                         is the unit of conservative parallelism, DP-5)
* ``seq``             -- global monotonically increasing schedule order

Events carry an opaque ``kind`` + ``payload``; the owning component's
``handle`` interprets them.  A component can only schedule events for
itself (enforced in :meth:`Component.schedule`), mirroring MGSim's rule
that "a component can only schedule events to itself".
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import typing


@dataclasses.dataclass(frozen=True)
class Event:
    time: int                  # picoseconds
    component: "typing.Any"    # the Component that will handle this event
    kind: str
    payload: typing.Any = None
    seq: int = -1              # filled by the queue

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Event(t={self.time}ps, {getattr(self.component, 'name', '?')}, {self.kind})"


class EventQueue:
    """Min-heap of events keyed (time, component_rank, seq)."""

    def __init__(self) -> None:
        self._heap: list = []
        self._counter = itertools.count()

    def push(self, event: Event) -> Event:
        seq = next(self._counter)
        event = dataclasses.replace(event, seq=seq)
        rank = getattr(event.component, "rank", 0)
        heapq.heappush(self._heap, (event.time, rank, seq, event))
        return event

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def peek_time(self) -> int:
        return self._heap[0][0]

    def pop_batch(self) -> list:
        """Pop *all* events sharing the earliest timestamp.

        Those events are, by construction of the component system,
        mutually independent across components: a handler may only touch
        its own component's state.  This is the conservative-parallel
        batch of DP-5.
        """
        if not self._heap:
            return []
        return self.pop_window(self._heap[0][0] + 1)

    def pop_window(self, end_time) -> list:
        """Pop every event with ``time < end_time`` in (time, rank, seq)
        order — the unit of work of a lookahead window (conservative
        PDES: the caller guarantees no event created inside the window
        can target another component before ``end_time``)."""
        out = []
        while self._heap and self._heap[0][0] < end_time:
            out.append(heapq.heappop(self._heap)[-1])
        return out

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class LocalQueue:
    """Per-group working heap used inside one scheduler round.

    Holds the group's slice of a popped window plus any events its own
    handlers schedule back into the window.  Keys are (time, generation,
    rank, seq):

    * ``generation`` reproduces the serial engine's snapshot-round
      semantics for same-timestamp chains: serial pops *all* events at
      time t, runs them in (rank, seq) order, and any delay-0 posts they
      make wait for the next same-t round.  A locally created event at
      its creator's own timestamp therefore carries ``creator's
      generation + 1`` so it sorts after every same-t event of the
      current round regardless of rank; events created for a later
      timestamp reset to generation 0 (serial would see them in that
      timestamp's first snapshot).
    * locally created events draw seqs from a high base so they sort
      *after* every globally assigned seq at the same (time, gen, rank)
      — exactly where serial's monotonically increasing post-time seqs
      would put them.
    """

    LOCAL_SEQ_BASE = 1 << 60

    def __init__(self) -> None:
        self._heap: list = []
        self._counter = itertools.count(self.LOCAL_SEQ_BASE)

    def adopt(self, event: Event) -> None:
        """Add an event already carrying a globally assigned seq."""
        rank = getattr(event.component, "rank", 0)
        heapq.heappush(self._heap, (event.time, 0, rank, event.seq, event))

    def push_new(self, event: Event, generation: int = 0) -> Event:
        """Add an event created during this round; assigns a local seq."""
        event = dataclasses.replace(event, seq=next(self._counter))
        rank = getattr(event.component, "rank", 0)
        heapq.heappush(self._heap,
                       (event.time, generation, rank, event.seq, event))
        return event

    def pop(self) -> tuple:
        """Returns (generation, event) in (time, gen, rank, seq) order."""
        entry = heapq.heappop(self._heap)
        return entry[1], entry[-1]

    def peek_time(self) -> int:
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
