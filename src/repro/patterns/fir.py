"""FIR filter — Adjacent Access pattern.

Causal FIR over a partitioned signal: each shard needs the previous
shard's last (taps-1) samples.  D-mode moves exactly that halo with one
collective_permute; U-mode lets GSPMD discover the same halo from a
global convolution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import axis_size, shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

PATTERN = "adjacent"
TAPS = 16


def _fir_local(x, taps):
    """x already left-padded with (T-1) halo samples: y_i = sum taps_j *
    x[i + T-1 - j]."""
    T = taps.shape[0]
    n = x.shape[0] - (T - 1)
    y = jnp.zeros(n, x.dtype)
    for j in range(T):                               # static taps
        y = y + taps[j] * jax.lax.dynamic_slice(x, (T - 1 - j,), (n,))
    return y


def reference(x: np.ndarray, taps: np.ndarray) -> np.ndarray:
    return np.convolve(x, taps, mode="full")[:x.shape[0]].astype(x.dtype)


def default_size(n_devices: int) -> int:
    return 64 * 1024 * max(1, n_devices)            # Table 2: 64K SP samples


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev"))

    def fn(x, taps):
        x = jax.lax.with_sharding_constraint(x, sh)
        xp = jnp.pad(x, (TAPS - 1, 0))
        return _fir_local(xp, taps)
    return jax.jit(fn, out_shardings=sh)


def make_dmode(mesh):
    def local(x, taps):
        T = taps.shape[0]
        # halo: last T-1 samples of the LEFT neighbor (ring, shard 0 zero)
        n = axis_size("dev")
        idx = jax.lax.axis_index("dev")
        tail = x[-(T - 1):]
        halo = jax.lax.ppermute(tail, "dev",
                                perm=[(i, (i + 1) % n) for i in range(n)])
        halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
        return _fir_local(jnp.concatenate([halo, x]), taps)
    fn = shard_map(local, mesh=mesh, in_specs=(P("dev"), P(None)),
                   out_specs=P("dev"), check_vma=False)
    return jax.jit(fn)


def make_args(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 1, n).astype(np.float32),
            rng.normal(0, 1, TAPS).astype(np.float32))
