"""KMeans (one assignment + partial-sum iteration) — Partitioned Data.

Points are partitioned; every device computes distances/assignments for
its slice and local per-cluster partial sums.  Like the paper's version
the centroid update is a host-side reduction — devices never exchange
points, making this Partitioned despite the iteration structure.  Memory
-intensive and cache-reuse-heavy (the paper's contrast with AES).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

PATTERN = "partitioned"
FEATURES = 32
CLUSTERS = 16


def _assign_and_sum(pts, cent):
    """pts (n,F), cent (K,F) -> (sums (K,F), counts (K,), assign (n,))."""
    d2 = (jnp.sum(pts * pts, -1, keepdims=True)
          - 2.0 * pts @ cent.T + jnp.sum(cent * cent, -1)[None])
    a = jnp.argmin(d2, axis=-1)
    onehot = jax.nn.one_hot(a, cent.shape[0], dtype=pts.dtype)
    return onehot.T @ pts, jnp.sum(onehot, axis=0), a.astype(jnp.int32)


def reference(points: np.ndarray, centroids: np.ndarray):
    d2 = ((points[:, None, :] - centroids[None]) ** 2).sum(-1)
    a = d2.argmin(-1)
    sums = np.zeros_like(centroids)
    counts = np.zeros(centroids.shape[0])
    for k in range(centroids.shape[0]):
        sel = points[a == k]
        sums[k] = sel.sum(0) if len(sel) else 0
        counts[k] = len(sel)
    new = sums / np.maximum(counts[:, None], 1)
    return new.astype(points.dtype)


def default_size(n_devices: int) -> int:
    return 32 * 1024 * max(1, n_devices)            # Table 2: 32K pts x devs


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev", None))

    def fn(pts, cent):
        pts = jax.lax.with_sharding_constraint(pts, sh)
        sums, counts, _ = _assign_and_sum(pts, cent)
        return sums / jnp.maximum(counts[:, None], 1)
    return jax.jit(fn)


def make_dmode(mesh):
    def local(pts, cent):
        sums, counts, _ = _assign_and_sum(pts, cent)
        # host-reduction analog: one small psum of (K,F)+(K,) partials
        sums = jax.lax.psum(sums, "dev")
        counts = jax.lax.psum(counts, "dev")
        return sums / jnp.maximum(counts[:, None], 1)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("dev", None), P(None, None)),
                   out_specs=P(None, None), check_vma=False)
    return jax.jit(fn)


def make_args(n_points: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(0, 1, (n_points, FEATURES)).astype(np.float32)
    cent = rng.normal(0, 1, (CLUSTERS, FEATURES)).astype(np.float32)
    return pts, cent
