"""Matrix Transpose — Scatter pattern.

Row-partitioned matrix; the transpose scatters every shard's blocks to
every other shard.  D-mode is one explicit all_to_all of (M, Nl, Nl)
blocks + a local block transpose; U-mode states `x.T` with row-sharded
input and output and lets GSPMD materialize the exchange.  (The paper
uses MT to validate LDS/local-memory modeling — here the local transpose
is the VMEM-tiled part and the all_to_all is the fabric part.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import axis_size, shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

PATTERN = "scatter"


def reference(x: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(x.T)


def default_size(n_devices: int) -> int:
    return 2048 * max(1, int(np.sqrt(n_devices)) * 2)  # Table 2: 2048->4096


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev", None))

    def fn(x):
        x = jax.lax.with_sharding_constraint(x, sh)
        return x.T
    return jax.jit(fn, out_shardings=sh)


def make_dmode(mesh):
    def local(x):                                  # x (Nl, N) local rows
        m = axis_size("dev")
        Nl = x.shape[0]
        blocks = x.reshape(Nl, m, Nl).transpose(1, 0, 2)   # (m, Nl, Nl)
        recv = jax.lax.all_to_all(blocks, "dev", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv[p] = block B_pq owned by sender p; Y_q columns block p = B_pq^T
        return jnp.transpose(recv, (2, 0, 1)).reshape(Nl, m * Nl)
    fn = shard_map(local, mesh=mesh, in_specs=(P("dev", None),),
                   out_specs=P("dev", None), check_vma=False)
    return jax.jit(fn)


def make_args(width: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 1, (width, width)).astype(np.float32),)
