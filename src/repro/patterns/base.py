"""MGMark-TPU common harness.

Every workload module exposes:
    reference(...)            -- numpy/jnp oracle (DP-4: data validates)
    run_umode(mesh, ...)      -- one jit over the mesh, GSPMD placement
    run_dmode(mesh, ...)      -- shard_map, every collective explicit
    PATTERN                   -- its collaborative-execution pattern
    default_size(n_devices)   -- Table-2 sizing (4-device column scaled)

`evaluate` runs one mode, checks the output against the oracle, parses
the compiled HLO for collective traffic and prices it on the system
model — the three numbers Fig. 9 plots (time, traffic, correctness).
"""
from __future__ import annotations

import dataclasses
import typing

import jax
from repro.compat import cost_analysis_dict
import numpy as np

from repro.core import SystemSpec, analyze, simulate

PATTERNS = ("partitioned", "adjacent", "gather", "scatter", "irregular")


@dataclasses.dataclass
class PatternReport:
    name: str
    mode: str                       # "umode" | "dmode"
    pattern: str
    correct: bool
    max_err: float
    collective_bytes: float         # per-device, from compiled HLO
    bytes_by_kind: dict
    sim_time_s: float               # timeline simulation on the system model
    compute_util: float
    flops: float
    hbm_bytes: float

    def row(self) -> str:
        return (f"{self.name:6s} {self.mode:6s} {self.pattern:12s} "
                f"ok={self.correct} coll={self.collective_bytes:12.4g}B "
                f"t_sim={self.sim_time_s * 1e3:9.3f}ms "
                f"util={self.compute_util:.2f}")


def evaluate(name: str, pattern: str, mode: str, jitted, args,
             oracle: np.ndarray, spec: SystemSpec = None,
             atol: float = 2e-2, device_limit: int = 8) -> PatternReport:
    """Run a compiled pattern workload, validate + price it."""
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    out = np.asarray(jax.device_get(compiled(*args)))
    oracle = np.asarray(oracle)
    if np.issubdtype(out.dtype, np.floating):
        err = float(np.max(np.abs(out.astype(np.float64)
                                  - oracle.astype(np.float64))))
    else:
        err = float(np.max(np.abs(out.astype(np.int64)
                                  - oracle.astype(np.int64))))
    cost = analyze(compiled.as_text())
    spec = spec or SystemSpec(pod_shape=(1, jax.device_count()))
    rep = simulate(cost=cost, spec=spec, device_limit=device_limit)
    ca = cost_analysis_dict(compiled)
    return PatternReport(
        name=name, mode=mode, pattern=pattern,
        correct=bool(err <= atol), max_err=err,
        collective_bytes=cost.collective_bytes,
        bytes_by_kind=cost.collective_bytes_by_kind(),
        sim_time_s=rep.time_s, compute_util=rep.compute_util,
        flops=max(float(ca.get("flops", 0.0)), cost.flops),
        hbm_bytes=max(float(ca.get("bytes accessed", 0.0)), cost.hbm_bytes))
