"""AES-256 (ECB over blocks) — Partitioned Data pattern.

The paper's compute-intensive no-communication workload: plaintext is
chunked across devices, every device encrypts its chunk, zero cross-
device traffic.  Full AES-256 in JAX: SubBytes via table gather,
ShiftRows via fixed gather, MixColumns in GF(2^8) with uint8 bit ops —
validated against the FIPS-197 C.3 test vector
(tests/test_patterns.py::test_aes_fips_vector).

Key expansion runs on the host (numpy) — it is sequential and tiny,
exactly like the paper's host-side setup.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

PATTERN = "partitioned"


# --------------------------------------------------------------------------
# tables (generated, not typed in)
# --------------------------------------------------------------------------

def _gf_mul(a: int, b: int) -> int:
    r = 0
    for _ in range(8):
        if b & 1:
            r ^= a
        hi = a & 0x80
        a = (a << 1) & 0xFF
        if hi:
            a ^= 0x1B
        b >>= 1
    return r


@functools.lru_cache(None)
def sbox() -> np.ndarray:
    # multiplicative inverse in GF(2^8) + affine transform (FIPS-197 5.1.1)
    inv = np.zeros(256, np.uint8)
    for a in range(1, 256):
        for b in range(1, 256):
            if _gf_mul(a, b) == 1:
                inv[a] = b
                break
    out = np.zeros(256, np.uint8)
    for i in range(256):
        x = int(inv[i])
        y = x
        for _ in range(4):
            x = ((x << 1) | (x >> 7)) & 0xFF
            y ^= x
        out[i] = y ^ 0x63
    return out


_RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80,
                  0x1B, 0x36, 0x6C, 0xD8, 0xAB, 0x4D], np.uint8)


def expand_key(key: np.ndarray) -> np.ndarray:
    """key (32,) uint8 -> round keys (15, 16) uint8 (AES-256, Nk=8)."""
    S = sbox()
    w = [key[4 * i:4 * i + 4].copy() for i in range(8)]
    for i in range(8, 60):
        t = w[i - 1].copy()
        if i % 8 == 0:
            t = np.roll(t, -1)
            t = S[t]
            t[0] ^= _RCON[i // 8 - 1]
        elif i % 8 == 4:
            t = S[t]
        w.append(w[i - 8] ^ t)
    return np.concatenate(w).reshape(15, 16)


# --------------------------------------------------------------------------
# the cipher (vectorized over blocks)
# --------------------------------------------------------------------------

# ShiftRows on column-major state bytes b[r + 4c]: byte i moves to
# position (i*5 mod 16) inverse; precompute the gather indices.
_SHIFT_IDX = np.array([(i + 4 * (i % 4)) % 16 for i in range(16)])


def _xtime(a):
    return ((a << 1) ^ jnp.where(a & 0x80, jnp.uint8(0x1B),
                                 jnp.uint8(0))).astype(jnp.uint8)


def encrypt_blocks(blocks, round_keys, sbox_table):
    """blocks (N,16) uint8, round_keys (15,16), sbox (256,) -> (N,16)."""
    st = blocks ^ round_keys[0]

    def sub_shift(st):
        st = jnp.take(sbox_table, st.astype(jnp.int32), axis=0)
        return st[:, _SHIFT_IDX]

    def mix(st):
        s = st.reshape(-1, 4, 4)                    # columns (N, col, row)
        a0, a1, a2, a3 = s[:, :, 0], s[:, :, 1], s[:, :, 2], s[:, :, 3]
        x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
        b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
        b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
        b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
        b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
        return jnp.stack([b0, b1, b2, b3], axis=2).reshape(-1, 16)

    for rnd in range(1, 14):
        st = mix(sub_shift(st)) ^ round_keys[rnd]
    return sub_shift(st) ^ round_keys[14]


# --------------------------------------------------------------------------
# oracle + modes
# --------------------------------------------------------------------------

def reference(plain: np.ndarray, key: np.ndarray) -> np.ndarray:
    """Pure-numpy oracle (independent of the jnp path)."""
    S = sbox()
    rk = expand_key(key)
    st = plain.reshape(-1, 16) ^ rk[0]

    def mix_np(st):
        s = st.reshape(-1, 4, 4).astype(np.uint8)
        out = np.empty_like(s)
        for c in range(4):
            a = s[:, c, :]
            x = ((a << 1) ^ np.where(a & 0x80, 0x1B, 0)).astype(np.uint8)
            out[:, c, 0] = x[:, 0] ^ (x[:, 1] ^ a[:, 1]) ^ a[:, 2] ^ a[:, 3]
            out[:, c, 1] = a[:, 0] ^ x[:, 1] ^ (x[:, 2] ^ a[:, 2]) ^ a[:, 3]
            out[:, c, 2] = a[:, 0] ^ a[:, 1] ^ x[:, 2] ^ (x[:, 3] ^ a[:, 3])
            out[:, c, 3] = (x[:, 0] ^ a[:, 0]) ^ a[:, 1] ^ a[:, 2] ^ x[:, 3]
        return out.reshape(-1, 16)

    for rnd in range(1, 14):
        st = mix_np(S[st][:, _SHIFT_IDX]) ^ rk[rnd]
    return (S[st][:, _SHIFT_IDX] ^ rk[14]).reshape(plain.shape)


def default_size(n_devices: int) -> int:
    return 256 * 1024 * max(1, n_devices // 1)      # Table 2: 256KB x devs


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev", None))

    def fn(blocks, rk, sb):
        blocks = jax.lax.with_sharding_constraint(blocks, sh)
        return encrypt_blocks(blocks, rk, sb)
    return jax.jit(fn, out_shardings=sh)


def make_dmode(mesh):
    def local(blocks, rk, sb):                       # no collectives at all
        return encrypt_blocks(blocks, rk, sb)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("dev", None), P(None, None), P(None)),
                   out_specs=P("dev", None), check_vma=False)
    return jax.jit(fn)


def make_args(size_bytes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    plain = rng.integers(0, 256, (size_bytes // 16, 16), dtype=np.uint8)
    key = rng.integers(0, 256, 32, dtype=np.uint8)
    return plain, key, expand_key(key), sbox()
