"""Simple Convolution (2-D 3x3 stencil) — Adjacent Access pattern, 2-D.

Row-partitioned image; each shard needs one halo row from each
neighbor.  D-mode: two collective_permutes (up + down).  The local
stencil math matches kernels/stencil.py (which is the TPU Pallas kernel
for this hot-spot); the oracle is kernels.ref.stencil2d_ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import axis_size, shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.ref import stencil2d_ref

PATTERN = "adjacent"
K = 3


def reference(img: np.ndarray, kern: np.ndarray) -> np.ndarray:
    return np.asarray(stencil2d_ref(jnp.asarray(img), jnp.asarray(kern)))


def _stencil_padded(x, kern):
    """x (h+2, W) incl. top/bottom halo rows -> (h, W) same-padded cols."""
    h = x.shape[0] - 2
    W = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (1, 1)))
    acc = jnp.zeros((h, W), x.dtype)
    for dy in range(K):
        for dx in range(K):
            acc = acc + kern[dy, dx] * \
                jax.lax.dynamic_slice(xp, (dy, dx), (h, W))
    return acc


def default_size(n_devices: int) -> int:
    return 1024 * max(1, int(np.sqrt(n_devices)) * 2)   # Table 2: 1024->2048


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev", None))

    def fn(img, kern):
        img = jax.lax.with_sharding_constraint(img, sh)
        return _stencil_padded(jnp.pad(img, ((1, 1), (0, 0))), kern)
    return jax.jit(fn, out_shardings=sh)


def make_dmode(mesh):
    def local(img, kern):
        n = axis_size("dev")
        idx = jax.lax.axis_index("dev")
        down = [(i, (i + 1) % n) for i in range(n)]
        up = [(i, (i - 1) % n) for i in range(n)]
        top_halo = jax.lax.ppermute(img[-1:], "dev", perm=down)
        bot_halo = jax.lax.ppermute(img[:1], "dev", perm=up)
        top_halo = jnp.where(idx == 0, jnp.zeros_like(top_halo), top_halo)
        bot_halo = jnp.where(idx == n - 1, jnp.zeros_like(bot_halo), bot_halo)
        return _stencil_padded(jnp.concatenate([top_halo, img, bot_halo]),
                               kern)
    fn = shard_map(local, mesh=mesh, in_specs=(P("dev", None), P(None, None)),
                   out_specs=P("dev", None), check_vma=False)
    return jax.jit(fn)


def make_args(width: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 1, (width, width)).astype(np.float32),
            rng.normal(0, 1, (K, K)).astype(np.float32))
