"""Bitonic Sort — Irregular pattern.

Every stage touches the whole address space; stages whose compare
distance crosses the shard boundary are pairwise shard exchanges.

D-mode decomposition for L elements on m shards (Ll = L/m local):
  * dist >= Ll: partner shard = mine XOR (dist/Ll); ONE collective_permute
    of the whole local array per stage; direction/keep side are static
    per (stage, shard) — computed from the shard index;
  * dist <  Ll: fully local vectorized compare-exchange
    (kernels/bitonic.py owns this on TPU; jnp ref here).

The cross-shard stages are the Irregular traffic the paper measures: the
full array crosses the fabric O(log^2 m) times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.ref import bitonic_stage_ref, bitonic_sort_ref

PATTERN = "irregular"


def reference(x: np.ndarray) -> np.ndarray:
    return np.sort(x)


def default_size(n_devices: int) -> int:
    return 32 * 1024 * max(1, n_devices)           # Table 2: 32K -> 128K


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev"))

    def fn(x):
        x = jax.lax.with_sharding_constraint(x, sh)
        return bitonic_sort_ref(x)
    return jax.jit(fn, out_shardings=sh)


def make_dmode(mesh):
    m = mesh.shape["dev"]

    def local(x):
        Ll = x.shape[0]
        idx = jax.lax.axis_index("dev")
        L = Ll * m
        size = 2
        while size <= L:
            dist = size // 2
            while dist >= 1:
                if dist >= Ll:
                    shard_dist = dist // Ll
                    partner = [(i, i ^ shard_dist) for i in range(m)]
                    other = jax.lax.ppermute(x, "dev", perm=partner)
                    pidx = idx ^ shard_dist
                    # ascending iff (global index & size)==0: bit above the
                    # offset -> a bit of the shard id
                    asc = (idx & (size // Ll)) == 0
                    low_side = idx < pidx
                    take_min = jnp.logical_not(jnp.logical_xor(low_side, asc))
                    x = jnp.where(take_min, jnp.minimum(x, other),
                                  jnp.maximum(x, other))
                else:
                    # direction bit of the *global* index: if size <= Ll it
                    # varies inside the shard (local ref handles it); when
                    # size > Ll it is constant here -> pass shard-adjusted size
                    if size < Ll:
                        # direction bit is inside the local offset
                        x = bitonic_stage_ref(x, dist, size)
                    else:
                        # direction bit is a shard-id bit: constant here
                        asc = (idx & (size // Ll)) == 0
                        x = jnp.where(asc, _stage_fixed(x, dist, True),
                                      _stage_fixed(x, dist, False))
                dist //= 2
            size *= 2
        return x

    fn = shard_map(local, mesh=mesh, in_specs=(P("dev"),),
                   out_specs=P("dev"), check_vma=False)
    return jax.jit(fn)


def _stage_fixed(x, dist: int, ascending: bool):
    """Compare-exchange stage with a single fixed direction."""
    L = x.shape[0]
    v = x.reshape(L // (2 * dist), 2, dist)
    lo = jnp.minimum(v[:, 0], v[:, 1])
    hi = jnp.maximum(v[:, 0], v[:, 1])
    pair = (lo, hi) if ascending else (hi, lo)
    return jnp.stack(pair, axis=1).reshape(L)


def make_args(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 1, n).astype(np.float32),)
