"""MGMark-TPU: the paper's benchmark suite on the multi-pod TPU model.

Seven workloads across the five collaborative-execution patterns
(paper Sec. 5):

  AES  partitioned   KM  partitioned   FIR  adjacent   SC  adjacent
  GD   gather        MT  scatter       BS   irregular

Each module: reference oracle + run in U-mode (jit/GSPMD — the paper's
U-MGPU) and D-mode (shard_map, explicit collectives — D-MGPU).
"""
from . import aes, base, bs, fir, gd, km, mt, sc
from .base import PatternReport, evaluate

WORKLOADS = {"aes": aes, "km": km, "fir": fir, "sc": sc, "gd": gd,
             "mt": mt, "bs": bs}

__all__ = ["aes", "base", "bs", "fir", "gd", "km", "mt", "sc",
           "WORKLOADS", "PatternReport", "evaluate"]
