"""Gradient Descent (data-parallel linear regression) — Gather pattern.

Each device computes the gradient over its mini-batch shard; the
gradients are averaged — the gather/all-reduce every DP trainer performs
each step (the paper calls out GD as the canonical Gather workload and a
cross-GPU interconnect stress test).  Several steps run inside one
program so the Gather repeats on the timeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import axis_size, shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

PATTERN = "gather"
FEATURES = 256
STEPS = 8
LR = 0.05


def reference(X: np.ndarray, y: np.ndarray, w: np.ndarray) -> np.ndarray:
    w = w.copy().astype(np.float64)
    for _ in range(STEPS):
        g = X.T.astype(np.float64) @ (X.astype(np.float64) @ w
                                      - y.astype(np.float64)) / X.shape[0]
        w = w - LR * g
    return w.astype(X.dtype)


def default_size(n_devices: int) -> int:
    return 64 * 1024 * max(1, n_devices)   # Table 2: 256K/1M params scaled


def make_umode(mesh):
    sh = NamedSharding(mesh, P("dev", None))

    def fn(X, y, w):
        X = jax.lax.with_sharding_constraint(X, sh)

        def step(w, _):
            g = X.T @ (X @ w - y) / X.shape[0]
            return w - LR * g, None
        w, _ = jax.lax.scan(step, w, None, length=STEPS)
        return w
    return jax.jit(fn)


def make_dmode(mesh):
    def local(X, y, w):
        n = X.shape[0] * axis_size("dev")

        def step(w, _):
            g_local = X.T @ (X @ w - y) / n
            g = jax.lax.psum(g_local, "dev")         # THE gather
            return w - LR * g, None
        w, _ = jax.lax.scan(step, w, None, length=STEPS)
        return w
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("dev", None), P("dev"), P(None)),
                   out_specs=P(None), check_vma=False)
    return jax.jit(fn)


def make_args(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, (n, FEATURES)).astype(np.float32)
    w_true = rng.normal(0, 1, FEATURES).astype(np.float32)
    y = X @ w_true + rng.normal(0, 0.01, n).astype(np.float32)
    w0 = np.zeros(FEATURES, np.float32)
    return X, y, w0
