"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Qwen3 uses an explicit head_dim=128 (num_heads*head_dim != d_model).
d_ff=768 is the per-expert intermediate size.
"""
from repro.models.base import ModelConfig, register


@register("qwen3-moe-30b-a3b")
def qwen3_moe_30b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151_936,
        num_experts=128, experts_per_token=8, moe_groups=256,
        rope_theta=1e6, fsdp=True, attn_impl="ref", microbatches=2,
        seq_shard_activations=True,
    )


@register("qwen3-moe-30b-a3b-smoke")
def qwen3_moe_30b_smoke() -> ModelConfig:
    return qwen3_moe_30b().replace(
        name="qwen3-moe-30b-a3b-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
        num_experts=8, experts_per_token=2, capacity_factor=8.0,
        moe_groups=4,
        dtype="float32", microbatches=1, fsdp=False, seq_shard_activations=False,
        attn_impl="ref")
