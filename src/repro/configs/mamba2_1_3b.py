"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.models.base import ModelConfig, register


@register("mamba2-1.3b")
def mamba2_1_3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50_280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, microbatches=4,
    )


@register("mamba2-1.3b-smoke")
def mamba2_1_3b_smoke() -> ModelConfig:
    return mamba2_1_3b().replace(
        name="mamba2-1.3b-smoke", num_layers=2, d_model=64, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, vocab_size=256, dtype="float32", microbatches=1)
