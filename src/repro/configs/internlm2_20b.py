"""internlm2-20b [dense] — GQA. [arXiv:2403.17297; hf]"""
from repro.models.base import ModelConfig, register


@register("internlm2-20b")
def internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16_384, vocab_size=92_544,
        rope_theta=1e6, fsdp=True, attn_impl="ref", microbatches=2,
        seq_shard_activations=True,
    )


@register("internlm2-20b-smoke")
def internlm2_20b_smoke() -> ModelConfig:
    return internlm2_20b().replace(
        name="internlm2-20b-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32", microbatches=1, fsdp=False,
        seq_shard_activations=False, attn_impl="ref")
