"""qwen1.5-110b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.base import ModelConfig, register


@register("qwen1.5-110b")
def qwen1_5_110b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=49_152, vocab_size=152_064, qkv_bias=True,
        fsdp=True, seq_shard_activations=True, attn_impl="ref", microbatches=2,
    )


@register("qwen1.5-110b-smoke")
def qwen1_5_110b_smoke() -> ModelConfig:
    return qwen1_5_110b().replace(
        name="qwen1.5-110b-smoke", num_layers=3, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=256, vocab_size=256, dtype="float32", microbatches=1,
        fsdp=False, seq_shard_activations=False)
