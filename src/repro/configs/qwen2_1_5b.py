"""qwen2-1.5b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.base import ModelConfig, register


@register("qwen2-1.5b")
def qwen2_1_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151_936, qkv_bias=True,
        rope_theta=1e6, attn_impl="blocked",
        seq_shard_activations=True, fsdp=True,
    )


@register("qwen2-1.5b-smoke")
def qwen2_1_5b_smoke() -> ModelConfig:
    return qwen2_1_5b().replace(
        name="qwen2-1.5b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
        seq_shard_activations=False, fsdp=False, attn_impl="ref")
