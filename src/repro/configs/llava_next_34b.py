"""llava-next-34b [vlm] — anyres tiling STUB + dense 60L backbone.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

input_specs() provides precomputed patch embeddings (B, 2880, d_model):
anyres = 4 tiles + base image, 576 CLIP patches each. The vision tower
and 2-layer MLP projector are out of assignment scope (stub).
"""
from repro.models.base import ModelConfig, register


@register("llava-next-34b")
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20_480, vocab_size=64_000,
        num_patches=2880, rope_theta=5e6, attn_impl="ref", microbatches=2,
        fsdp=True, seq_shard_activations=True,
    )


@register("llava-next-34b-smoke")
def llava_next_34b_smoke() -> ModelConfig:
    return llava_next_34b().replace(
        name="llava-next-34b-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=128, vocab_size=256, num_patches=8,
        dtype="float32", microbatches=1, fsdp=False, seq_shard_activations=False)
