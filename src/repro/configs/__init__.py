"""Assigned architecture configs (one module per arch, per assignment).

Importing this package populates the model registry; use
``repro.models.get_config(name)`` / ``list_archs()`` or the ``--arch``
flag on the launchers.
"""
from . import (qwen2_1_5b, internlm2_20b, qwen1_5_4b, qwen1_5_110b,
               whisper_base, dbrx_132b, qwen3_moe_30b, llava_next_34b,
               mamba2_1_3b, zamba2_7b)
from .shapes import SHAPES, ShapeCell, cell_applicable, input_specs, \
    cache_specs, tokens_in_cell

ASSIGNED = ["qwen2-1.5b", "internlm2-20b", "qwen1.5-4b", "qwen1.5-110b",
            "whisper-base", "dbrx-132b", "qwen3-moe-30b-a3b",
            "llava-next-34b", "mamba2-1.3b", "zamba2-7b"]

__all__ = ["SHAPES", "ShapeCell", "cell_applicable", "input_specs",
           "cache_specs", "tokens_in_cell", "ASSIGNED"]
