"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""
from repro.models.base import ModelConfig, register


@register("dbrx-132b")
def dbrx_132b() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10_752, vocab_size=100_352,
        num_experts=16, experts_per_token=4, moe_groups=256,
        rope_theta=5e5, fsdp=True, seq_shard_activations=True,
        attn_impl="ref", microbatches=4,
    )


@register("dbrx-132b-smoke")
def dbrx_132b_smoke() -> ModelConfig:
    return dbrx_132b().replace(
        name="dbrx-132b-smoke", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=96, vocab_size=256, num_experts=4,
        experts_per_token=2, capacity_factor=4.0, moe_groups=4, dtype="float32", microbatches=1, fsdp=False,
        seq_shard_activations=False)
