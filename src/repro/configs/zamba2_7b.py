"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

81 SSM layers; the single shared attention+MLP block runs after every
6th SSM layer (13 applications + 3 tail SSM layers). MHA kv=32.
"""
from repro.models.base import ModelConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14_336, vocab_size=32_000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64,
        attn_every=6, fsdp=True, attn_impl="ref", microbatches=2,
    )


@register("zamba2-7b-smoke")
def zamba2_7b_smoke() -> ModelConfig:
    return zamba2_7b().replace(
        name="zamba2-7b-smoke", num_layers=5, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, attn_every=2, dtype="float32", microbatches=1,
        fsdp=False)
