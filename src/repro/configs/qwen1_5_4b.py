"""qwen1.5-4b [dense] — MHA (kv=heads), QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.base import ModelConfig, register


@register("qwen1.5-4b")
def qwen1_5_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense",
        num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
        d_ff=6912, vocab_size=151_936, qkv_bias=True, attn_impl="blocked",
        seq_shard_activations=True, fsdp=True,
    )


@register("qwen1.5-4b-smoke")
def qwen1_5_4b_smoke() -> ModelConfig:
    return qwen1_5_4b().replace(
        name="qwen1.5-4b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, dtype="float32",
        seq_shard_activations=False, fsdp=False, attn_impl="ref")
