"""Assigned input shapes and ShapeDtypeStruct builders (dry-run inputs).

Four shape cells per architecture (assignment):
    train_4k     seq 4,096    global_batch 256   -> train_step
    prefill_32k  seq 32,768   global_batch 32    -> prefill
    decode_32k   seq 32,768   global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524,288  global_batch 1     -> serve_step; SSM/hybrid only

``input_specs`` returns weak-type-correct ShapeDtypeStructs — shardable,
zero allocation — for every model input of a cell, exactly the
shannon/kernels dry-run pattern the assignment references.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: typing.Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> typing.Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (SSM/hybrid only)."""
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 512k-KV decode is "
                       "quadratic/memory-infeasible; skipped per assignment")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for the *data* inputs of one cell.

    For decode cells the per-token input is the token ids; the KV cache is
    part of the carried state and its specs come from ``cache_specs``.
    """
    B, S = cell.global_batch, cell.seq_len
    tok = jnp.int32
    if cell.kind == "train":
        batch = {"tokens": _sds((B, S), tok), "targets": _sds((B, S), tok)}
        if cfg.family == "vlm":
            text = S - cfg.num_patches
            batch = {"tokens": _sds((B, text), tok),
                     "targets": _sds((B, text), tok),
                     "patches": _sds((B, cfg.num_patches, cfg.d_model),
                                     cfg.jnp_dtype)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.jnp_dtype)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": _sds((B, S), tok)}
        if cfg.family == "vlm":
            batch = {"tokens": _sds((B, S - cfg.num_patches), tok),
                     "patches": _sds((B, cfg.num_patches, cfg.d_model),
                                     cfg.jnp_dtype)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   cfg.jnp_dtype)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"token": _sds((B,), tok)}


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for the KV/SSM cache at this cell's depth."""
    from repro.models import api
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, cell.global_batch, cell.seq_len))
    return cache


def tokens_in_cell(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cell.kind == "decode":
        return cell.global_batch          # one new token per sequence
    return cell.global_batch * cell.seq_len
