"""whisper-base [audio] — enc-dec, conv frontend STUB. [arXiv:2212.04356]

input_specs() provides precomputed frame embeddings (B, 1500, 512); the
conv1d+mel frontend is out of assignment scope.
"""
from repro.models.base import ModelConfig, register


@register("whisper-base")
def whisper_base() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec",
        num_layers=6, encoder_layers=6, d_model=512, num_heads=8,
        num_kv_heads=8, d_ff=2048, vocab_size=51_865,
        encoder_seq=1500, attn_impl="ref", microbatches=2,
    )


@register("whisper-base-smoke")
def whisper_base_smoke() -> ModelConfig:
    return whisper_base().replace(
        name="whisper-base-smoke", num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        encoder_seq=16, dtype="float32", microbatches=1)
