from . import dmode, specs, umode
from .specs import param_specs, state_specs, batch_specs, cache_specs_tree

__all__ = ["dmode", "specs", "umode", "param_specs", "state_specs",
           "batch_specs", "cache_specs_tree"]
