"""Partition-spec rules: DP / TP (Megatron) / EP / SP / FSDP per family.

Rules are keyed on parameter *names* (the leaf's dict key) and applied to
the **trailing** dims, with leading stack dims (layers; zamba2's (G,K))
padded with None — one rule table covers every family and both the
stacked and unstacked (zamba2 shared block) layouts.

Axes:
  dp     = ("pod","data") on the multi-pod mesh, "data" on single-pod —
           pure data parallel (batch dim).
  model  = TP: attention heads / MLP ff / vocab / MoE experts / SSM heads.
  fsdp   = "data" when cfg.fsdp — params + optimizer state additionally
           sharded over the data axis (ZeRO-3-style; GSPMD inserts the
           per-layer all-gathers inside the layer scan).

Cache rules: KV heads go on "model" when divisible, otherwise the cache
*sequence* dim is model-sharded (SP decode — mandatory for kv_heads < 16
archs like qwen2-1.5b kv=2).
"""
from __future__ import annotations

import typing

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import ModelConfig


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

def _param_rule(name: str, ndim: int, cfg: ModelConfig) -> P:
    """Spec for the *trailing* dims of leaf `name` (pre-stack)."""
    f = "data" if cfg.fsdp else None
    table: typing.Dict[str, typing.Tuple] = {
        # embeddings
        "embed": ("model", f),
        "lm_head": (f, "model"),
        # attention (col-parallel qkv, row-parallel out)
        "wq": (f, "model"), "wk": (f, "model"), "wv": (f, "model"),
        "wo": ("model", f),
        "bq": ("model",), "bk": ("model",), "bv": ("model",),
        # dense MLPs
        "wg": (f, "model"), "wu": (f, "model"), "wd": ("model", f),
        "w1": (f, "model"), "w2": ("model", f),
        # SSM (column-block layout: z/x/dt head-sharded, B/C replicated —
        # B/C are shared across all heads so sharding them is pure waste)
        "wz": (f, "model"), "wx": (f, "model"), "wdt": (f, "model"),
        "wbc": (f, None), "out_proj": ("model", f),
        "conv_xw": (None, "model"), "conv_xb": ("model",),
        "conv_bcw": (None, None), "conv_bcb": (None,),
        "A_log": ("model",), "D": ("model",), "dt_bias": ("model",),
        "norm_w": ("model",),
        # router stays replicated (tiny, read by every token)
        "router": (None, None),
    }
    tail = table.get(name)
    if tail is None:
        return P()                                   # norms, scalars: replicate
    if name in ("wg", "wu", "wd") and ndim >= 4:     # MoE expert stacks
        # (..., E, d, ff): experts -> model (EP), d/ff -> fsdp
        tail = ("model", f, None) if name != "wd" else ("model", None, f)
    pad = ndim - len(tail)
    return P(*(((None,) * pad) + tuple(tail)))


def param_specs(cfg: ModelConfig, params_shape) -> typing.Any:
    """Pytree of PartitionSpec mirroring the params tree (shape-only ok)."""
    def leaf_spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        return _param_rule(name, leaf.ndim, cfg)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def state_specs(cfg: ModelConfig, state_shape) -> typing.Any:
    """TrainState specs: mu/nu mirror params; step replicated."""
    return {
        "params": param_specs(cfg, state_shape["params"]),
        "mu": param_specs(cfg, state_shape["mu"]),
        "nu": param_specs(cfg, state_shape["nu"]),
        "step": P(),
    }


# --------------------------------------------------------------------------
# batch / activation / cache rules
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch, mesh: Mesh) -> typing.Any:
    dp = dp_axes(mesh)

    def spec(path, leaf):
        b = leaf.shape[0] if leaf.shape else 0
        lead = dp if (b and _divisible(b, mesh, dp)) else None
        return P(*((lead,) + (None,) * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch)


def _divisible(b: int, mesh: Mesh, dp) -> bool:
    if dp is None:
        return False
    n = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n *= mesh.shape[a]
    return b % n == 0


def cache_specs_tree(cfg: ModelConfig, cache_shape, mesh: Mesh) -> typing.Any:
    dp = dp_axes(mesh)
    msize = model_axis_size(mesh)
    kv_on_heads = cfg.num_kv_heads and cfg.num_kv_heads % msize == 0

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        if name == "pos":
            return P()
        batch_dim_ok = _divisible(leaf.shape[1], mesh, dp) if leaf.ndim > 1 \
            else False
        b = dp if batch_dim_ok else None
        if name in ("k", "v", "xk", "xv"):
            # (L?, B, S, K, hd) — shard heads if divisible, else the
            # sequence (SP decode), else replicate (tiny caches only)
            S, K = leaf.shape[-3], leaf.shape[-2]
            if kv_on_heads and K % msize == 0:
                return P(*((None,) * (leaf.ndim - 4) + (b, None, "model",
                                                        None)))
            if S % msize == 0:
                return P(*((None,) * (leaf.ndim - 4) + (b, "model", None,
                                                        None)))
            return P(*((None,) * (leaf.ndim - 4) + (b, None, None, None)))
        if name in ("ssm", "ssm_tail"):
            # (..., B, H, N, P): heads -> model
            return P(*((None,) * (leaf.ndim - 4) + (b, "model", None, None)))
        if name in ("conv", "conv_tail"):
            # (..., B, W-1, conv_dim): channels -> model
            return P(*((None,) * (leaf.ndim - 3) + (b, None, "model")))
        return P()
    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def logits_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None, "model")


def activation_spec(cfg: ModelConfig, mesh: Mesh) -> P:
    """Residual-stream spec at layer boundaries (SP when enabled)."""
    dp = dp_axes(mesh)
    if cfg.seq_shard_activations:
        return P(dp, "model", None)
    return P(dp, None, None)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
