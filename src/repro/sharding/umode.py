"""U-mode: the unified-logical-device programming model (paper's U-MGPU).

One `jax.jit` over the whole mesh; GSPMD owns intermediate placement.
The programmer declares *only* input/output shardings (+ a few
`with_sharding_constraint` hints for SP residuals and MoE expert
buffers); the compiler decides every collective.  This is the U-MGPU
analog the case study compares against D-mode (explicit shard_map).

Builders return (step_fn, in_shardings, out_shardings) ready for
``.lower(...)`` in the dry-run or direct execution in the trainers.
"""
from __future__ import annotations

import functools
import typing

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import api
from repro.models.base import ModelConfig
from repro.train import optim
from . import specs


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_ctx(cfg: ModelConfig, mesh: Mesh) -> dict:
    """Sharding-constraint callables threaded into the model forward."""
    ctx = {}
    if cfg.seq_shard_activations:
        sp = NamedSharding(mesh, specs.activation_spec(cfg, mesh))
        ctx["sp"] = lambda h: jax.lax.with_sharding_constraint(h, sp)
    if cfg.family == "moe":
        ep = NamedSharding(mesh, P("model", None, None))
        ctx["ep"] = lambda xe: jax.lax.with_sharding_constraint(xe, ep)
        # NOTE: embedding the D-mode shard_map MoE inside the U-mode step
        # (make_moe_shard_map) was tried and REFUTED at full scale — the
        # shard_map boundary resharding inside scan+remat exploded
        # collectives 20x (EXPERIMENTS.md §Perf qwen3 iteration 2b).
        # Grouped dispatch + ep constraints is the winning configuration.
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_heads % \
            specs.model_axis_size(mesh) == 0:
        dp = specs.dp_axes(mesh)

        def bh(x, b_axis, h_axis):
            spec = [None] * x.ndim
            spec[b_axis] = dp
            spec[h_axis] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        ctx["bh"] = bh
    return ctx


def make_moe_shard_map(cfg: ModelConfig, mesh: Mesh):
    """Paper's D-MGPU lesson applied inside U-mode: the MoE block runs as
    an embedded shard_map (explicit all_to_all dispatch, dmode.ep_moe_ffn)
    instead of letting GSPMD place it.  GSPMD lowers the expert exchange
    to a model-axis ALL-GATHER of every group's dispatch buffer — 16x the
    bytes of the all-to-all a discrete program writes (§Perf qwen3-moe
    iteration 2; 5.4 GB vs 0.34 GB per layer per device)."""
    from repro.compat import shard_map
    from . import dmode

    def local(pl, xl):
        y, aux = dmode.ep_moe_ffn(pl, xl, cfg)
        return y, jax.lax.pmean(jax.lax.pmean(aux, "model"), "data")

    p_specs = {"router": P(None, None), "wg": P("model", None, None),
               "wu": P("model", None, None), "wd": P("model", None, None)}
    fn = shard_map(local, mesh=mesh,
                   in_specs=(p_specs, P(("data", "model"), None)),
                   out_specs=(P(("data", "model"), None), P()),
                   check_vma=False)

    def moe_sm(p_layer, x2d):
        return fn(p_layer, x2d)
    return moe_sm


# --------------------------------------------------------------------------
# training
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    opt_cfg: optim.OptConfig = None):
    """Returns (train_step, state_shardings, batch_specs_fn).

    train_step(state, batch) -> (state, metrics); state is donated.
    """
    opt_cfg = opt_cfg or optim.OptConfig()
    ctx = make_ctx(cfg, mesh)
    k = max(1, cfg.microbatches)

    def train_step(state, batch):
        if k == 1:
            def loss_of(p):
                return api.loss(p, cfg, batch, ctx=ctx)
            loss, grads = jax.value_and_grad(loss_of)(state["params"])
        else:
            # gradient accumulation: activation peak scales 1/k; grads
            # accumulate in f32 (one params-sized buffer)
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def one(p, mb):
                return jax.value_and_grad(
                    lambda q: api.loss(q, cfg, mb, ctx=ctx))(p)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                loss, g = one(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (loss, grads), _ = jax.lax.scan(acc_step, (0.0, zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        state, metrics = optim.adamw_update(state, grads, opt_cfg)
        return state, {"loss": loss, **metrics}

    def state_shardings(state_shape):
        return _ns(mesh, specs.state_specs(cfg, state_shape))

    def batch_shardings(batch_shape):
        return _ns(mesh, specs.batch_specs(cfg, batch_shape, mesh))

    return train_step, state_shardings, batch_shardings


def lower_train_step(cfg: ModelConfig, mesh: Mesh, batch_sds: dict,
                     opt_cfg: optim.OptConfig = None):
    """Lower (not run) the full train step for ShapeDtypeStruct inputs —
    the dry-run entry point.  Returns the jax `Lowered` object."""
    step, state_sh_fn, batch_sh_fn = make_train_step(cfg, mesh, opt_cfg)
    params_shape = jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), cfg))
    state_shape = _state_shape(params_shape)
    st_sh = state_sh_fn(state_shape)
    bt_sh = batch_sh_fn(batch_sds)
    out_metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P())}
    jitted = jax.jit(step,
                     in_shardings=(st_sh, bt_sh),
                     out_shardings=(st_sh, out_metrics_sh),
                     donate_argnums=(0,))
    state_in = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        state_shape, st_sh)
    batch_in = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        batch_sds, bt_sh)
    return jitted.lower(state_in, batch_in)


def _state_shape(params_shape):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"params": params_shape,
            "mu": jax.tree.map(f32, params_shape),
            "nu": jax.tree.map(f32, params_shape),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def make_prefill(cfg: ModelConfig, mesh: Mesh):
    def prefill_step(params, cache, batch):
        return api.prefill(params, cfg, cache, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh):
    def decode(params, cache, token):
        return api.decode_step(params, cfg, cache, token)
    return decode


def lower_serve_step(cfg: ModelConfig, mesh: Mesh, kind: str,
                     batch_sds: dict, cell=None):
    """Lower prefill or decode for the dry-run.

    decode: inputs are (params, cache, token) with the cache at the
    cell's full depth — "one new token with a KV cache of seq_len".
    Prefill always uses blocked attention (no backward pass, and the
    full (S_shard x S) score tile would not fit at 32k for the wide
    archs); training honors cfg.attn_impl.
    """
    if kind == "prefill" and cfg.num_heads and cfg.attn_impl == "ref":
        cfg = cfg.replace(attn_impl="blocked")
    from repro.configs.shapes import cache_specs
    params_shape = jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), cfg))
    p_sh = _ns(mesh, specs.param_specs(cfg, params_shape))
    params_in = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        params_shape, p_sh)
    cache_shape = cache_specs(cfg, cell)
    c_sh = _ns(mesh, specs.cache_specs_tree(cfg, cache_shape, mesh))
    cache_in = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        cache_shape, c_sh)
    logit_sh = NamedSharding(mesh, P(specs.dp_axes(mesh)
                                     if cell.global_batch > 1 else None,
                                     "model"))
    if kind == "prefill":
        fn = make_prefill(cfg, mesh)
        b_sh = _ns(mesh, specs.batch_specs(cfg, batch_sds, mesh))
        batch_in = jax.tree.map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
            batch_sds, b_sh)
        jitted = jax.jit(fn, out_shardings=(logit_sh, c_sh),
                         donate_argnums=(1,))
        return jitted.lower(params_in, cache_in, batch_in)
    fn = make_decode_step(cfg, mesh)
    tok_spec = P(specs.dp_axes(mesh)) if cell.global_batch > 1 else P()
    tok_in = jax.ShapeDtypeStruct(
        batch_sds["token"].shape, batch_sds["token"].dtype,
        sharding=NamedSharding(mesh, tok_spec))
    jitted = jax.jit(fn, out_shardings=(logit_sh, c_sh), donate_argnums=(1,))
    return jitted.lower(params_in, cache_in, tok_in)
