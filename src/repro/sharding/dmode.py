"""D-mode: the discrete programming model (paper's D-MGPU) via shard_map.

Where U-mode lets GSPMD decide every collective, D-mode is the paper's
lesson applied: the *programmer* owns data placement and every byte that
crosses a device boundary is an explicit `jax.lax` collective:

* `tp_loss`          — Megatron tensor-parallel dense transformer:
                       column/row-parallel matmuls with exactly ONE psum
                       per attention block and ONE per MLP; vocab-sharded
                       logits with a distributed (psum/pmax) softmax
                       cross-entropy — logits never materialize globally.
* `ep_moe_ffn`       — expert parallelism: capacity dispatch, one
                       all_to_all out, local expert FFN, one all_to_all
                       back (the paper's Scatter/Irregular pattern).
* `sp_flash_decode`  — sequence-parallel decode: the KV cache is
                       seq-sharded over "model"; each shard computes a
                       partial (m, l, acc) online-softmax triple and the
                       exact result combines with one pmax + two psums —
                       this is how kv_heads=2 archs use a 16-wide model
                       axis that head-sharding cannot.

Differentiable end-to-end (collectives have transpose rules), so
`jax.grad` over `tp_loss` yields a D-mode train step.
"""
from __future__ import annotations

import functools
import math
import typing

import jax
import jax.numpy as jnp
from repro.compat import axis_size, shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M
from repro.models.base import ModelConfig


# --------------------------------------------------------------------------
# Megatron TP dense transformer (explicit collectives)
# --------------------------------------------------------------------------

def _tp_attention(lp, h, cfg, positions, axis: str):
    """Column-parallel QKV (head shards), row-parallel WO, one psum."""
    B, S, _ = h.shape
    m = axis_size(axis)
    Hl = cfg.num_heads // m                     # local q heads
    q = h @ lp["wq"]                            # wq: (d, q_dim/m) local
    k = h @ lp["wk"]                            # kv replicated or sharded
    v = h @ lp["wv"]
    if "bq" in lp:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, Hl, cfg.hd)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.hd)
    ang = L.rope_angles(positions, cfg.hd, cfg.rope_theta)
    q, k = L.apply_rope(q, ang), L.apply_rope(k, ang)
    # KV is replicated across TP ranks; expand to q-head space and take
    # this rank's local heads so GQA grouping works for any Hl vs K.
    G = cfg.num_heads // cfg.num_kv_heads
    idx = jax.lax.axis_index(axis)
    k = jax.lax.dynamic_slice_in_dim(jnp.repeat(k, G, axis=2),
                                     idx * Hl, Hl, axis=2)
    v = jax.lax.dynamic_slice_in_dim(jnp.repeat(v, G, axis=2),
                                     idx * Hl, Hl, axis=2)
    o = L.attention_core(q, k, v, causal=True,
                         impl="blocked" if cfg.attn_impl != "ref" else "ref")
    o = o.reshape(B, S, Hl * cfg.hd) @ lp["wo"]  # wo: (q_dim/m, d) local
    return jax.lax.psum(o, axis)                # THE attention all-reduce


def _tp_mlp(lp, h, axis: str):
    y = (jax.nn.silu(h @ lp["wg"]) * (h @ lp["wu"])) @ lp["wd"]
    return jax.lax.psum(y, axis)                # THE mlp all-reduce


def _vocab_sharded_xent(logits_l, targets, vocab_start, axis: str):
    """Distributed cross-entropy over vocab shards: logits (B,S,V/m)."""
    logits_l = logits_l.astype(jnp.float32)
    m_local = jnp.max(jax.lax.stop_gradient(logits_l), axis=-1)
    # the shift is a stability constant: stop_gradient keeps grads exact
    # (and pmax has no transpose rule anyway)
    m_glob = jax.lax.pmax(m_local, axis)                     # (B,S)
    z = jax.lax.psum(
        jnp.sum(jnp.exp(logits_l - m_glob[..., None]), axis=-1), axis)
    Vl = logits_l.shape[-1]
    local_t = targets - vocab_start
    in_shard = (local_t >= 0) & (local_t < Vl)
    gathered = jnp.take_along_axis(
        logits_l, jnp.clip(local_t, 0, Vl - 1)[..., None], axis=-1)[..., 0]
    gold = jax.lax.psum(jnp.where(in_shard, gathered, 0.0), axis)
    return jnp.mean(jnp.log(z) + m_glob - gold)


def tp_param_specs(cfg: ModelConfig) -> dict:
    """PartitionSpecs for the D-mode local-view params (dense family)."""
    lay = {"attn": {"wq": P(None, None, "model"), "wk": P(None, None, None),
                    "wv": P(None, None, None), "wo": P(None, "model", None)},
           "mlp": {"wg": P(None, None, "model"), "wu": P(None, None, "model"),
                   "wd": P(None, "model", None)},
           "ln1": P(None, None), "ln2": P(None, None)}
    if cfg.qkv_bias:
        lay["attn"].update({"bq": P(None, "model"), "bk": P(None, None),
                            "bv": P(None, None)})
    return {"embed": P(None, None), "lm_head": P(None, "model"),
            "layers": lay, "ln_f": P(None)}


def tp_loss(cfg: ModelConfig, mesh: Mesh):
    """Returns loss_fn(params, batch) built with shard_map: DP over
    "data" (batch), TP over "model". KV is replicated across TP ranks
    (GQA kv_heads < TP size), q heads and MLP are column/row parallel."""
    assert cfg.num_heads % mesh.shape["model"] == 0, \
        f"{cfg.name}: q heads must divide the model axis for D-mode TP"

    def local_loss(p, tokens, targets):
        midx = jax.lax.axis_index("model")
        B, S = tokens.shape
        h = jnp.take(p["embed"], tokens, axis=0)
        positions = jnp.arange(S)

        def body(h, lp):
            a = _tp_attention(lp["attn"],
                              L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                              positions, "model")
            h = h + a
            y = _tp_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                        "model")
            return h + y, None

        body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, p["layers"])
        h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
        logits_l = h @ p["lm_head"]                # (B,S,V/m) vocab shard
        Vl = logits_l.shape[-1]
        nll = _vocab_sharded_xent(logits_l, targets, midx * Vl, "model")
        return jax.lax.pmean(nll, "data")

    in_specs = (tp_param_specs(cfg), P("data", None), P("data", None))
    fn = shard_map(local_loss, mesh=mesh, in_specs=in_specs,
                   out_specs=P(), check_vma=False)
    return lambda params, batch: fn(params, batch["tokens"],
                                    batch["targets"])


# --------------------------------------------------------------------------
# Expert parallelism (MoE) with explicit all_to_all
# --------------------------------------------------------------------------

def ep_moe_ffn(p, x, cfg: ModelConfig, axis: str = "model"):
    """Inside shard_map: x (T_local, d) local tokens; p holds the LOCAL
    expert slices (E_local = E/m on the expert axis) and a replicated
    router.  Two all_to_alls move each token to/from its experts."""
    m = axis_size(axis)
    T, d = x.shape
    E = cfg.num_experts
    El = E // m
    C = M.capacity(T, cfg)
    xe, meta, aux = M.dispatch_local({"router": p["router"]}, x, cfg, C)
    # (E, C, d) -> exchange -> (E_local, m*C, d): tokens for MY experts.
    # tiled=True keeps the op layout-symmetric so its VJP is the mirror
    # all_to_all (the untiled reshape form breaks cotangent layouts).
    xr = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=1,
                            tiled=True)
    ye = M.expert_ffn({"wg": p["wg"], "wu": p["wu"], "wd": p["wd"]}, xr)
    # reverse exchange: (E_local, m*C, d) -> (E, C, d) back at the senders
    yb = jax.lax.all_to_all(ye, axis, split_axis=1, concat_axis=0,
                            tiled=True)
    return M.combine_local(yb, meta, cfg).astype(x.dtype), aux


# --------------------------------------------------------------------------
# Sequence-parallel decode (flash-decode combine)
# --------------------------------------------------------------------------

def sp_flash_decode_step(q, k_shard, v_shard, lengths_local, axis="model"):
    """q (B,H,hd) one token; k/v_shard (B,Tl,K,hd) this shard's KV rows;
    lengths_local (B,) = how many rows of THIS shard are valid.
    Exact softmax over the full (sharded) sequence with one pmax + two
    psums — the collective cost is O(B*H*hd), independent of seq_len."""
    B, H, hd = q.shape
    K = k_shard.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_shard.astype(jnp.float32))
    s = s / math.sqrt(hd)
    Tl = k_shard.shape[1]
    valid = jnp.arange(Tl)[None, :] < lengths_local[:, None]     # (B,Tl)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m_loc = jnp.max(s, axis=-1)                                  # (B,K,G)
    m_glob = jax.lax.pmax(m_loc, axis)
    p = jnp.exp(s - m_glob[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l_loc = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p, v_shard.astype(jnp.float32))
    l = jax.lax.psum(l_loc, axis)
    acc = jax.lax.psum(acc, axis)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd)


def make_sp_decode_attention(mesh: Mesh, cfg: ModelConfig,
                             pos_spec: P = P()):
    """shard_map wrapper: cache seq dim sharded over "model", batch over
    "data"; returns attention(q, k_cache, v_cache, pos) -> (B,H,hd).
    Pass pos_spec=P("data") for per-slot (B,) positions."""
    def local(q, kc, vc, pos):
        m = axis_size("model")
        idx = jax.lax.axis_index("model")
        Tl = kc.shape[1]
        start = idx * Tl
        # rows valid on this shard: clip(pos+1 - start, 0, Tl)
        lengths = jnp.clip(pos + 1 - start, 0, Tl)
        if lengths.ndim == 0:                 # scalar pos -> per-row
            lengths = jnp.broadcast_to(lengths, (q.shape[0],))
        return sp_flash_decode_step(q, kc, vc, lengths)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P("data", None, None), P("data", "model", None, None),
                  P("data", "model", None, None), pos_spec),
        out_specs=P("data", None, None), check_vma=False)
