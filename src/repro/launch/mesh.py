"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure DP over DCN; "data"/"model" stay intra-pod on ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices).reshape(shape), axes)


def make_mesh(shape, axes):
    """Arbitrary mesh over the first prod(shape) devices (tests/examples)."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices).reshape(shape), axes)
