"""Training launcher.

  python -m repro.launch.train --arch qwen2-1.5b-smoke --steps 50 \
      --mesh 1x1 --batch 8 --seq 64

On this CPU container only smoke-scale configs execute; the full configs
train through the same code path on a real pod (same mesh axes, same
sharding rules — the dry-run proves they lower/compile at scale).
"""
from __future__ import annotations

import argparse

import jax

from repro.models import get_config
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, run
from repro.train.optim import OptConfig
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    report = run(cfg, mesh, data_cfg,
                 opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps,
                                   warmup_steps=max(1, args.steps // 10)),
                 loop_cfg=LoopConfig(total_steps=args.steps,
                                     ckpt_every=args.ckpt_every,
                                     ckpt_dir=args.ckpt_dir))
    print(f"final loss {report.final_loss:.4f} after {report.final_step} "
          f"steps (restarts={report.restarts})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
