"""Serving launcher: continuous-batching engine over synthetic requests.

  python -m repro.launch.serve --arch mamba2-1.3b-smoke --requests 16 \
      --slots 4 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import api, get_config
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b-smoke")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        n = int(rng.integers(4, 16))
        engine.submit(Request(uid=i,
                              prompt=rng.integers(0, cfg.vocab_size, n),
                              max_new_tokens=args.max_new))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s); stats={engine.stats()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
