import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input-shape x mesh) cell:
  1. build the production mesh (single-pod 16x16 / multi-pod 2x16x16),
  2. jit the real train_step / prefill / serve_step with the U-mode
     shardings and ``.lower()`` it on ShapeDtypeStruct inputs,
  3. ``.compile()`` — the SPMD partitioner must accept every sharding,
  4. print ``compiled.memory_analysis()`` (fits?) and
     ``compiled.cost_analysis()`` (FLOPs/bytes),
  5. parse the per-device HLO for collective payload bytes (trip-count
     scaled) and emit a JSON row for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import sys
import time
import traceback

import jax
from repro.compat import cost_analysis_dict

from repro.configs import ASSIGNED, SHAPES, cell_applicable, input_specs
from repro.core import analyze, build_terms, SINGLE_POD, MULTI_POD
from repro.core.roofline import (attention_flops, model_flops_decode,
                                 model_flops_prefill, model_flops_train)
from repro.launch.mesh import make_production_mesh
from repro.models import get_config
from repro.sharding import umode
from repro.train.optim import OptConfig


def lower_cell(cfg, cell, mesh):
    sds = input_specs(cfg, cell)
    with mesh:
        if cell.kind == "train":
            return umode.lower_train_step(cfg, mesh, sds, OptConfig())
        return umode.lower_serve_step(cfg, mesh, cell.kind, sds, cell=cell)


def model_flops_for(cfg, cell):
    n_active = cfg.active_param_count()
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        attn = 3 * attention_flops(B, S, cfg.num_heads, cfg.hd,
                                   cfg.num_layers) if cfg.num_heads else 0.0
        return model_flops_train(n_active, B * S) + attn
    if cell.kind == "prefill":
        attn = attention_flops(B, S, cfg.num_heads, cfg.hd,
                               cfg.num_layers) if cfg.num_heads else 0.0
        return model_flops_prefill(n_active, B * S, attn)
    # decode: one token/seq; KV read flops = 2*2*S*K*hd*H? -> QK^T+PV per layer
    kv_flops = (4.0 * B * cfg.num_heads * S * cfg.hd * cfg.num_layers
                if cfg.num_heads else 0.0)
    return model_flops_decode(n_active, B, kv_flops)


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = cell_applicable(cfg, cell)
    row = {"arch": arch, "shape": shape,
           "mesh": "(2,16,16)" if multi_pod else "(16,16)",
           "chips": 512 if multi_pod else 256}
    if not ok:
        row.update(status="skipped", reason=why)
        return row
    spec = MULTI_POD if multi_pod else SINGLE_POD
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, cell, mesh)
        compiled = lowered.compile()
    except Exception as e:  # a failure here is a bug in our sharding
        row.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return row
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    hlo_cost = analyze(compiled.as_text())
    terms = build_terms(
        cell=f"{arch}/{shape}", mesh_name=row["mesh"], chips=row["chips"],
        cost_analysis=ca, hlo_cost=hlo_cost, spec=spec,
        model_flops_global=model_flops_for(cfg, cell))
    row.update(
        status="ok", compile_s=round(t_compile, 1),
        argument_bytes_per_device=getattr(mem, "argument_size_in_bytes", None),
        output_bytes_per_device=getattr(mem, "output_size_in_bytes", None),
        temp_bytes_per_device=getattr(mem, "temp_size_in_bytes", None),
        peak_bytes_per_device=(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
        flops_per_device=terms.flops_per_device,
        hbm_bytes_per_device=terms.hbm_bytes_per_device,
        collective_bytes_per_device=terms.coll_bytes_per_device,
        collective_bytes_by_kind=terms.coll_bytes_by_kind,
        t_compute=terms.t_compute, t_memory=terms.t_memory,
        t_collective=terms.t_collective,
        t_collective_sim=terms.t_collective_sim,
        dominant=terms.dominant, bound_time=terms.bound_time,
        roofline_fraction=terms.roofline_fraction,
        model_flops_global=terms.model_flops_global,
        useful_ratio=terms.useful_ratio,
        unknown_trip_counts=hlo_cost.unknown_trip_counts,
    )
    if verbose:
        print(f"--- {arch}/{shape} {row['mesh']} ---")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")
        print(f"  roofline: compute={terms.t_compute:.4g}s "
              f"memory={terms.t_memory:.4g}s "
              f"collective(spec)={terms.t_collective:.4g}s "
              f"collective(sim)={terms.t_collective_sim:.4g}s "
              f"dominant={terms.dominant} "
              f"useful={terms.useful_ratio:.2f} "
              f"roofline%={100 * terms.roofline_fraction:.1f}",
              flush=True)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    if args.all:
        archs = ASSIGNED
        shapes = list(SHAPES)
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            row = run_cell(arch, shape, args.multi_pod)
            rows.append(row)
            if row["status"] == "FAILED":
                print(f"FAILED {arch}/{shape}: {row['error']}",
                      file=sys.stderr, flush=True)
            elif row["status"] == "skipped":
                print(f"skipped {arch}/{shape}: {row['reason']}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
        print(f"wrote {args.out}")
    failed = [r for r in rows if r["status"] == "FAILED"]
    print(f"\n{len(rows)} cells: {sum(r['status'] == 'ok' for r in rows)} ok, "
          f"{sum(r['status'] == 'skipped' for r in rows)} skipped, "
          f"{len(failed)} FAILED")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
