"""Analytic fabric backend: closed-form collective pricing.

The fast path and the parity oracle.  Today's ring / hierarchical /
bisection formulas live in :class:`repro.core.topology.Topology`
(validated against hand-computed micro-benchmarks in
``tests/test_sim_topology.py``); this backend prices each collective
with one formula evaluation and schedules a single completion event --
O(1) events per collective, no link state, no contention: two
collectives sharing a link are priced as if each had it to itself.
When that fidelity gap matters, switch to the ``event`` backend
(:mod:`repro.fabric.event`).
"""
from __future__ import annotations

import typing

from ..core.event import Event
from ..core.hw import s_to_ps
from .base import FabricBackend, FabricController


class AnalyticController(FabricController):
    """Prices a collective with the topology formulas and replies after
    the computed delay.  Also debits the topology's per-link byte
    counters (the analytic occupancy report)."""

    def begin(self, key, kind: str, nbytes: float,
              group: typing.List[int]) -> None:
        t = self.backend.topology.collective_time_s(kind, nbytes, [group])
        self.schedule("xfer_complete", s_to_ps(t), payload=key)

    def handle(self, event: Event) -> None:
        if event.kind == "xfer_complete":
            self.finish(event.payload)
        else:
            super().handle(event)


class AnalyticFabric(FabricBackend):
    name = "analytic"

    def make_controller(self) -> FabricController:
        return AnalyticController("fabric.ctrl", self)
