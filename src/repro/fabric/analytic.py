"""Analytic fabric backend: closed-form collective pricing.

The fast path and the parity oracle.  Today's ring / hierarchical /
bisection formulas live in :class:`repro.core.topology.Topology`
(validated against hand-computed micro-benchmarks in
``tests/test_sim_topology.py``); this backend prices each collective
with a closed-form formula and schedules a single completion event --
O(1) events per collective, no link state, no contention: two
collectives sharing a link are priced as if each had it to itself.
When that fidelity gap matters, switch to the ``event`` backend
(:mod:`repro.fabric.event`).

Batched pricing: SPMD traces complete many replica groups at the same
simulated instant (every x-ring of a 256-chip all-reduce joins
together), and pricing each group through its own Python formula walk
is the per-event tax the vectorized fast path removes.  The controller
therefore *defers* each ``start`` by one zero-delay flush event,
collects every start sharing that timestep, and prices the whole batch
with one :func:`repro.fabric.pricing.price_collectives` call --
bit-equal to the scalar formulas (asserted in ``tests/test_pricing.py``
and by the ``batch_pricing=False`` identity test in
``tests/test_fabric.py``), so completion timestamps, link debits and
every ``SimReport`` field are unchanged.
"""
from __future__ import annotations

import typing

from ..core.event import Event
from ..core.hw import s_to_ps
from . import pricing
from .base import FabricBackend, FabricController


class AnalyticController(FabricController):
    """Prices collectives with the topology formulas and replies after
    the computed delay.  Also debits the topology's per-link byte
    counters (the analytic occupancy report).

    With ``backend.batch_pricing`` (the default), same-timestep starts
    are accumulated and priced in one vectorized call; otherwise each
    start is priced scalar and immediately -- both paths are bit-equal.
    """

    def __init__(self, name: str, backend: "AnalyticFabric") -> None:
        super().__init__(name, backend)
        self._pending: list = []       # same-timestep starts awaiting flush
        self._flush_at: int = -1       # timestep a flush is scheduled for
        self._class_memo: dict = {}    # group tuple -> class code
        self.batched_pricings = 0      # collectives priced via vector calls
        self.flushes = 0               # vectorized flush rounds

    def begin(self, key, kind: str, nbytes: float,
              group: typing.List[int]) -> None:
        if not self.backend.batch_pricing:
            t = self.backend.topology.collective_time_s(kind, nbytes, [group])
            self.schedule("xfer_complete", s_to_ps(t), payload=key)
            return
        self._pending.append((key, kind, nbytes, group))
        if self._flush_at != self.engine.now:
            self._flush_at = self.engine.now
            self.schedule("price_flush", 0)

    def _flush(self) -> None:
        pending, self._pending = self._pending, []
        self._flush_at = -1
        topo = self.backend.topology
        if len(pending) == 1:
            # a lone start gains nothing from array dispatch overhead
            key, kind, nbytes, group = pending[0]
            t = topo.collective_time_s(kind, nbytes, [group])
            self.schedule("xfer_complete", s_to_ps(t), payload=key)
            return
        times = pricing.price_collectives(
            topo, [(kind, nbytes, tuple(group))
                   for _, kind, nbytes, group in pending],
            memo=self._class_memo)
        for (key, kind, nbytes, group), t in zip(pending, times):
            topo.debit_links(kind, nbytes, [group])
            self.schedule("xfer_complete", s_to_ps(float(t)), payload=key)
        self.batched_pricings += len(pending)
        self.flushes += 1

    def handle(self, event: Event) -> None:
        if event.kind == "xfer_complete":
            self.finish(event.payload)
        elif event.kind == "price_flush":
            self._flush()
        else:
            super().handle(event)


class AnalyticFabric(FabricBackend):
    name = "analytic"

    def __init__(self, spec, batch_pricing: bool = True) -> None:
        super().__init__(spec)
        self.batch_pricing = batch_pricing

    def make_controller(self) -> FabricController:
        return AnalyticController("fabric.ctrl", self)

    def link_report(self) -> dict:
        # Under the procs executor the controller is shard-resident: the
        # worker debits *its replica's* backend.topology, and end-of-run
        # sync replaces controller.backend with that replica.  Read the
        # report through the controller so the debits survive shard
        # residency (the parent-held self.topology stays pristine there).
        if self.controller is not None:
            return self.controller.backend.topology.link_report()
        return self.topology.link_report()

    def describe(self) -> dict:
        d = super().describe()
        d["batch_pricing"] = self.batch_pricing
        if self.controller is not None:
            d["batched_pricings"] = self.controller.batched_pricings
            d["pricing_flushes"] = self.controller.flushes
        return d
