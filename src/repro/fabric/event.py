"""Event-driven fabric backend: links and DMA engines as components.

Where the ``analytic`` backend prices a collective with one closed-form
evaluation, this backend *executes* it on the engine timeline:

* every directed ICI link, every pod DCN uplink and every pod bisection
  channel is a :class:`FabricLink` component with its own serialization
  queue (``busy_until_ps``) -- concurrent transfers on a shared link
  queue behind each other, which is exactly the contention the analytic
  formulas cannot express;
* every chip has a :class:`DmaEngine` component that walks the chip's
  per-hop transfer program (ring steps over the 2-D torus, hierarchical
  reduce over DCN) hop by hop;
* the :class:`EventController` decomposes each collective into those
  per-chip programs (:func:`decompose`) and reports completion when the
  last DMA engine drains.

The decomposition mirrors the analytic formulas step for step, so on an
uncongested single collective both backends agree to rounding error
(asserted in ``tests/test_fabric.py``); they diverge -- the event
backend slower, i.e. more faithful -- exactly when transfers overlap on
shared links (multi-tenant traces, concurrent cross-pod groups,
multi-hop collective-permutes through a common chip).

Fabric traffic rides the latency-carrying :class:`FabricXbar`: every
protocol leg (program dispatch, transfer request, ack/chunk return,
completion) is priced out of the step's own hop/DCN latency budget (see
:class:`Legs`), so no leg is zero-latency and the lookahead scheduler
does NOT fuse the fabric into one sequential cluster.  Instead each
chip's DMA engine plus its four ICI links form one cluster (via
``cluster_affinity``) and every DCN/bisection link is its own, letting
a windowed scheduler replay link traffic for distinct chips
concurrently.  The leg budget is carved so each step still totals
exactly ``bytes/bw + step_latency`` and a whole program's walltime is
identical to a zero-latency-bus replay -- parity with the analytic
oracle is preserved to ``s_to_ps`` rounding, and all schedulers remain
bit-identical (the commit-phase ordering argument in docs/engine.md).

Decompositions additionally carry the *consumer data dependency*
(delivered as ``chunk`` requests to the downstream DMA): each ring
step ``i+1`` waits for the chunks its two ring neighbors forwarded in
step ``i``; a ring all-to-all's single exchange step waits on both
neighbors the same way; and a collective-permute receiver closes with
an arrival gate fed by the final hop of its producer's store-and-
forward chain.  On a healthy fabric the chunks arrive exactly when the
consumers' own acks/gates fall due, so timing is unchanged; under a
degraded or transiently failed link the stall now propagates to every
data consumer -- a whole ring, both a2a neighbors, a permute receiver
-- instead of pinning only the sending chip's chain: the honest
failure mode.

Fault surface: links and DMA engines are ordinary components, so
``hooks.FaultInjector`` can degrade a *single link* by name (e.g.
``{"fabric.pod0.ici[0,1]+x": [(0.0, "slow", 8.0)]}``) -- straggler
links, flapping ("transient") links, not just straggler chips.  See
docs/faults.md for the full plan grammar.
"""
from __future__ import annotations

import dataclasses
import typing

from ..core.component import Component
from ..core.connection import Connection, LagNode, Request
from ..core.event import Event
from ..core.hw import s_to_ps
from .base import FabricBackend, FabricController
from .plancache import cached_decompose


# -- per-chip transfer programs ----------------------------------------------

@dataclasses.dataclass(frozen=True)
class Xfer:
    """One transfer on one named link (parallel within a DmaStep).

    ``dst_chip`` names the consuming chip whose DMA engine receives the
    chunk (None for transfers without a modeled consumer, e.g. DCN or
    bisection aggregates): the link forwards a ``chunk`` notification
    there, which the consumer's matching step waits on.  ``dst_step``
    tags which of the consumer's steps banks the chunk; None means
    "same index as the producing step" (symmetric rings, where both
    programs advance in lockstep) -- multi-hop collective-permute paths
    of differing lengths set it explicitly.
    """
    link: str
    bytes: int
    dst_chip: typing.Optional[int] = None
    dst_step: typing.Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DmaStep:
    """Parallel transfers + a post-step latency (hop / DCN one-way).

    ``arrivals`` is the number of producer ``chunk`` notifications this
    step must collect (in addition to its own transfer acks) before the
    program may advance -- the collective data dependency.  A step with
    no transfers, zero latency and ``arrivals > 0`` is a pure *arrival
    gate* (a receiver waiting on inbound data, e.g. the closing step of
    a collective-permute consumer): it costs no simulated time of its
    own and completes the moment its chunks are banked.
    """
    xfers: tuple                  # tuple[Xfer, ...]; may be empty
    latency_ps: int = 0
    arrivals: int = 0


@dataclasses.dataclass(frozen=True)
class Legs:
    """Per-kind latency budget of the fabric bus (all integer ps).

    Every leg is carved out of the step latency it accompanies: a step
    costs ``xfer_ps`` on the request leg, ``bytes/bw`` serializing on
    the link, and ``latency_ps - xfer_ps`` on the ack/chunk leg -- so
    one step still totals ``bytes/bw + latency_ps``.  The final step's
    ack additionally absorbs ``exec_ps + done_ps``, cancelling the
    program-dispatch and completion legs: the whole program's walltime
    equals the zero-latency-bus replay exactly.  ``floor_ps`` (the
    xbar's ``min_latency_ps``, hence the lookahead window bound) is the
    minimum any leg may take; all four default to a quarter of the
    smallest link latency so even a one-step program (latency = one
    hop) fits ``exec + xfer + ack + done``.
    """
    exec_ps: int                  # controller -> DMA program dispatch
    xfer_ps: int                  # DMA -> link transfer request
    done_ps: int                  # DMA -> controller completion
    floor_ps: int                 # lower bound on any bus leg


ZERO_LEGS = Legs(0, 0, 0, 0)


def make_legs(topo) -> Legs:
    """Size the bus legs from the topology's smallest link latency.

    A zero hop latency degrades gracefully: all legs become zero, the
    xbar turns zero-latency and ``Engine.compute_clusters`` fuses the
    whole fabric back into one sequential cluster (the pre-latency
    behavior -- correct, just serial).
    """
    q = s_to_ps(topo.min_link_latency_s()) // 4
    return Legs(exec_ps=q, xfer_ps=q, done_ps=q, floor_ps=q)


class _Xmit:
    """Routing envelope for xfer / xfer_done / chunk requests on the
    fabric bus.  ``ack_ps`` is the connection latency of the returning
    ack AND of the forwarded neighbor chunk (computed by the issuing
    DMA from the step's latency budget); ``step`` tags which program
    step a chunk belongs to at the consuming neighbor.  One ``_Xmit``
    is allocated per transfer -- the densest payload on the fabric --
    so it is a bare ``__slots__`` class."""

    __slots__ = ("link", "chip", "key", "ack_ps", "dst_chip", "step")

    def __init__(self, link: str, chip: int, key: typing.Any,
                 ack_ps: int = 0, dst_chip: typing.Optional[int] = None,
                 step: int = 0) -> None:
        self.link = link
        self.chip = chip
        self.key = key
        self.ack_ps = ack_ps
        self.dst_chip = dst_chip
        self.step = step


def _dma_name(chip: int) -> str:
    return f"fabric.chip{chip}.dma"


def _ici(topo, device: int, dirn: str) -> str:
    pod, y, x = topo.coords(device)
    return f"fabric.pod{pod}.ici[{y},{x}]{dirn}"


# -- components ---------------------------------------------------------------

class FabricLink(Component):
    """A serialized, bandwidth-limited channel (ICI link, DCN uplink or
    bisection aggregate).  Transfers queue on ``busy_until_ps``; the
    FaultInjector's ``slow`` action stretches transfer durations (a
    degraded / straggler link), ``fail``/``transient`` drops transfers
    on the floor (they are never acked -- the sender, and with ring
    dependencies the whole ring, stalls)."""

    def __init__(self, name: str, bandwidth: float) -> None:
        super().__init__(name)
        self.bandwidth = bandwidth
        self.busy_until_ps = 0
        self.bytes_total = 0
        self.busy_ps = 0
        self.bus = self.port("bus")         # cached: hot on every transfer

    def handle(self, event: Event) -> None:
        now = event.time                    # == engine.now inside a handler
        if event.kind == "request":            # an xfer from a DMA engine
            req: Request = event.payload
            start = max(now, self.busy_until_ps)
            dur = s_to_ps(req.size_bytes / self.bandwidth
                          * self.fault_slow_factor)
            end = start + dur
            self.busy_until_ps = end
            self.bytes_total += req.size_bytes
            self.busy_ps += dur
            self.mark_busy(start, end, "xfer")
            self.schedule("xmit_done", end - now, payload=req.payload)
        elif event.kind == "xmit_done":
            xm: _Xmit = event.payload
            bus = self.bus
            bus.send(Request(src=bus, dst=None, kind="xfer_done",
                             payload=xm))
            if xm.dst_chip is not None:
                # ring data dependency: forward the chunk to the
                # consuming neighbor's DMA engine
                bus.send(Request(src=bus, dst=None, kind="chunk",
                                 payload=xm))


class DmaEngine(Component):
    """Walks per-collective hop programs for one chip: issue a step's
    transfers, wait for all of their acks plus any neighbor chunk
    arrivals the step declares, advance.  Multiple collectives
    (different keys) may be in flight at once -- their transfers contend
    on the links, not here.

    Step latency rides the bus legs (see :class:`Legs`), not a local
    timer: the ack returns ``latency_ps - xfer_ps`` after serialization,
    so the step still totals ``bytes/bw + latency_ps``.  A FaultInjector
    ``slow`` on this DMA engine stretches the step turnaround by
    ``(factor - 1) x latency`` on top (a straggling DMA issues hops more
    slowly), preserving the pre-latency fault arithmetic exactly.
    """

    def __init__(self, name: str, chip: int, legs: Legs = ZERO_LEGS) -> None:
        super().__init__(name)
        self.chip = chip
        self.legs = legs
        self.bus = self.port("bus")  # cached: hot on every step/ack
        self._progs: dict = {}     # key -> [steps, idx, final step idx]
        self._acks: dict = {}      # key -> outstanding xfer acks this step
        self._arrived: dict = {}   # (key, step idx) -> banked chunk count
        self._timed: set = set()   # keys waiting on a step_done timer

    def progress(self) -> dict:
        """Current step index per in-flight collective key (observable
        for ring-stall studies: a stalled ring shows every member pinned
        within one step of the faulted link's sender)."""
        return {key: prog[1] for key, prog in self._progs.items()}

    def handle(self, event: Event) -> None:
        if event.kind == "request":
            req: Request = event.payload
            if req.kind == "exec":
                _, key, steps = req.payload
                # The *final* step for walltime accounting is the last
                # one that costs simulated time (transfers or latency);
                # trailing arrival gates ride for free, so the exec/done
                # leg absorption stays on the step whose ack actually
                # closes the program's time budget.
                final = len(steps) - 1
                while final >= 0 and not (steps[final].xfers
                                          or steps[final].latency_ps > 0):
                    final -= 1
                self._progs[key] = [steps, 0, final]
                self._start_step(key)
            elif req.kind == "xfer_done":
                key = req.payload.key
                self._acks[key] -= 1
                self._maybe_finish_step(key)
            elif req.kind == "chunk":
                xm: _Xmit = req.payload
                slot = (xm.key, xm.step)
                self._arrived[slot] = self._arrived.get(slot, 0) + 1
                self._maybe_finish_step(xm.key)
        elif event.kind == "step_done":
            key = event.payload
            self._timed.discard(key)
            self._advance(key)

    def _maybe_finish_step(self, key) -> None:
        prog = self._progs.get(key)
        if prog is None or key in self._timed:
            return                 # late chunk for a finished/timed step
        steps, idx = prog[0], prog[1]
        if self._acks.get(key, 0) > 0:
            return
        step: DmaStep = steps[idx]
        if self._arrived.get((key, idx), 0) < step.arrivals:
            return                 # still waiting on ring neighbors
        self._arrived.pop((key, idx), None)
        extra = int(round(step.latency_ps * (self.fault_slow_factor - 1.0)))
        if extra > 0:              # straggling DMA: stretched turnaround
            self._timed.add(key)
            self.schedule("step_done", extra, payload=key)
        else:
            self._advance(key)

    def _advance(self, key) -> None:
        prog = self._progs[key]
        prog[1] += 1
        if prog[1] < len(prog[0]):
            self._start_step(key)
        else:
            del self._progs[key]
            self._acks.pop(key, None)
            for slot in [s for s in self._arrived if s[0] == key]:
                del self._arrived[slot]
            bus = self.bus
            bus.send(Request(src=bus, dst=None, kind="dma_done",
                             payload=(self.chip, key)))

    def _start_step(self, key) -> None:
        steps, idx, final_idx = self._progs[key]
        step: DmaStep = steps[idx]
        final = idx == final_idx
        legs = self.legs
        if not step.xfers:
            if step.arrivals and not step.latency_ps:
                # Arrival gate: no time of its own -- completes when the
                # producers' chunks are banked (possibly already).
                self._maybe_finish_step(key)
                return
            # Timed step (no transfers): the latency is waited locally; a
            # final timed step also absorbs the exec/done legs so program
            # walltime stays exact.
            residual = step.latency_ps - (legs.exec_ps + legs.done_ps
                                          if final else 0)
            self._timed.add(key)
            self.schedule(
                "step_done",
                max(0, int(round(residual * self.fault_slow_factor))),
                payload=key)
            return
        ack = step.latency_ps - legs.xfer_ps
        if final:
            ack -= legs.exec_ps + legs.done_ps
        ack = max(legs.floor_ps, ack)
        self._acks[key] = len(step.xfers)
        bus = self.bus
        for x in step.xfers:
            bus.send(Request(
                src=bus, dst=None, kind="xfer", size_bytes=int(x.bytes),
                payload=_Xmit(x.link, self.chip, key, ack, x.dst_chip,
                              idx if x.dst_step is None else x.dst_step)))


# -- bounded-lag refinement predicates (see FabricXbar.cluster_edges) --------

def _dispatch_pred(ev: Event) -> bool:
    """Controller-cluster events that may lead to an ``exec`` dispatch:
    everything *except* a pending ``dma_done`` completion (whose handler
    only does bookkeeping; a new dispatch needs a coordinator round trip
    first).  Unknown event shapes conservatively count."""
    p = ev.payload
    return not (ev.kind == "request" and isinstance(p, Request)
                and p.kind == "dma_done")


def _queued_xfer_pred(ranks: set):
    """Events at this cluster's links that have not serialized yet
    (transfer requests and anything else that is not an in-flight
    ``xmit_done``).  ``ranks`` is shared with the wire pred and keeps
    growing while the plan walk discovers the cluster's links."""
    def pred(ev: Event) -> bool:
        return ev.component.rank in ranks and ev.kind != "xmit_done"
    return pred


def _in_flight_pred(ranks: set):
    """Serializations already on the wire: their chunk/ack leaves after
    the step's ack leg, no serialization left to pay."""
    def pred(ev: Event) -> bool:
        return ev.kind == "xmit_done" and ev.component.rank in ranks
    return pred


class FabricXbar(Connection):
    """Routing bus for all fabric traffic.  Routing lives in the
    connection (DP-3): components address links / DMA engines / the
    controller by *name* in the request payload, never by reference.

    Unlike a plain Connection it prices each leg of the replay protocol
    individually (:class:`Legs`); ``min_latency_ps`` -- the bound the
    lookahead window derives from -- is the legs' common floor.  With a
    nonzero floor the xbar is never fused, so its endpoint clusters
    (chip DMA+links islands, DCN/bisection links, the controller) replay
    in parallel under windowed schedulers.
    """

    def __init__(self, name: str, controller, legs: Legs = ZERO_LEGS,
                 topology=None) -> None:
        super().__init__(name)
        self.controller = controller
        self.legs = legs
        self.topology = topology            # None -> clique cluster_edges
        self.registry: dict = {}
        # Shared reference to the backend's noted collective plans
        # (``EventFabric.note_plan``); non-empty -> trace-exact edges.
        self.plans = None

    @property
    def min_latency_ps(self) -> int:
        return self.legs.floor_ps

    def cluster_edges(self):
        """The xbar's true routing graph, instead of the default clique
        over its (many) endpoints.  Without this, one shared bus couples
        every fabric cluster to the global minimum time and bounded-lag
        horizons collapse back into the global barrier.

        Both modes route controller dispatch through a *gate* node: the
        controller's ``dma_done`` handler only completes bookkeeping and
        reports to the coordinator -- it never issues a new ``exec``
        directly, that always takes a full coordinator round trip over
        the collective star first (two control-latency hops).  Excluding
        pending completions from the dispatch bound is what lets a chip
        run deep into its DMA program while its own ``dma_done`` for the
        *previous* collective still sits at the controller.

        Without noted plans, edges mirror ``_resolve_dst`` /
        ``decompose`` conservatively:

        * gate -> each chip cluster (``exec``), chip -> controller
          (``dma_done``);
        * chip cluster <-> its pod's DCN / bisection links: ``xfer``
          requests out, ``xfer_done`` acks back;
        * per-pod chip clique at the leg floor: ring/a2a chunks go to
          torus neighbors, but collective-permute store-and-forward
          issues ``xfer`` on *any* link along an intra-pod torus path
          (and its ack returns from there), so the honest per-``xfer``
          reach inside a pod is every other chip.  Rings never leave a
          pod -- cross-pod traffic rides the DCN -- so no chip-to-chip
          edge crosses pods.

        With plans noted (``System.load_trace`` forwards every planned
        collective), the per-pod cliques are replaced by the exact
        per-link transfer graph of the planned programs -- see
        :meth:`_planned_edges`.  Collectives *not* noted while plans
        are in effect fail loudly at the strict-window guard, never
        silently: the declared edges stop being a superset of the
        traffic.
        """
        topo = self.topology
        if topo is None:                    # standalone xbar: default clique
            yield from super().cluster_edges()
            return
        legs = self.legs
        ctrl = self.controller.cluster_id
        registry = self.registry
        gate = LagNode("fabric.ctrl.dispatch", ctrl, pred=_dispatch_pred,
                       inherit_inputs=True)
        by_pod: dict = {}
        for d in range(topo.spec.total_chips):
            cid = registry[_dma_name(d)].cluster_id
            by_pod.setdefault(topo.coords(d)[0], []).append(cid)
            yield (gate, cid, legs.exec_ps)
            yield (cid, ctrl, legs.done_ps)
        if self.plans:
            yield from self._planned_edges()
            return
        for pod, chips in by_pod.items():
            pod_links = []
            for kind in ("dcn", "bisect"):
                link = registry.get(f"fabric.pod{pod}.{kind}")
                if link is not None:
                    pod_links.append(link.cluster_id)
            for cid in chips:
                for lid in pod_links:
                    yield (cid, lid, legs.xfer_ps)
                    yield (lid, cid, legs.floor_ps)
                for other in chips:
                    if other != cid:
                        yield (cid, other, legs.floor_ps)

    def _planned_edges(self):
        """Trace-exact link-level edges for the noted collective plans.

        Each plan is re-decomposed into its per-chip DMA programs (the
        same :func:`decompose` the controller will run), and every
        transfer contributes its true legs.  Per link *cluster* two
        refinement nodes split the two event classes a link holds:

        * ``queue`` -- transfer requests not yet serialized.  Before
          anything leaves the link they must serialize, so the only
          out-edge is ``queue -> wire`` at the minimum serialization
          time of any planned transfer on those links (``bytes / bw``;
          fault ``slow`` only stretches it).
        * ``wire`` -- in-flight serializations (``xmit_done``).  These
          ack the issuing DMA and hand ring chunks to the consuming
          neighbor no earlier than the step's ack leg.

        Splitting matters because a chip fuses with its own four ICI
        links: one node would bound the *neighbor's* horizon by the
        chip's earliest pending event + one ack, hiding the
        serialization the chunk still has to pay.
        """
        topo, legs, registry = self.topology, self.legs, self.registry
        qnode: dict = {}                    # link cluster -> queue LagNode
        wnode: dict = {}                    # link cluster -> wire LagNode
        lranks: dict = {}                   # link cluster -> link ranks (grows)
        mindur: dict = {}                   # link cluster -> min serialization
        edges: list = []
        for kind, nbytes, group in self.plans:
            for d, steps in cached_decompose(topo, kind, float(nbytes),
                                             list(group)).items():
                src = registry[_dma_name(d)].cluster_id
                final = len(steps) - 1
                while final >= 0 and not (steps[final].xfers
                                          or steps[final].latency_ps > 0):
                    final -= 1
                for idx, st in enumerate(steps):
                    if not st.xfers:
                        continue
                    # mirrors DmaEngine._start_step ack arithmetic
                    ack = st.latency_ps - legs.xfer_ps
                    if idx == final:
                        ack -= legs.exec_ps + legs.done_ps
                    ack = max(legs.floor_ps, ack)
                    for x in st.xfers:
                        link = registry[x.link]
                        lcid = link.cluster_id
                        ranks = lranks.get(lcid)
                        if ranks is None:
                            ranks = lranks[lcid] = set()
                            qnode[lcid] = LagNode(
                                f"links{lcid}.queue", lcid,
                                pred=_queued_xfer_pred(ranks))
                            wnode[lcid] = LagNode(
                                f"links{lcid}.wire", lcid,
                                pred=_in_flight_pred(ranks))
                        ranks.add(link.rank)
                        dur = s_to_ps(int(x.bytes) / link.bandwidth)
                        prev = mindur.get(lcid)
                        if prev is None or dur < prev:
                            mindur[lcid] = dur
                        edges.append((src, qnode[lcid], legs.xfer_ps))
                        edges.append((wnode[lcid], src, ack))
                        if x.dst_chip is not None:
                            edges.append(
                                (wnode[lcid],
                                 registry[_dma_name(x.dst_chip)].cluster_id,
                                 ack))
        for lcid, qn in qnode.items():
            edges.append((qn, wnode[lcid], mindur[lcid]))
        return edges

    def transfer_time_ps(self, request: Request) -> int:
        legs = self.legs
        if request.kind == "xfer":
            return legs.xfer_ps
        if request.kind in ("xfer_done", "chunk"):
            return request.payload.ack_ps
        if request.kind == "exec":
            return legs.exec_ps
        if request.kind == "dma_done":
            return legs.done_ps
        return legs.floor_ps

    def attach(self, component, port_name: str = "bus") -> None:
        self.plug(component.port(port_name))
        self.registry[component.name] = component

    def _resolve_dst(self, src_port, request: Request) -> None:
        if request.dst is not None:
            return
        if request.kind == "xfer":
            request.dst = self.registry[request.payload.link]
        elif request.kind == "xfer_done":
            request.dst = self.registry[_dma_name(request.payload.chip)]
        elif request.kind == "chunk":
            request.dst = self.registry[_dma_name(request.payload.dst_chip)]
        elif request.kind == "exec":
            request.dst = self.registry[_dma_name(request.payload[0])]
        elif request.kind == "dma_done":
            request.dst = self.controller


class EventController(FabricController):
    """Decomposes collectives into per-chip DMA programs and completes a
    key when every participating DMA engine reports done."""

    def __init__(self, name: str, backend: "EventFabric") -> None:
        super().__init__(name, backend)
        self._pending: dict = {}   # key -> DMAs still running

    def begin(self, key, kind: str, nbytes: float,
              group: typing.List[int]) -> None:
        # content-hashed plan cache: the same (topology, kind, bytes,
        # group) triple decomposes once per process (or once per sweep,
        # with the disk tier) -- the cached programs are read-only and
        # this filter copies into a fresh dict before use
        progs = cached_decompose(self.backend.topology, kind,
                                 float(nbytes), group)
        progs = {d: steps for d, steps in progs.items() if steps}
        if not progs:
            self.schedule("noop_done", 0, payload=key)
            return
        self._pending[key] = len(progs)
        for chip in sorted(progs):
            self.port("bus").send(Request(
                src=self.port("bus"), dst=None, kind="exec",
                payload=(chip, key, tuple(progs[chip]))))

    def handle(self, event: Event) -> None:
        if event.kind == "request" and event.payload.kind == "dma_done":
            _, key = event.payload.payload
            self._pending[key] -= 1
            if self._pending[key] == 0:
                del self._pending[key]
                self.finish(key)
        elif event.kind == "noop_done":
            self.finish(event.payload)
        else:
            super().handle(event)


# -- collective decomposition (mirrors topology.py's analytic formulas) ------

def _ring_neighbors(topo, members, axis: str) -> tuple:
    """Successor/predecessor maps along ``axis`` for the physical wrap
    rings the members form (rows keyed by the orthogonal coordinates).
    Members alone in their row -- e.g. cross-pod representatives whose
    closing exchange is not a physical ring -- get no neighbors and
    therefore no data dependency."""
    rows: dict = {}
    for d in members:
        pod, y, x = topo.coords(d)
        rows.setdefault((pod, y) if axis == "x" else (pod, x), []).append(d)
    succ: dict = {}
    pred: dict = {}
    for row in rows.values():
        if len(row) < 2:
            continue
        row.sort(key=lambda d: topo.coords(d)[2 if axis == "x" else 1])
        for i, d in enumerate(row):
            succ[d] = row[(i + 1) % len(row)]
            pred[d] = row[(i - 1) % len(row)]
    return succ, pred


def _ring_steps(topo, members, axis: str, B: float, phases: int,
                ring_n: int = None) -> dict:
    """Bidirectional ring: each step moves B/(2n) per direction per chip.
    ``phases*(n-1)`` steps of ``chunk/bw + hop`` reproduce ``_ring_time``.
    Each step carries the ring data dependency: the +axis chunk feeds
    the successor, the -axis chunk the predecessor, and the chip's next
    step waits for the matching chunks from both neighbors."""
    n = ring_n or len(members)
    hop = s_to_ps(topo.spec.chip.ici_hop_latency_s)
    chunk = int(round(B / (2 * n)))
    nsteps = phases * (n - 1)
    succ, pred = _ring_neighbors(topo, members, axis)
    out = {}
    for d in members:
        plus, minus = _ici(topo, d, "+" + axis), _ici(topo, d, "-" + axis)
        arrivals = (d in succ) + (d in pred)
        out[d] = [DmaStep((Xfer(plus, chunk, succ.get(d)),
                           Xfer(minus, chunk, pred.get(d))), hop, arrivals)
                  for _ in range(nsteps)]
    return out


def _block_steps(topo, members, m: int, B: float, phases: int) -> dict:
    """Hierarchical 2-D: x-ring phase with B, then y-ring with B/nx --
    the event-space image of ``_block2d_time``."""
    nx = min(topo.X, m)
    ny = max(1, m // nx)
    out = _ring_steps(topo, members, "x", B, phases, ring_n=nx)
    if ny > 1:
        for d, steps in _ring_steps(topo, members, "y", B / nx, phases,
                                    ring_n=ny).items():
            out[d] = out[d] + steps
    return out


def _merge(progs: dict, extra: dict) -> None:
    for d, steps in extra.items():
        progs[d] = progs.get(d, []) + steps


def _torus_path(topo, src: int, dst: int) -> typing.List[str]:
    """Directed link names along the x-then-y torus-shortest route."""
    pod, y, x = topo.coords(src)
    _, y2, x2 = topo.coords(dst)
    X, Y = topo.X, topo.Y
    links = []
    dx = (x2 - x) % X
    sx, nx = ("+x", dx) if dx <= X - dx else ("-x", X - dx)
    for _ in range(nx):
        links.append(f"fabric.pod{pod}.ici[{y},{x}]{sx}")
        x = (x + (1 if sx == "+x" else -1)) % X
    dy = (y2 - y) % Y
    sy, ny = ("+y", dy) if dy <= Y - dy else ("-y", Y - dy)
    for _ in range(ny):
        links.append(f"fabric.pod{pod}.ici[{y},{x}]{sy}")
        y = (y + (1 if sy == "+y" else -1)) % Y
    return links


def _cross_pod_steps(topo, kind: str, B: float, group) -> dict:
    """Hierarchical intra-pod + DCN exchange; mirrors ``_cross_pod_time``
    (with its n_groups=1 per-coordinator-call specialization).  The DCN
    transfer and any closing broadcast phase run on each pod's
    representative chip, so concurrent cross-pod groups queue on the
    shared :class:`FabricLink` DCN uplink -- the contention the analytic
    formula only models *within* one call's group list."""
    spec = topo.spec
    pods = spec.num_pods
    n = len(group)
    per_pod = max(1, n // pods)
    if kind == "all-reduce":
        eff = 2 * (pods - 1) / pods
    else:                          # ag / rs / a2a / permute, as analytic
        eff = (pods - 1) / pods
    by_pod: dict = {}
    for d in group:
        by_pod.setdefault(topo.coords(d)[0], []).append(d)
    progs = {d: [] for d in group}
    Bx = B
    if per_pod > 1:
        _merge(progs, _block_steps(topo, group, per_pod, B, 1))
        Bx = B / per_pod
    dcn_lat = s_to_ps(spec.chip.dcn_latency_s)
    dcn_bytes = int(round(Bx * eff))
    reps = []
    for pod in sorted(by_pod):
        rep = min(by_pod[pod])
        reps.append(rep)
        progs[rep] = progs[rep] + [DmaStep(
            (Xfer(f"fabric.pod{pod}.dcn", dcn_bytes),), dcn_lat)]
    if per_pod > 1 and kind in ("all-reduce", "all-gather"):
        _merge(progs, _block_steps(topo, reps, per_pod, B, 1))
    return progs


def decompose(topo, kind: str, B: float, group: typing.List[int]) -> dict:
    """Per-chip DMA programs for one collective over one replica group."""
    n = len(group)
    if n <= 1:
        return {}
    cls = topo.classify_group(group)
    spec = topo.spec
    c = spec.chip
    if cls == "cross_pod":
        return _cross_pod_steps(topo, kind, B, group)
    axis = "x" if cls == "ring_x" else "y"
    if kind == "all-reduce":
        return (_ring_steps(topo, group, axis, B, 2) if cls.startswith("ring")
                else _block_steps(topo, group, n, B, 2))
    if kind in ("all-gather", "reduce-scatter"):
        return (_ring_steps(topo, group, axis, B, 1) if cls.startswith("ring")
                else _block_steps(topo, group, n, B, 1))
    if kind == "all-to-all":
        if cls.startswith("ring"):
            # Single exchange step, but with the same consumer
            # dependency as the ring phases: each chip's step also waits
            # for its two neighbors' chunks, so a failed link stalls the
            # neighbors' programs, not just the sender's ack chain.  On
            # a healthy symmetric ring the chunks arrive exactly when
            # the chip's own acks do -- timing is unchanged.
            load = int(round(B * (n - 1) / 8))
            post = s_to_ps(n / 2 * c.ici_hop_latency_s)
            succ, pred = _ring_neighbors(topo, group, axis)
            return {d: [DmaStep(
                (Xfer(_ici(topo, d, "+" + axis), load, succ.get(d)),
                 Xfer(_ici(topo, d, "-" + axis), load, pred.get(d))),
                post, (d in succ) + (d in pred))]
                    for d in group}
        post = s_to_ps((topo.X / 2 + topo.Y / 2) * c.ici_hop_latency_s)
        return {d: [DmaStep(
            (Xfer(f"fabric.pod{topo.coords(d)[0]}.bisect",
                  int(round(B / 2))),), post)] for d in group}
    if kind == "collective-permute":
        # Store-and-forward chain per (src -> dst) pair, plus the
        # consumer dependency: the final hop forwards its chunk to the
        # destination's DMA, whose program closes with an arrival gate.
        # A fault anywhere on the path therefore stalls the *receiver*
        # too.  Healthy walltime is unchanged: the gate is free, the
        # chunk rides the final ack's own latency budget, and the
        # collective still completes with the slowest send chain.
        hop = s_to_ps(c.ici_hop_latency_s)
        progs = {d: [] for d in group}
        pairs = []
        for i, src in enumerate(group):
            dst = group[(i + 1) % n]
            if dst == src:
                continue
            pairs.append((src, dst))
            progs[src] = [DmaStep((Xfer(link, int(round(B))),), hop)
                          for link in _torus_path(topo, src, dst)]
        send_len = {d: len(progs[d]) for d in group}
        for src, dst in pairs:
            steps = progs[src]
            if not steps:
                continue
            last = steps[send_len[src] - 1]
            x = last.xfers[0]
            steps[send_len[src] - 1] = DmaStep(
                (Xfer(x.link, x.bytes, dst, send_len[dst]),),
                last.latency_ps)
            progs[dst].append(DmaStep((), 0, arrivals=1))
        return progs
    raise ValueError(f"unknown collective kind {kind!r}")


# -- the backend --------------------------------------------------------------

class EventFabric(FabricBackend):
    name = "event"

    def __init__(self, spec) -> None:
        super().__init__(spec)
        self.links: typing.List[FabricLink] = []
        self.dcn: typing.List[FabricLink] = []
        self.dmas: typing.List[DmaEngine] = []
        self.legs: Legs = make_legs(self.topology)
        self.xbar: FabricXbar = None
        self.plans: list = []               # noted (kind, bytes, group)
        self._plan_keys: set = set()

    def note_plan(self, kind: str, nbytes: float, group) -> None:
        """Record one planned collective (``System.load_trace`` calls
        this for every planned op).  Non-empty plans switch the xbar's
        bounded-lag edges from the conservative per-pod cliques to the
        exact link-level transfer graph of the planned programs; a
        collective that then runs *unplanned* trips the strict-window
        guard instead of corrupting determinism."""
        key = (kind, float(nbytes), tuple(group))
        if key not in self._plan_keys:
            self._plan_keys.add(key)
            self.plans.append(key)

    def make_controller(self) -> FabricController:
        return EventController("fabric.ctrl", self)

    def _install_extra(self, engine) -> None:
        spec = self.spec
        topo = self.topology
        legs = self.legs
        xbar = engine.register(
            FabricXbar("fabric.xbar", self.controller, legs, topology=topo))
        self.xbar = xbar
        xbar.plans = self.plans             # shared: later notes are seen
        xbar.attach(self.controller)
        for d in range(spec.total_chips):
            dma = engine.register(DmaEngine(_dma_name(d), d, legs))
            # one lookahead cluster per chip: the DMA engine and the
            # chip's own four ICI links (its dominant traffic partners)
            dma.cluster_affinity = f"fabric.chip{d}"
            self.dmas.append(dma)
            xbar.attach(dma)
            for dirn in ("+x", "-x", "+y", "-y"):
                link = FabricLink(_ici(topo, d, dirn),
                                  spec.chip.ici_link_bandwidth)
                link.cluster_affinity = f"fabric.chip{d}"
                self.links.append(engine.register(link))
                xbar.attach(link)
        for p in range(spec.num_pods):
            # pod-shared channels stay their own clusters: they are
            # contended by many chips and fusing them anywhere would
            # serialize that whole pod
            up = FabricLink(f"fabric.pod{p}.dcn", spec.dcn_bandwidth_per_pod)
            bis = FabricLink(f"fabric.pod{p}.bisect",
                             spec.bisection_bandwidth_per_pod)
            self.dcn.append(engine.register(up))
            self.links.append(engine.register(bis))
            xbar.attach(up)
            xbar.attach(bis)

    # -- fault / reporting surface ---------------------------------------
    def fault_targets(self):
        return self.links + self.dcn + self.dmas

    def link_report(self) -> dict:
        hot = sorted(self.links, key=lambda l: (-l.bytes_total, l.name))[:8]
        return {
            "hottest_links": [(l.name, float(l.bytes_total))
                              for l in hot if l.bytes_total],
            "dcn_bytes": [(l.name, float(l.bytes_total)) for l in self.dcn],
        }

    def link_utilization(self, end_ps: int = None) -> dict:
        if not end_ps:
            end_ps = max((l.busy_until_ps for l in self.links + self.dcn),
                         default=0)
        if not end_ps:
            return {}
        return {l.name: l.busy_ps / end_ps
                for l in self.links + self.dcn if l.busy_ps}
