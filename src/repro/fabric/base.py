"""Fabric backend interface + the coordinator-facing controller protocol.

The interconnect is a pluggable subsystem (mirroring the scheduler
registry in ``repro.core.engine``): a :class:`FabricBackend` owns the
pricing/transport model for collectives, and every backend exposes it to
the :class:`~repro.core.system.CollectiveCoordinator` through the same
asynchronous protocol:

* ``System`` calls :meth:`FabricBackend.install`, which registers the
  backend's components (at minimum a :class:`FabricController`) on the
  engine and wires the coordinator's ``fabric`` port to the controller
  over a zero-latency connection -- the lookahead scheduler therefore
  fuses coordinator + controller into one sequential cluster, while the
  rest of a backend's component graph chooses its own cluster layout
  (the ``event`` backend rides a latency-carrying bus so its links and
  DMA engines parallelize; see ``repro.fabric.event``).
* When a replica group has fully joined, the coordinator sends a
  ``start`` request carrying ``(key, kind, bytes, group)``.
* The controller answers with a ``fabric_done`` request for the key when
  the transfer completes -- after one analytically priced delay
  (``analytic``) or after the last per-hop transfer event drains
  (``event``).

This keeps the coordinator completely ignorant of *how* collectives are
priced; swapping fidelity is a ``fabric=`` string, exactly like swapping
an engine scheduler.
"""
from __future__ import annotations

import typing

from ..core.component import Component
from ..core.connection import Connection, Request
from ..core.event import Event


class FabricController(Component):
    """Engine-registered entry point of a fabric backend.

    Receives ``start`` requests from the coordinator and must eventually
    reply ``fabric_done`` with the same key via :meth:`finish`.
    Subclasses implement :meth:`begin`.
    """

    def __init__(self, name: str, backend: "FabricBackend") -> None:
        super().__init__(name)
        self.backend = backend
        # per-kind traffic ledger: sum of nbytes * group fan-out over
        # every started collective, integer-accumulated so the totals
        # are independent of event-processing order (lives on the
        # controller so the procs executor's end-of-run state sync
        # carries it back, same idiom as the analytic link ledger)
        self.kind_bytes: typing.Dict[str, int] = {}
        self.collectives_started = 0

    def begin(self, key, kind: str, nbytes: float,
              group: typing.List[int]) -> None:
        raise NotImplementedError

    def finish(self, key) -> None:
        """Report collective completion back to the coordinator."""
        self.port("coord").send(Request(
            src=self.port("coord"), dst=None, kind="fabric_done",
            payload=key))

    def handle(self, event: Event) -> None:
        if event.kind == "request" and event.payload.kind == "start":
            key, kind, nbytes, group = event.payload.payload
            self.kind_bytes[kind] = (self.kind_bytes.get(kind, 0)
                                     + int(nbytes) * len(group))
            self.collectives_started += 1
            self.begin(key, kind, nbytes, group)


class FabricBackend:
    """Strategy object modeling the multi-chip interconnect.

    ``topology`` (a :class:`repro.core.topology.Topology`) provides the
    shared geometry -- coordinates, group classification, and the
    analytic formulas the ``analytic`` backend prices with and the
    ``event`` backend validates against.
    """

    name = "abstract"

    def __init__(self, spec) -> None:
        from ..core.topology import Topology  # late: avoid import cycle
        self.spec = spec
        self.topology = Topology(spec)
        self.controller: FabricController = None

    # -- wiring ----------------------------------------------------------
    def install(self, engine, coordinator) -> None:
        """Register backend components and wire the coordinator.

        One backend instance serves one ``System``: links and byte
        counters are per-install state, so reuse would mix dead
        components from an earlier engine into later reports.
        """
        if self.controller is not None:
            raise RuntimeError(
                f"fabric backend {self.name!r} is already installed; "
                "backend instances are single-use -- pass the fabric "
                "*name* to reuse the model in another System")
        self.controller = engine.register(self.make_controller())
        bus = engine.register(Connection("fabric.coord_bus"))
        bus.plug(coordinator.port("fabric"))
        bus.plug(self.controller.port("coord"))
        self._install_extra(engine)

    def make_controller(self) -> FabricController:
        raise NotImplementedError

    def _install_extra(self, engine) -> None:
        """Hook for backends that register more components (links, DMAs)."""

    def note_plan(self, kind: str, nbytes: float, group) -> None:
        """Advance notice of one planned collective (``System.load_trace``
        forwards the trace's ops).  Backends that derive bounded-lag
        synchronization structure from the workload override this; the
        default -- and the analytic backend -- ignore it."""

    # -- reporting / fault surface ---------------------------------------
    def fault_targets(self) -> typing.List[Component]:
        """Components a FaultInjector plan may address (e.g. links)."""
        return []

    def link_report(self) -> dict:
        return self.topology.link_report()

    def link_utilization(self, end_ps: int = None) -> dict:
        """Per-link busy fraction; only transfer-level backends have one."""
        return {}

    def traffic_report(self) -> dict:
        """Per-collective-kind byte totals (``nbytes * fan-out`` summed
        over started collectives) plus the start count.  Read through
        the controller so it survives the procs executor's shard
        residency; identical across backends for the same workload --
        it counts what was *asked* of the fabric, not how it moved."""
        if self.controller is None:
            return {}
        out = {"collectives_started": self.controller.collectives_started}
        for kind in sorted(self.controller.kind_bytes):
            out[kind] = self.controller.kind_bytes[kind]
        return out

    def describe(self) -> dict:
        return {"name": self.name}
