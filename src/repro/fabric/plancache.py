"""Content-hashed cache of decomposed collective plans.

``repro.fabric.event.decompose`` is pure: the per-chip DMA programs it
emits are a function of the topology geometry/bandwidth parameters and
the ``(kind, bytes, group)`` triple.  A sweep replays the *same*
collectives thousands of times -- every grid point over a scenario
re-decomposes the identical plans -- so the decomposition is cached
under a content hash of exactly those inputs:

* **in-memory** per process (every repeated plan inside one run or one
  long-lived sweep worker is a hit);
* optionally **on disk** (:func:`configure`): sweep workers share one
  cache directory, and a *repeat* sweep run hits the persisted plans
  without calling ``decompose`` at all -- the hit rate is recorded in
  ``BENCH_fabric.json``'s ``sweep`` section.

Cached programs are shared objects and must be treated as read-only by
callers (the event controller already copies before filtering; the
steps themselves are frozen dataclasses).  Pickle is the disk format:
the cache directory is a private artifact of the local sweep, not an
interchange format -- delete it freely, it repopulates.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import typing

_mem: dict = {}
_disk_dir: typing.Optional[str] = None
_stats = {"hits": 0, "disk_hits": 0, "misses": 0}


def plan_key(topo, kind: str, B: float, group) -> str:
    """Content hash of everything a decomposition depends on: topology
    geometry + bandwidth/latency parameters + the collective triple.
    Two sweeps (or two processes) with equal specs share keys."""
    spec = topo.spec
    c = spec.chip
    blob = repr((tuple(spec.pod_shape), spec.num_pods,
                 c.ici_link_bandwidth, c.ici_hop_latency_s,
                 c.dcn_latency_s, spec.dcn_bandwidth_per_pod,
                 spec.bisection_bandwidth_per_pod,
                 kind, float(B), tuple(group)))
    return hashlib.sha256(blob.encode()).hexdigest()


def configure(directory: typing.Optional[str]) -> None:
    """Enable (or, with ``None``, disable) the on-disk tier.  Creates
    the directory; safe to call from every sweep worker."""
    global _disk_dir
    if directory is not None:
        os.makedirs(directory, exist_ok=True)
    _disk_dir = directory


def cached_decompose(topo, kind: str, B: float,
                     group: typing.List[int]) -> dict:
    """``decompose`` with content-hashed memoization (same plan key ->
    skip ``decompose()``).  The returned programs are shared: do not
    mutate them."""
    from .event import decompose      # late: avoid import cycle
    key = plan_key(topo, kind, B, group)
    plans = _mem.get(key)
    if plans is not None:
        _stats["hits"] += 1
        return plans
    if _disk_dir is not None:
        path = os.path.join(_disk_dir, key + ".plan")
        try:
            with open(path, "rb") as f:
                plans = pickle.load(f)
        except (OSError, pickle.PickleError, EOFError):
            plans = None
        if plans is not None:
            _mem[key] = plans
            _stats["disk_hits"] += 1
            return plans
    plans = decompose(topo, kind, float(B), list(group))
    _mem[key] = plans
    _stats["misses"] += 1
    if _disk_dir is not None:
        # atomic publish: a parallel worker reading a half-written plan
        # would poison its run, so write aside and rename into place
        fd, tmp = tempfile.mkstemp(dir=_disk_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(plans, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, os.path.join(_disk_dir, key + ".plan"))
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
    return plans


def stats() -> dict:
    """Counters since process start / last :func:`reset_stats`.  Both
    hit tiers count as hits for the headline rate."""
    hits = _stats["hits"] + _stats["disk_hits"]
    total = hits + _stats["misses"]
    return {**_stats, "lookups": total,
            "hit_rate": (hits / total) if total else 0.0}


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def clear(memory: bool = True, disk: bool = False) -> None:
    """Drop cached plans (testing / cache-dir hygiene)."""
    if memory:
        _mem.clear()
    if disk and _disk_dir is not None:
        for name in os.listdir(_disk_dir):
            if name.endswith(".plan"):
                try:
                    os.unlink(os.path.join(_disk_dir, name))
                except OSError:
                    pass
