"""Pluggable interconnect fabric (see docs/fabric.md).

Mirrors the engine's scheduler registry: ``make_fabric("analytic" |
"event", spec)`` resolves a :class:`FabricBackend`, and a third backend
is one :func:`register_fabric` call away.  ``System`` / ``simulate``
plumb a ``fabric=`` knob through (default ``SystemSpec.fabric``).

* ``analytic`` -- closed-form ring/hierarchical/bisection pricing
  (O(1) events per collective; no contention between collectives).
  Same-timestep pricings are batched through the vectorized kernels in
  :mod:`repro.fabric.pricing` (bit-equal to the scalar formulas).
* ``event``    -- per-hop transfer events on link / DMA-engine
  components; concurrent collectives queue on shared links.
  Decompositions are memoized by content hash
  (:mod:`repro.fabric.plancache`; same plan key -> skip decompose).
"""
from . import plancache, pricing
from .base import FabricBackend, FabricController
from .analytic import AnalyticFabric
from .event import (EventFabric, FabricLink, DmaEngine, DmaStep, Legs,
                    Xfer, decompose, make_legs)
from .plancache import cached_decompose

FABRICS: dict = {}


def register_fabric(name: str, factory) -> None:
    """Make ``make_fabric(name, spec)`` resolve to ``factory(spec)``."""
    FABRICS[name] = factory


def make_fabric(spec_or_name, system_spec) -> FabricBackend:
    """Resolve a fabric name (or pass through a backend instance)."""
    if isinstance(spec_or_name, FabricBackend):
        return spec_or_name
    try:
        factory = FABRICS[spec_or_name]
    except KeyError:
        raise ValueError(f"unknown fabric {spec_or_name!r}; "
                         f"available: {sorted(FABRICS)}") from None
    return factory(system_spec)


register_fabric("analytic", AnalyticFabric)
register_fabric("event", EventFabric)

__all__ = [
    "FabricBackend", "FabricController", "AnalyticFabric", "EventFabric",
    "FabricLink", "DmaEngine", "DmaStep", "Legs", "Xfer", "decompose",
    "cached_decompose", "make_legs", "FABRICS", "register_fabric",
    "make_fabric", "plancache", "pricing",
]
