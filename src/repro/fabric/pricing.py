"""Vectorized analytic collective pricing: thousands of points per call.

The closed-form ring / hierarchical / bisection / cross-pod formulas in
:class:`repro.core.topology.Topology` price one collective per Python
call -- fine on the live event timeline, hopeless for a design-space
sweep that wants to price (config x traffic) grids with thousands of
points.  This module mirrors those formulas as numpy ``float64`` array
kernels: every expression tree is **identical** to the scalar path
(same operands, same association order), so the vectorized results are
*bit-equal* to ``Topology.price`` -- not merely close.  That exactness
is load-bearing: the scalar formulas are the parity oracle the event
fabric is validated against, and ``tests/test_pricing.py`` asserts
``==`` (no tolerance) across the full kind x class x payload grid.

Two consumers:

* the ``analytic`` fabric backend batches homogeneous same-timestep
  pricings through :func:`price_collectives` instead of evaluating one
  formula per Python event handler (``repro.fabric.analytic``);
* the sweep driver (``tools/sweep.py``) and the throughput benchmark
  (``benchmarks/sweep_throughput.py``) price whole scenario grids with
  :func:`price` over broadcast :class:`FabricParams` arrays.

All kernels are plain broadcasting ops (no indexing tricks), so they
also run unchanged under ``jax.numpy`` for an accelerator-resident
sweep -- but the supported, parity-tested dtype is numpy ``float64``
(jax defaults to ``float32``, which would break bit-equality).
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

# Collective kinds and group classes, in a fixed code order shared by
# every consumer.  ``classify_group``'s "self" (singleton) class is not
# listed: singleton groups price to 0.0 before classification matters.
KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
CLASSES = ("ring_x", "ring_y", "block_2d", "cross_pod")

KIND_CODES = {k: i for i, k in enumerate(KINDS)}
CLASS_CODES = {c: i for i, c in enumerate(CLASSES)}
CLASS_CODES["self"] = 0          # priced 0.0 via the n<=1 mask anyway

_AR, _AG, _RS, _A2A, _CP = range(5)
_RING_X, _RING_Y, _BLOCK_2D, _CROSS_POD = range(4)


def encode_kinds(kinds) -> np.ndarray:
    """Kind names (str or sequence) -> int codes; raises on unknowns."""
    if isinstance(kinds, str):
        return np.asarray(KIND_CODES[kinds])
    try:
        return np.asarray([KIND_CODES[k] for k in kinds])
    except KeyError as e:
        raise ValueError(f"unknown collective kind {e.args[0]!r}; "
                         f"known: {KINDS}") from None


def encode_classes(classes) -> np.ndarray:
    """Group-class names (str or sequence) -> int codes."""
    if isinstance(classes, str):
        return np.asarray(CLASS_CODES[classes])
    try:
        return np.asarray([CLASS_CODES[c] for c in classes])
    except KeyError as e:
        raise ValueError(f"unknown group class {e.args[0]!r}; "
                         f"known: {CLASSES}") from None


@dataclasses.dataclass(frozen=True)
class FabricParams:
    """Broadcastable spec parameters for one -- or many -- machines.

    Each field is a scalar or a numpy array; all fields must broadcast
    against each other and against the traffic arrays handed to
    :func:`price`.  ``from_spec`` gives plain scalars (one machine);
    ``stack`` gives shape-``(k,)`` arrays over ``k`` machine configs --
    reshape (e.g. ``params.reshape((k, 1))``) to sweep configs on one
    axis and traffic points on another.
    """

    ici_bw: typing.Any          # bytes/s per ICI link per direction
    hop_s: typing.Any           # ICI per-hop latency, seconds
    dcn_bw: typing.Any          # aggregate DCN bytes/s per pod
    dcn_s: typing.Any           # cross-pod one-way latency, seconds
    bisect_bw: typing.Any       # pod bisection bytes/s
    X: typing.Any               # pod torus x dimension (int)
    Y: typing.Any               # pod torus y dimension (int)
    pods: typing.Any            # number of pods (int)

    @classmethod
    def from_spec(cls, spec) -> "FabricParams":
        c = spec.chip
        return cls(ici_bw=c.ici_link_bandwidth, hop_s=c.ici_hop_latency_s,
                   dcn_bw=spec.dcn_bandwidth_per_pod, dcn_s=c.dcn_latency_s,
                   bisect_bw=spec.bisection_bandwidth_per_pod,
                   X=spec.pod_shape[1], Y=spec.pod_shape[0],
                   pods=spec.num_pods)

    @classmethod
    def stack(cls, specs) -> "FabricParams":
        rows = [cls.from_spec(s) for s in specs]
        return cls(*(np.asarray([getattr(r, f.name) for r in rows])
                     for f in dataclasses.fields(cls)))

    def reshape(self, shape) -> "FabricParams":
        return FabricParams(*(np.reshape(getattr(self, f.name), shape)
                              for f in dataclasses.fields(FabricParams)))


# -- formula kernels (each mirrors its Topology._* scalar twin EXACTLY) ------

def ring_time(B, n, phases, p: FabricParams):
    """Mirror of ``Topology._ring_time`` (bidirectional ring)."""
    bw = 2 * p.ici_bw
    steps = phases * (n - 1)
    return phases * (n - 1) / n * B / bw + steps * p.hop_s


def block2d_time(B, n, phases, p: FabricParams):
    """Mirror of ``Topology._block2d_time`` (x rings then y rings)."""
    nx = np.minimum(p.X, n)
    ny = np.maximum(1, n // nx)
    t = ring_time(B, nx, phases, p)
    return np.where(ny > 1, t + ring_time(B / nx, ny, phases, p), t)


def alltoall_ring_time(B, n, p: FabricParams):
    """Mirror of ``Topology._alltoall_ring_time``."""
    return (B * (n - 1) / 8) / p.ici_bw + (n / 2) * p.hop_s


def alltoall_block_time(B, n, p: FabricParams):
    """Mirror of ``Topology._alltoall_block_time`` (bisection-limited)."""
    cross = n * B / 2
    return cross / p.bisect_bw + (p.X / 2 + p.Y / 2) * p.hop_s


def cross_pod_time(kind, B, n, n_groups, p: FabricParams):
    """Mirror of ``Topology._cross_pod_time``.

    ``n`` is the member count of one group, ``n_groups`` the number of
    concurrent groups sharing the pods' DCN bandwidth (the live fabric
    path prices one group per coordinator call, i.e. ``n_groups=1``).
    """
    pods = p.pods
    per_pod = np.maximum(1, n // pods)
    eff = np.where(kind == _AR, 2 * (pods - 1) / pods, (pods - 1) / pods)
    multi = per_pod > 1
    t = np.where(multi, block2d_time(B, per_pod, 1.0, p), 0.0)
    Bx = np.where(multi, B / per_pod, B)
    # scalar path: t += dcn_bytes / dcn_bw + dcn_latency  (one RHS, so
    # the association is t + ((bytes/bw) + lat) -- mirror it exactly)
    t = t + (n_groups * Bx * eff / p.dcn_bw + p.dcn_s)
    closing = multi & ((kind == _AR) | (kind == _AG))
    return np.where(closing,
                    t + block2d_time(Bx * per_pod, per_pod, 1.0, p), t)


def price(kind, cls, B, n, params: FabricParams, n_groups=1) -> np.ndarray:
    """Price a whole (config x traffic) grid in a handful of array ops.

    ``kind`` / ``cls`` -- kind and group-class names (one str each) or
    int code arrays (:func:`encode_kinds` / :func:`encode_classes`);
    ``B`` -- float payload bytes per participant (the same B convention
    as ``Topology.collective_time_s``); ``n`` -- int group member
    counts; ``params`` -- broadcastable :class:`FabricParams`.  All
    five broadcast together; the result is the broadcast-shaped
    ``float64`` array of seconds, element-wise bit-equal to
    ``Topology.price`` on the matching scalar inputs.
    """
    kind = encode_kinds(kind) if isinstance(kind, str) else np.asarray(kind)
    cls = encode_classes(cls) if isinstance(cls, str) else np.asarray(cls)
    B = np.asarray(B, dtype=np.float64)
    n = np.asarray(n)
    ng = np.asarray(n_groups)
    pf = [np.asarray(getattr(params, f.name))
          for f in dataclasses.fields(FabricParams)]
    shape = np.broadcast_shapes(kind.shape, cls.shape, B.shape, n.shape,
                                ng.shape, *(a.shape for a in pf))

    # Every (kind, class) combination evaluates its formula only on its
    # own lanes (boolean mask -> gather, formula, scatter).  This is a
    # pure optimization over full-width branch evaluation + np.select:
    # each lane still runs the exact scalar expression tree, so
    # bit-equality with ``Topology.price`` is untouched, but a mixed
    # grid does ~1/5 of the element work.
    def flat(a):
        return a if a.ndim == 0 else np.broadcast_to(a, shape).reshape(-1)

    kindf, clsf, Bf, nf, ngf = (flat(a) for a in (kind, cls, B, n, ng))
    pflat = [flat(a) for a in pf]

    def at(a, idx):
        return a if a.ndim == 0 else a[idx]

    out = np.zeros(int(np.prod(shape, dtype=np.int64)))

    def fill(mask, fn):
        idx = np.flatnonzero(mask)
        if idx.size:
            p = FabricParams(*(at(a, idx) for a in pflat))
            out[idx] = fn(at(Bf, idx), at(nf, idx), idx, p)

    live = nf > 1                       # n<=1 lanes stay 0.0 (never priced)
    cross = clsf == _CROSS_POD
    ringm = live & ~cross & (clsf <= _RING_Y)
    blockm = live & ~cross & (clsf == _BLOCK_2D)
    agrs = (kindf == _AG) | (kindf == _RS)
    fill(ringm & (kindf == _AR), lambda b, m, i, p: ring_time(b, m, 2.0, p))
    fill(blockm & (kindf == _AR),
         lambda b, m, i, p: block2d_time(b, m, 2.0, p))
    fill(ringm & agrs, lambda b, m, i, p: ring_time(b, m, 1.0, p))
    fill(blockm & agrs, lambda b, m, i, p: block2d_time(b, m, 1.0, p))
    fill(ringm & (kindf == _A2A),
         lambda b, m, i, p: alltoall_ring_time(b, m, p))
    fill(blockm & (kindf == _A2A),
         lambda b, m, i, p: alltoall_block_time(b, m, p))
    fill(live & ~cross & (kindf == _CP),
         lambda b, m, i, p: b / p.ici_bw + p.hop_s)
    fill(live & cross,
         lambda b, m, i, p: cross_pod_time(at(kindf, i), b, m,
                                           at(ngf, i), p))
    return out.reshape(shape)


def classify_cached(topology, memo: dict, group: tuple) -> int:
    """Class code of one replica group, memoized by group tuple -- the
    per-group classification is pure Python coordinate walking and
    dominates batched pricing without this."""
    code = memo.get(group)
    if code is None:
        code = memo[group] = CLASS_CODES[topology.classify_group(list(group))]
    return code


def price_collectives(topology, items, memo: dict = None) -> np.ndarray:
    """Vector-price a batch of per-group collectives on one machine.

    ``items``: sequence of ``(kind, nbytes, group)`` with ``group`` a
    tuple of member ids -- exactly the payload of one coordinator
    ``start`` request each, so ``n_groups=1`` like the scalar live path
    (``Topology.price(kind, nbytes, [group])``).  Returns seconds, one
    per item, bit-equal to that scalar call.
    """
    if memo is None:
        memo = {}
    k = len(items)
    kinds = np.fromiter((KIND_CODES[kind] for kind, _, _ in items),
                        dtype=np.int64, count=k)
    B = np.fromiter((nbytes for _, nbytes, _ in items),
                    dtype=np.float64, count=k)
    n = np.fromiter((len(group) for _, _, group in items),
                    dtype=np.int64, count=k)
    cls = np.fromiter((classify_cached(topology, memo, tuple(group))
                       for _, _, group in items), dtype=np.int64, count=k)
    return price(kinds, cls, B, n, FabricParams.from_spec(topology.spec))
