"""Model configuration + registry.

One :class:`ModelConfig` describes every assigned architecture; family
dispatch ("dense" | "moe" | "ssm" | "hybrid" | "encdec" | "vlm") selects
the forward implementation.  Configs are plain frozen dataclasses so they
hash/compare cleanly for jit static args.
"""
from __future__ import annotations

import dataclasses
import math
import typing

import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int = 0             # 0 for attention-free
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0            # >1: per-group dispatch (GShard-style
    #                                local capacity; no global cumsum)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2): shared attention block every k SSM layers ---
    attn_every: int = 0
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # stub frontend: frames arrive pre-embedded
    # --- VLM (llava) ---
    num_patches: int = 0           # stub frontend: patches arrive pre-embedded
    # --- numerics / lowering ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # activation/param dtype
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False       # CPU dry-run lowers the pure-JAX path
    # --- optimisation knobs (perf hillclimbing; defaults = paper-faithful) ---
    attn_impl: str = "ref"         # "ref" | "blocked" | "flash" (Pallas)
    seq_shard_activations: bool = False  # SP: residual stream seq-sharded
    fsdp: bool = False             # shard params/opt over the data axis too
    microbatches: int = 1          # grad accumulation (activation peak / k)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the embedding/logits
        shard evenly on any mesh up to model=128 (standard TP practice;
        padded logit columns are masked to -inf in unembed — exact)."""
        if not self.vocab_size:
            return 0
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ssm_groups(self) -> int:
        return 1

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def in_proj_dim(self) -> int:
        # z, x, B, C, dt
        return (2 * self.d_inner + 2 * self.ssm_groups * self.ssm_state
                + self.ssm_heads)

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float16": jnp.float16}[self.dtype]

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state is O(1); hybrid shards its few
        attention caches. Pure full-attention archs skip long_500k."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (cross-checked against real init in
        tests/test_models.py::test_param_count_matches)."""
        d, V = self.d_model, self.padded_vocab
        n = 0
        if self.family == "encdec":
            n += V * d + d * V                      # embed + lm head
            n += self.encoder_layers * self._attn_params(cross=False)
            n += self.encoder_layers * self._mlp_params()
            n += self.encoder_layers * 2 * d        # norms
            n += self.num_layers * (self._attn_params() * 2 +  # self+cross
                                    self._mlp_params() + 3 * d)
            n += 2 * d                              # final norms enc+dec
            return n
        if V:
            n += V * d                              # embed
            if not self.tie_embeddings:
                n += d * V                          # lm_head
        n += d                                      # final norm
        L = self.num_layers
        if self.family in ("dense", "vlm"):
            n += L * (self._attn_params() + self._mlp_params() + 2 * d)
        elif self.family == "moe":
            n += L * (self._attn_params() + self._moe_params() + 2 * d)
        elif self.family == "ssm":
            n += L * (self._ssm_params() + d)
        elif self.family == "hybrid":
            n += L * (self._ssm_params() + d)
            n += self._attn_params() + self._mlp_params() + 2 * d  # shared blk
        return n

    def _attn_params(self, cross: bool = False) -> int:
        d = self.d_model
        n = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            n += self.q_dim + 2 * self.kv_dim
        return n

    def _mlp_params(self) -> int:
        if self.family == "encdec":                 # gelu 2-matrix MLP
            return 2 * self.d_model * self.d_ff
        return 3 * self.d_model * self.d_ff         # SwiGLU

    def _moe_params(self) -> int:
        return (self.d_model * self.num_experts
                + self.num_experts * 3 * self.d_model * self.d_ff)

    def _ssm_params(self) -> int:
        d = self.d_model
        n = d * self.in_proj_dim                    # in_proj
        n += self.conv_dim * (self.ssm_conv_width + 1)  # conv w + bias
        n += 3 * self.ssm_heads                     # A_log, D, dt_bias
        n += self.d_inner                           # gated norm
        n += self.d_inner * d                       # out_proj
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * \
            3 * self.d_model * self.d_ff * self.num_layers
        return self.param_count() - inactive


# --------------------------------------------------------------------------
_REGISTRY: typing.Dict[str, typing.Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from repro import configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> typing.List[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)
