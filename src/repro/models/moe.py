"""Mixture-of-Experts FFN (GShard-style capacity dispatch, scatter-based).

The classic (tokens, experts, capacity) one-hot dispatch tensor is
O(T*E*C) — 2e13 elements for dbrx at train_4k — so we build (E, C)
*index* buffers by scatter instead: O(T*k) routing metadata, O(E*C*d)
activations.  Dropped tokens (beyond capacity) fall into a dump slot and
contribute zero, exactly like GShard with capacity_factor.

Two consumers:
* U-mode (jit/GSPMD): `moe_ffn` runs on the full local token block;
  sharding constraints on the (E, C, d) buffers put experts on the
  "model" mesh axis and GSPMD materializes the all-to-alls.
* D-mode (shard_map): `dispatch`/`combine` are called around explicit
  `jax.lax.all_to_all` over the expert axis — the paper's
  Scatter/Irregular pattern made explicit (see sharding/dmode.py).

This is the paper's "Irregular" collaborative pattern in LM form: every
shard reads/writes token slots across the whole expert space.
"""
from __future__ import annotations

import math
import typing

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, split_rngs


def init_moe(rng, cfg) -> Params:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.jnp_dtype
    rs = split_rngs(rng, 4)
    return {
        "router": dense_init(rs[0], (d, E), jnp.float32),
        "wg": dense_init(rs[1], (E, d, f), dt),
        "wu": dense_init(rs[2], (E, d, f), dt),
        "wd": dense_init(rs[3], (E, f, d), dt),
    }


def capacity(tokens: int, cfg) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    return max(8, int(math.ceil(c / 8) * 8))  # pad to an MXU-friendly size


def route(p: Params, x, cfg):
    """x (T,d) -> (expert_idx (T,k) int32, gate_w (T,k) f32, aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"])          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch): E * mean(frac_tokens * frac_probs)
    E = cfg.num_experts
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(onehot_top1, axis=0) * jnp.mean(probs, axis=0))
    return expert_idx.astype(jnp.int32), gate_w, aux


def build_dispatch(expert_idx, T: int, E: int, C: int):
    """expert_idx (T,k) -> (dispatch_idx (E,C) int32 in [0..T] where T is
    the zero-pad slot, pos (T*k,) int32 clipped to C, keep (T*k,) bool).

    Token-major flattening keeps each token's k assignments contiguous so
    combine is a reshape+sum, not a scatter-add.
    """
    k = expert_idx.shape[1]
    flat_e = expert_idx.reshape(-1)                         # (T*k,)
    onehot = (flat_e[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)             # exclusive count
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                         # dump slot
    token_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.full((E, C + 1), T, jnp.int32)
    buf = buf.at[flat_e, pos_c].set(token_ids, mode="drop")
    return buf[:, :C], pos_c, keep


def expert_ffn(p: Params, xe):
    """xe (E,C,d) -> (E,C,d): per-expert SwiGLU via batched matmul."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["wd"])


def moe_ffn(p: Params, x, cfg, ep_constraint=None):
    """Full MoE FFN on a local token block. x (T,d) -> (y (T,d), aux).

    With cfg.moe_groups > 1 dispatch runs per token-group (GShard's
    per-device capacity): the position-in-expert cumsum becomes
    group-local, so under SPMD no cross-shard prefix sums ever happen —
    the fix that removes the per-layer all-reduce avalanche the global
    formulation costs at 1M-token scale (EXPERIMENTS.md §Perf).
    """
    if cfg.moe_groups > 1 and x.shape[0] % cfg.moe_groups == 0:
        return grouped_moe_ffn(p, x, cfg, ep_constraint)
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(T, cfg)
    expert_idx, gate_w, aux = route(p, x, cfg)
    dispatch_idx, pos_c, keep = build_dispatch(expert_idx, T, E, C)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = jnp.take(x_pad, dispatch_idx, axis=0)              # (E,C,d)
    if ep_constraint is not None:
        xe = ep_constraint(xe)                              # experts -> "model"
    ye = expert_ffn(p, xe)
    if ep_constraint is not None:
        ye = ep_constraint(ye)
    # gather each assignment's output back: rows are token-major
    ye_pad = jnp.concatenate(
        [ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)       # dump slot reads 0
    flat_e = expert_idx.reshape(-1)
    out_rows = ye_pad[flat_e, pos_c]                        # (T*k, d)
    w = (gate_w.reshape(-1) * keep).astype(out_rows.dtype)
    y = (out_rows * w[:, None]).reshape(T, k, d).sum(axis=1)
    return y.astype(x.dtype), aux


def grouped_moe_ffn(p: Params, x, cfg, ep_constraint=None):
    """Per-group dispatch: x (T,d) viewed as (G_r, T/G_r, d); routing,
    position cumsum and capacity are group-local (vmapped), experts see
    the concatenated slots (E, G_r*C_g, d).  Semantically GShard with
    group = device; drops can differ from the global formulation only
    when a group is locally over-subscribed (same trade GShard makes)."""
    T, d = x.shape
    Gr = cfg.moe_groups
    E, k = cfg.num_experts, cfg.experts_per_token
    Tg = T // Gr
    Cg = capacity(Tg, cfg)
    xg = x.reshape(Gr, Tg, d)

    def route_group(xs):
        expert_idx, gate_w, aux = route(p, xs, cfg)
        dispatch_idx, pos_c, keep = build_dispatch(expert_idx, Tg, E, Cg)
        x_pad = jnp.concatenate([xs, jnp.zeros((1, d), xs.dtype)], axis=0)
        xe = jnp.take(x_pad, dispatch_idx, axis=0)       # (E,Cg,d)
        return xe, (expert_idx, gate_w, pos_c, keep), aux

    xe, meta, aux = jax.vmap(route_group)(xg)            # (Gr,E,Cg,d)
    xe = jnp.swapaxes(xe, 0, 1).reshape(E, Gr * Cg, d)
    if ep_constraint is not None:
        xe = ep_constraint(xe)
    ye = expert_ffn(p, xe)
    if ep_constraint is not None:
        ye = ep_constraint(ye)
    ye = jnp.swapaxes(ye.reshape(E, Gr, Cg, d), 0, 1)    # (Gr,E,Cg,d)

    def combine_group(ye_g, meta_g):
        return combine_local(ye_g, meta_g, cfg)
    y = jax.vmap(combine_group)(ye, meta)                # (Gr,Tg,d)
    return y.reshape(T, d).astype(x.dtype), jnp.mean(aux)


# --------------------------------------------------------------------------
# D-mode building blocks (used inside shard_map; see sharding/dmode.py)
# --------------------------------------------------------------------------

def dispatch_local(p: Params, x, cfg, C: int):
    """Route a local token shard and build its (E, C, d) send buffer."""
    T, d = x.shape
    E = cfg.num_experts
    expert_idx, gate_w, aux = route(p, x, cfg)
    dispatch_idx, pos_c, keep = build_dispatch(expert_idx, T, E, C)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = jnp.take(x_pad, dispatch_idx, axis=0)              # (E,C,d)
    meta = (expert_idx, gate_w, pos_c, keep)
    return xe, meta, aux


def combine_local(ye, meta, cfg):
    """Invert dispatch_local: ye (E,C,d) expert outputs -> (T,d)."""
    expert_idx, gate_w, pos_c, keep = meta
    E, C, d = ye.shape
    k = cfg.experts_per_token
    T = expert_idx.shape[0]
    ye_pad = jnp.concatenate([ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)
    flat_e = expert_idx.reshape(-1)
    out_rows = ye_pad[flat_e, pos_c]
    w = (gate_w.reshape(-1) * keep).astype(out_rows.dtype)
    return (out_rows * w[:, None]).reshape(T, k, d).sum(axis=1)
