"""Decoder-only transformer LM (dense + MoE FFN variants).

Covers qwen2-1.5b / qwen1.5-4b / qwen1.5-110b / internlm2-20b (dense),
dbrx-132b / qwen3-moe-30b-a3b (moe), and the llava backbone (dense with
prepended patch embeddings).

Layer params are stacked on a leading axis and the forward `lax.scan`s
over them (small HLO, O(1) compile in depth); ``cfg.remat`` wraps the
scanned body in `jax.checkpoint` so only layer-boundary residuals are
kept live — the policy that makes the 110b train_4k cell fit.
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from .base import ModelConfig

Params = typing.Dict[str, typing.Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init(rng, cfg: ModelConfig) -> Params:
    r_embed, r_layers, r_ffn = jax.random.split(rng, 3)
    dt = cfg.jnp_dtype
    p: Params = L.init_embed(r_embed, cfg)
    n = cfg.num_layers
    p["layers"] = {
        "attn": L._stack_init(L.init_attention, r_layers, n, cfg),
        "ln1": jnp.ones((n, cfg.d_model), dt),
        "ln2": jnp.ones((n, cfg.d_model), dt),
    }
    if cfg.family == "moe":
        p["layers"]["moe"] = L._stack_init(M.init_moe, r_ffn, n, cfg)
    else:
        p["layers"]["mlp"] = L._stack_init(L.init_swiglu, r_ffn, n, cfg)
    p["ln_f"] = jnp.ones((cfg.d_model,), dt)
    return p


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _layer_fwd(lp: Params, h, cfg: ModelConfig, positions, ctx=None):
    """One pre-norm block. Returns (h, aux).  ``ctx`` carries optional
    sharding-constraint callables: {"sp": residual boundary, "ep": MoE
    expert buffers} — injected by sharding/umode.py."""
    ctx = ctx or {}
    a, _ = L.attention_block(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                             cfg, positions=positions, causal=True)
    h = h + a
    hn = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        B, S, d = hn.shape
        if ctx.get("moe_sm") is not None:   # embedded D-mode EP (a2a)
            y, aux = ctx["moe_sm"](lp["moe"], hn.reshape(B * S, d))
        else:
            y, aux = M.moe_ffn(lp["moe"], hn.reshape(B * S, d), cfg,
                               ep_constraint=ctx.get("ep"))
        y = y.reshape(B, S, d)
    else:
        y, aux = L.swiglu(lp["mlp"], hn), 0.0
    h = h + y
    if ctx.get("sp") is not None:
        h = ctx["sp"](h)         # SP: keep residual seq-sharded at boundary
    return h, aux


def forward(p: Params, cfg: ModelConfig, tokens, extra_embeds=None,
            ctx=None):
    """tokens (B,S) int32 [, extra_embeds (B,P,d) prepended] -> logits f32."""
    h = L.embed(p, tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)
    if ctx and ctx.get("sp") is not None:
        h = ctx["sp"](h)

    def body(h, lp):
        return _layer_fwd(lp, h, cfg, positions, ctx)

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, aux = jax.lax.scan(body, h, p["layers"])
        aux = jnp.sum(aux)
    else:
        aux = 0.0
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], p["layers"])
            h, a = body(h, lp)
            aux = aux + a
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    logits = L.unembed(p, h, cfg)
    return logits, aux


def _hidden(p: Params, cfg: ModelConfig, tokens, extra_embeds=None,
            ctx=None):
    """forward() up to (but excluding) the unembedding."""
    h = L.embed(p, tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])
    ctx = ctx or {}
    if ctx.get("sp") is not None:
        h = ctx["sp"](h)

    def body(h, lp):
        return _layer_fwd(lp, h, cfg, positions, ctx)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, aux = jax.lax.scan(body, h, p["layers"])
    return L.rms_norm(h, p["ln_f"], cfg.norm_eps), jnp.sum(aux)


def _chunked_xent(p: Params, cfg: ModelConfig, h, targets, mask=None,
                  chunk: int = 512):
    """Cross-entropy without ever materializing (B,S,V) logits: unembed +
    logsumexp per sequence chunk with a checkpointed body — at 152k vocab
    and 1M tokens the f32 logits (+cotangent) are ~5 GB/device, the
    single largest loss-side buffer in the 110b cell (§Perf iteration)."""
    B, S, d = h.shape
    pad = (-S) % chunk
    if mask is None:
        mask = jnp.ones((B, S), h.dtype)
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    hs = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def one(args):
        hc, tc, mc = args
        logits = L.unembed(p, hc, cfg)                  # (B,chunk,V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return jnp.sum(nll), jnp.sum(mc)

    nlls, counts = jax.lax.map(one, (hs, ts, ms))
    return jnp.sum(nlls) / jnp.maximum(jnp.sum(counts), 1)


def loss_fn(p: Params, cfg: ModelConfig, batch, aux_weight: float = 0.01,
            ctx=None):
    tgt = batch["targets"]
    h, aux = _hidden(p, cfg, batch["tokens"],
                     extra_embeds=batch.get("patches"), ctx=ctx)
    if h.shape[1] != tgt.shape[1]:                # VLM: loss on text positions
        h = h[:, -tgt.shape[1]:]
    if cfg.padded_vocab * h.shape[1] >= (1 << 26):     # big V*S: chunked CE
        nll = _chunked_xent(p, cfg, h, tgt, batch.get("mask"))
    else:
        logits = L.unembed(p, h, cfg)
        nll = L.cross_entropy(logits, tgt, batch.get("mask"))
    return nll + aux_weight * aux


# --------------------------------------------------------------------------
# serving: prefill + single-token decode with static KV cache
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(p: Params, cfg: ModelConfig, tokens, cache: dict,
            extra_embeds=None):
    """Run the prompt, fill the cache, return logits of the last position."""
    h = L.embed(p, tokens)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)

    def body(h, lp):
        a, kv = L.attention_block(
            lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=True)
        h = h + a
        hn = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            B, T, d = hn.shape
            y, _ = M.moe_ffn(lp["moe"], hn.reshape(B * T, d), cfg)
            y = y.reshape(B, T, d)
        else:
            y = L.swiglu(lp["mlp"], hn)
        return h + y, kv

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (ks, vs) = jax.lax.scan(body, h, p["layers"])
    T = cache["k"].shape[2]
    k_new = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    v_new = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    h = L.rms_norm(h[:, -1:], p["ln_f"], cfg.norm_eps)
    logits = L.unembed(p, h, cfg)[:, 0]
    return logits, {"k": k_new, "v": v_new,
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_step(p: Params, cfg: ModelConfig, cache: dict, token):
    """token (B,) int32 -> (logits (B,V) f32, new cache). One new token
    attending to a KV cache of static length — the decode_* dry-run op."""
    B = token.shape[0]
    h = L.embed(p, token[:, None])                     # (B,1,d)
    pos = cache["pos"]                                 # scalar or (B,) slots
    positions = pos[:, None] if pos.ndim else \
        pos[None, None] + jnp.zeros((1, 1), jnp.int32)

    def body(h, xs):
        lp, kc, vc = xs
        a, (kc2, vc2) = L.attention_block(
            lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=False, kv_cache=(kc, vc),
            cache_pos=pos)
        h = h + a
        hn = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            d = hn.shape[-1]
            y, _ = M.moe_ffn(lp["moe"], hn.reshape(B, d), cfg)
            y = y.reshape(B, 1, d)
        else:
            y = L.swiglu(lp["mlp"], hn)
        return h + y, (kc2, vc2)

    h, (k_new, v_new) = jax.lax.scan(body, h, (p["layers"], cache["k"],
                                               cache["v"]))
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    logits = L.unembed(p, h, cfg)[:, 0]
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
