"""Shared neural building blocks (pure JAX, no framework deps).

Everything here is functional: params are plain dicts of jnp arrays, all
modules are `init_*(rng, cfg) -> params` + `apply(params, x, ...) -> y`.
Per-layer params are created **stacked** on a leading layer axis so the
model forward can `lax.scan` over layers (small HLO, fast compile, remat-
friendly — the MaxText idiom).

The attention core has two implementations selected by
``cfg.attn_impl``: "ref" (einsum softmax — what the dry-run lowers; also
the oracle) and "flash" (Pallas TPU kernel from ``repro.kernels``,
validated in interpret mode on CPU).
"""
from __future__ import annotations

import math
import typing

import jax
import jax.numpy as jnp

Params = typing.Dict[str, typing.Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(rng, shape, dtype, scale: float = None):
    """Truncated-normal fan-in init (stacked shapes init per-slice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


def split_rngs(rng, n):
    return list(jax.random.split(rng, n))


# --------------------------------------------------------------------------
# normalisation
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding (rotate-half convention)
# --------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,S) int -> (...,S, head_dim//2) angles."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                           dtype=jnp.float32) / head_dim))
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x, angles):
    """x: (B,S,H,hd); angles: (S,hd/2) or (B,S,hd/2)."""
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d_model: int, dtype):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle)).at[:, 1::2].set(jnp.cos(angle))
    return pe.astype(dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(rng, cfg, cross: bool = False) -> Params:
    """Weights for one (stacked: leading dim = n_layers) attention block."""
    d, dt = cfg.d_model, cfg.jnp_dtype
    rs = split_rngs(rng, 4)
    p = {
        "wq": dense_init(rs[0], (d, cfg.q_dim), dt),
        "wk": dense_init(rs[1], (d, cfg.kv_dim), dt),
        "wv": dense_init(rs[2], (d, cfg.kv_dim), dt),
        "wo": dense_init(rs[3], (cfg.q_dim, d), dt,
                         scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def _stack_init(fn, rng, n_layers, *args, **kw):
    """Init `n_layers` instances and stack each leaf on axis 0."""
    outs = [fn(r, *args, **kw) for r in split_rngs(rng, n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


def qkv(p: Params, x, cfg, positions=None):
    """Project + (optionally) rope. x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.hd)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.hd)
    if positions is not None:
        ang = rope_angles(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
    return q, k, v


def blocked_attention(q, k, v, causal: bool = True, q_chunk: int = 256):
    """Memory-bounded causal GQA attention: q is processed in chunks so
    only a (cq, T) score tile is ever live — the pure-JAX mirror of the
    Pallas flash kernel (kernels/flash_attention.py) that the CPU dry-run
    can lower.  Exact softmax per chunk (full kv row), f32 accumulation.

    The chunk body is itself jax.checkpoint'ed: under the per-layer remat
    the backward pass would otherwise stack every chunk's (cq, T) softmax
    probabilities and causal mask (the dominant temp buffer at S >= 4k) —
    rematerializing them per chunk trades ~30% extra attention FLOPs in
    the backward for an O(S^2) -> O(cq*T) live-memory drop.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    cq = min(q_chunk, S)
    pad = (-S) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // cq
    scale = 1.0 / math.sqrt(hd)
    qs = jnp.moveaxis(q.reshape(B, nq, cq, K, G, hd), 1, 0)

    @jax.checkpoint
    def chunk(args):
        i, qi = args                                  # qi (B,cq,K,G,hd)
        s = jnp.einsum("bskgh,btkh->bkgst", qi, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * cq + jnp.arange(cq)[:, None] + (T - S)
            s = jnp.where(qpos >= jnp.arange(T)[None, :], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
        return o

    out = jax.lax.map(chunk, (jnp.arange(nq), qs))    # (nq,B,cq,K,G,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S + pad, H, hd)
    return out[:, :S]


def attention_core(q, k, v, mask=None, causal: bool = False,
                   impl: str = "ref"):
    """GQA attention. q: (B,S,H,hd); k/v: (B,T,K,hd); H % K == 0.

    mask: broadcastable to (B,1,1,S,T) boolean (True = attend) or None.
    impl: "ref" (materialized scores), "blocked" (q-chunked, memory-safe),
    "flash" (Pallas TPU kernel).
    """
    if impl == "flash" and mask is None and causal:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True)
    if impl == "blocked" and mask is None and causal and q.shape[1] > 1:
        return blocked_attention(q, k, v, causal=True)
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        cm = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(cm, scores, -jnp.inf)
    if mask is not None:
        scores = jnp.where(jnp.moveaxis(mask, -2, -2), scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def attention_block(p: Params, x, cfg, positions=None, causal=True,
                    kv_cache=None, cache_pos=None, kv_override=None):
    """Full attention block: qkv -> core -> output proj.

    Train / prefill: kv_cache None -> self attention over x.
    Decode: kv_cache = (k_cache, v_cache) of static length T; the new
    token's k/v are written at ``cache_pos`` and attention masks t <= pos.
    Cross-attention: kv_override = (k, v) precomputed from encoder.
    Returns (out, new_kv) where new_kv is the updated (k, v) or None.
    """
    B, S, _ = x.shape
    if kv_override is not None:
        q = (x @ p["wq"] + (p.get("bq", 0)
                            )).reshape(B, S, cfg.num_heads, cfg.hd)
        if positions is not None:
            q = apply_rope(q, rope_angles(positions, cfg.hd, cfg.rope_theta))
        k, v = kv_override
        out = attention_core(q, k, v, causal=False, impl=cfg.attn_impl)
        return out.reshape(B, S, -1) @ p["wo"], None

    q, k, v = qkv(p, x, cfg, positions)
    if kv_cache is None:
        out = attention_core(q, k, v, causal=causal, impl=cfg.attn_impl)
        return out.reshape(B, S, -1) @ p["wo"], (k, v)

    kc, vc = kv_cache                       # (B, T, K, hd) static T
    T = kc.shape[1]
    cache_pos = jnp.asarray(cache_pos)
    if cache_pos.ndim == 0:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, cache_pos, 0, 0))
        valid = (jnp.arange(T) <= cache_pos + S - 1
                 )[None, None, None, None, :]
    else:
        # per-slot positions (continuous batching): vmap the row update
        upd = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(
            c, n, (p, 0, 0)))
        kc = upd(kc, k.astype(kc.dtype), cache_pos)
        vc = upd(vc, v.astype(vc.dtype), cache_pos)
        valid = (jnp.arange(T)[None, :] <= (cache_pos[:, None] + S - 1)
                 )[:, None, None, None, :]
    out = attention_core(q, kc, vc, mask=valid, impl="ref")
    return out.reshape(B, S, -1) @ p["wo"], (kc, vc)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_swiglu(rng, cfg) -> Params:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.jnp_dtype
    rs = split_rngs(rng, 3)
    return {"wg": dense_init(rs[0], (d, f), dt),
            "wu": dense_init(rs[1], (d, f), dt),
            "wd": dense_init(rs[2], (f, d), dt)}


def swiglu(p: Params, x):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_gelu_mlp(rng, cfg) -> Params:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.jnp_dtype
    rs = split_rngs(rng, 2)
    return {"w1": dense_init(rs[0], (d, f), dt),
            "w2": dense_init(rs[1], (f, d), dt)}


def gelu_mlp(p: Params, x):
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def init_embed(rng, cfg) -> Params:
    dt = cfg.jnp_dtype
    rs = split_rngs(rng, 2)
    V = cfg.padded_vocab
    p = {"embed": embed_init(rs[0], (V, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(rs[1], (cfg.d_model, V), dt)
    return p


def embed(p: Params, tokens):
    return jnp.take(p["embed"], tokens, axis=0)


def unembed(p: Params, h, cfg):
    """Project to (padded) vocab logits; padded columns masked to -inf so
    softmax/argmax semantics are exactly the unpadded model's."""
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (h @ w).astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp != cfg.vocab_size:
        logits = jnp.where(jnp.arange(Vp) < cfg.vocab_size, logits, -1e30)
    return logits


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def cross_entropy(logits, targets, mask=None):
    """logits (B,S,V) f32, targets (B,S) int32 -> scalar mean nll."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
