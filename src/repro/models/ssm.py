"""Mamba2 (SSD — state-space duality) blocks, pure JAX reference.

The SSD chunked algorithm (Dao & Gu, 2024) maps the selective-state-space
recurrence onto matmuls the MXU can eat:

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t x_t^T        (state: N x P)
    y_t = C_t . S_t + D_h * x_t

split the sequence into chunks of Q tokens; within a chunk the kernel is
a (masked) quadratic form — matmuls; across chunks a cheap associative
recurrence over chunk states.  The intra-chunk part is the compute
hot-spot and has a Pallas TPU kernel (``repro.kernels.ssd``); this module
is the oracle and the CPU/dry-run lowering path.

Shapes: x (B,L,H,P)  dt (B,L,H)  A (H,)  B/C (B,L,G,N) with G==1 here.
All SSD math is f32 regardless of model dtype (exponentials).
"""
from __future__ import annotations

import math
import typing

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, rms_norm, split_rngs


# --------------------------------------------------------------------------
# core SSD scan (reference)
# --------------------------------------------------------------------------

def ssd_reference(x, dt, A, Bm, Cm, chunk: int = 256, initial_state=None,
                  return_state: bool = False):
    """Chunked SSD. x (B,L,H,P) dt (B,L,H) A (H,) Bm/Cm (B,L,G,N)."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    C = Lp // Q
    xc = x.reshape(Bsz, C, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, C, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, C, Q, G, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, C, Q, G, N).astype(jnp.float32)
    A = A.astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                      # (B,C,Q,H) <= 0
    cs = jnp.cumsum(dA, axis=2)                            # inclusive cumsum

    # ---- intra-chunk (diagonal blocks) --------------------------------
    # att[b,c,h,i,j] = exp(cs_i - cs_j) * (C_i . B_j) * dt_j   (i >= j)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]      # (B,C,Q,Q,H) i,j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    qk = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)          # (B,C,Q,Q,G)
    hpg = H // G
    att = (qk[..., :, None] *
           decay.reshape(*decay.shape[:-1], G, hpg)
           ).reshape(Bsz, C, Q, Q, H)
    att = att * dtc[:, :, None, :, :]                      # dt_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # ---- chunk states --------------------------------------------------
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)          # (B,C,Q,H)
    bdx = Bc[:, :, :, :, None, :] \
        .repeat(hpg, axis=4).reshape(Bsz, C, Q, H, N)      # (B,C,Q,H,N)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp",
                        bdx, decay_to_end * dtc, xc)        # (B,C,H,N,P)

    # ---- inter-chunk recurrence ----------------------------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])                 # (B,C,H)
    s0 = initial_state.astype(jnp.float32) if initial_state is not None \
        else jnp.zeros((Bsz, H, N, P), jnp.float32)

    def step(s, inp):
        d, snew = inp                                       # (B,H),(B,H,N,P)
        s_out = s                                           # state entering chunk
        s = d[:, :, None, None] * s + snew
        return s, s_out

    final, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                         # (B,C,H,N,P)

    # ---- off-diagonal (carry-in state) ---------------------------------
    cdx = Cc[:, :, :, :, None, :] \
        .repeat(hpg, axis=4).reshape(Bsz, C, Q, H, N)
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       cdx, s_in, jnp.exp(cs))
    y = (y_diag + y_off).reshape(Bsz, Lp, H, P)[:, :L]
    if return_state:
        return y, final
    return y


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 256, initial_state=None,
             return_state: bool = False, bh=None):
    """Memory-lean SSD — identical math to :func:`ssd_reference`, but the
    O(Q^2) intra-chunk tile is built for ONE chunk at a time.

    Two passes:
      1. chunk states (no Q^2 tensor) + the tiny inter-chunk scan;
      2. `lax.map` over chunks for the quadratic part, with the chunk
         body `jax.checkpoint`'ed so the backward pass rebuilds each
         (B,Q,Q,H) tile instead of stacking all C of them — the
         difference between ~30 MB and ~470 GB live per device at
         zamba2-7b/train_4k scale.

    Requires G == 1 (all assigned SSM archs).  Equality with
    ssd_reference is asserted in tests/test_kernels.py.
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert G == 1, "ssd_scan assumes a single B/C group"
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    C = Lp // Q
    xc = x.reshape(Bsz, C, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, C, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, C, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, C, Q, N).astype(jnp.float32)
    A = A.astype(jnp.float32)

    if bh is not None:
        # pin (batch -> dp, heads -> model) on the big SSD tensors: GSPMD
        # otherwise drops the batch sharding at the chunk-map boundary
        # and replicates full-batch tiles per device (§Perf zamba2 it.3)
        xc = bh(xc, 0, 3)
        dtc = bh(dtc, 0, 3)
    dA = dtc * A[None, None, None, :]
    cs = jnp.cumsum(dA, axis=2)                            # (B,C,Q,H)

    # ---- pass 1: chunk states (linear in Q) + inter-chunk scan --------
    w_end = jnp.exp(cs[:, :, -1:, :] - cs) * dtc           # (B,C,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, w_end, xc)
    if bh is not None:
        states = bh(states, 0, 2)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                 # (B,C,H)
    s0 = initial_state.astype(jnp.float32) if initial_state is not None \
        else jnp.zeros((Bsz, H, N, P), jnp.float32)

    def step(s, inp):
        d, snew = inp
        return d[:, :, None, None] * s + snew, s

    final, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                        # (B,C,H,N,P)

    if bh is not None:
        s_in = bh(s_in, 0, 2)

    # ---- pass 2: per-chunk quadratic tile, one chunk live at a time ----
    @jax.checkpoint
    def chunk_fn(args):
        cs_c, dt_c, x_c, b_c, c_c, sin_c = args
        if bh is not None:
            cs_c = bh(cs_c, 0, 2)
            x_c = bh(x_c, 0, 2)
        seg = cs_c[:, :, None, :] - cs_c[:, None, :, :]    # (B,Q,Q,H)
        ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        decay = jnp.where((ii >= jj)[None, :, :, None], jnp.exp(seg), 0.0)
        qk = jnp.einsum("bin,bjn->bij", c_c, b_c)          # (B,Q,Q)
        att = qk[..., None] * decay * dt_c[:, None, :, :]
        if bh is not None:
            att = bh(att, 0, 3)
        y_d = jnp.einsum("bijh,bjhp->bihp", att, x_c)
        y_o = jnp.einsum("bqn,bhnp->bqhp", c_c, sin_c) * \
            jnp.exp(cs_c)[..., None]
        y_c = y_d + y_o
        if bh is not None:
            y_c = bh(y_c, 0, 2)
        return y_c

    args = tuple(jnp.moveaxis(a, 1, 0) for a in
                 (cs, dtc, xc, Bc, Cc, s_in))
    y = jax.lax.map(chunk_fn, args)                        # (C,B,Q,H,P)
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, Lp, H, P)[:, :L]
    if return_state:
        return y, final
    return y


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token SSD update.  state (B,H,N,P); x (B,H,P); dt (B,H);
    Bm/Cm (B,G,N). Returns (y (B,H,P), new_state)."""
    B, H, N, P = state.shape
    G = Bm.shape[1]
    hpg = H // G
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                          # (B,H)
    Bh = Bm.astype(jnp.float32).repeat(hpg, axis=1)        # (B,H,N)
    Ch = Cm.astype(jnp.float32).repeat(hpg, axis=1)
    new = dA[:, :, None, None] * state + \
        jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, x)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new)
    return y, new


# --------------------------------------------------------------------------
# depthwise causal conv (width W, conv over channels of xBC)
# --------------------------------------------------------------------------

def causal_conv(x, w, b):
    """x (B,L,D), w (W,D), b (D,) -> (B,L,D); y_t = sum_i x_{t-W+1+i} w_i."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    L = x.shape[1]
    for i in range(W):                                      # static, W=4
        y = y + xp[:, i:i + L, :] * w[i]
    return y + b


def conv_step(conv_state, x_t, w, b):
    """conv_state (B,W-1,D); x_t (B,D) -> (y_t (B,D), new_state)."""
    W = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,D)
    y = jnp.einsum("bwd,wd->bd", full, w) + b
    return y, full[:, 1:, :]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def init_mamba2(rng, cfg) -> Params:
    """Mamba2 block params.  The reference fused in_proj/conv are stored
    as COLUMN BLOCKS (wz | wx | wbc | wdt and conv_x | conv_bc): the same
    linear maps (identical math, identical parameter count), but each
    block's output dim is cleanly TP-shardable — the fused layout slices
    at non-shard-aligned offsets and forces GSPMD to replicate the whole
    SSD inner state (EXPERIMENTS.md §Perf, zamba2 iteration 2)."""
    dt_ = cfg.jnp_dtype
    rs = split_rngs(rng, 8)
    H = cfg.ssm_heads
    gn2 = 2 * cfg.ssm_groups * cfg.ssm_state
    # A in [1, 16] (standard mamba2 init), dt bias ~ softplus^-1(U[1e-3,1e-1])
    a = jnp.exp(jax.random.uniform(rs[2], (H,), jnp.float32,
                                   math.log(1.0), math.log(16.0)))
    u = jax.random.uniform(rs[3], (H,), jnp.float32, 1e-3, 1e-1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))                  # inv softplus
    return {
        "wz": dense_init(rs[0], (cfg.d_model, cfg.d_inner), dt_),
        "wx": dense_init(rs[1], (cfg.d_model, cfg.d_inner), dt_),
        "wbc": dense_init(rs[4], (cfg.d_model, gn2), dt_),
        "wdt": dense_init(rs[5], (cfg.d_model, H), dt_),
        "conv_xw": dense_init(rs[6], (cfg.ssm_conv_width, cfg.d_inner),
                              jnp.float32, scale=0.5),
        "conv_xb": jnp.zeros((cfg.d_inner,), jnp.float32),
        "conv_bcw": dense_init(rs[7], (cfg.ssm_conv_width, gn2),
                               jnp.float32, scale=0.5),
        "conv_bcb": jnp.zeros((gn2,), jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.ones((cfg.d_inner,), dt_),
        "out_proj": dense_init(rs[3], (cfg.d_inner, cfg.d_model), dt_),
    }


def mamba2_block(p: Params, x, cfg, initial_state=None,
                 return_state: bool = False, ctx=None):
    """Full-sequence Mamba2 mixer. x (B,L,d) -> y (B,L,d)."""
    B, L, _ = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = x @ p["wz"]
    xr = (x @ p["wx"]).astype(jnp.float32)                  # (B,L,d_inner)
    bc = (x @ p["wbc"]).astype(jnp.float32)                 # (B,L,2GN)
    dt = x @ p["wdt"]                                       # (B,L,H)
    xs = jax.nn.silu(causal_conv(xr, p["conv_xw"], p["conv_xb"]))
    bc = jax.nn.silu(causal_conv(bc, p["conv_bcw"], p["conv_bcb"]))
    xs = xs.reshape(B, L, H, P)
    Bm = bc[..., :G * N].reshape(B, L, G, N)
    Cm = bc[..., G * N:].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    out = ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                   initial_state=initial_state, return_state=return_state,
                   bh=(ctx or {}).get("bh"))
    y, final = out if return_state else (out, None)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, L, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    y = y @ p["out_proj"]
    if return_state:
        # conv tail: last W-1 pre-activation channels feed future steps
        W = cfg.ssm_conv_width
        raw = jnp.concatenate([xr, bc_raw(x, p)], axis=-1)
        tail = jnp.pad(raw, ((0, 0), (max(0, W - 1 - L), 0),
                             (0, 0)))[:, -(W - 1):]
        return y, {"ssm": final, "conv": tail}
    return y


def bc_raw(x, p):
    return (x @ p["wbc"]).astype(jnp.float32)


def mamba2_step(p: Params, x_t, state, cfg):
    """One-token Mamba2 step. x_t (B,d); state {"ssm","conv"}."""
    B = x_t.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = x_t @ p["wz"]
    xr = (x_t @ p["wx"]).astype(jnp.float32)
    bc = (x_t @ p["wbc"]).astype(jnp.float32)
    dt = x_t @ p["wdt"]
    xbc = jnp.concatenate([xr, bc], axis=-1)
    w = jnp.concatenate([p["conv_xw"], p["conv_bcw"]], axis=-1)
    b = jnp.concatenate([p["conv_xb"], p["conv_bcb"]], axis=-1)
    xbc_c, conv_new = conv_step(state["conv"], xbc, w, b)
    xbc_c = jax.nn.silu(xbc_c)
    xs = xbc_c[..., :cfg.d_inner].reshape(B, H, P)
    Bm = xbc_c[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B, G, N)
    Cm = xbc_c[..., cfg.d_inner + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm_new = ssd_decode_step(state["ssm"], xs, dt, A, Bm, Cm)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(B, cfg.d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], {"ssm": ssm_new, "conv": conv_new}


def init_mamba_state(cfg, batch: int) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {"ssm": jnp.zeros((batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.conv_dim),
                              jnp.float32)}
