"""Mamba2 language model (attention-free, SSD blocks only).

mamba2-1.3b: 48 layers, d_model=2048, d_state=128 — sub-quadratic in
sequence length, so it runs the long_500k cell (O(1) per-token state).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .base import ModelConfig

Params = typing.Dict[str, typing.Any]


def init(rng, cfg: ModelConfig) -> Params:
    rs = L.split_rngs(rng, 2)
    n = cfg.num_layers
    p: Params = L.init_embed(rs[0], cfg)
    outs = [S.init_mamba2(r, cfg) for r in L.split_rngs(rs[1], n)]
    p["layers"] = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *outs),
                   "ln": jnp.ones((n, cfg.d_model), cfg.jnp_dtype)}
    p["ln_f"] = jnp.ones((cfg.d_model,), cfg.jnp_dtype)
    return p


def forward(p: Params, cfg: ModelConfig, tokens, extra_embeds=None,
            ctx=None):
    h = L.embed(p, tokens)

    def body(h, lp):
        y = S.mamba2_block(lp["ssm"],
                           L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                           ctx=ctx)
        return h + y, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["layers"])
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg), 0.0


def loss_fn(p: Params, cfg: ModelConfig, batch, aux_weight: float = 0.0,
            ctx=None):
    logits, _ = forward(p, cfg, batch["tokens"], ctx=ctx)
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    n = cfg.num_layers
    return {"ssm": jnp.zeros((n, batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((n, batch, cfg.ssm_conv_width - 1, cfg.conv_dim),
                              jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(p: Params, cfg: ModelConfig, tokens, cache: dict):
    h = L.embed(p, tokens)

    def body(h, lp):
        y, st = S.mamba2_block(lp["ssm"],
                               L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                               return_state=True)
        return h + y, st

    h, states = jax.lax.scan(body, h, p["layers"])
    cache = dict(cache, ssm=states["ssm"], conv=states["conv"],
                 pos=jnp.asarray(tokens.shape[1], jnp.int32))
    h = L.rms_norm(h[:, -1:], p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)[:, 0], cache


def decode_step(p: Params, cfg: ModelConfig, cache: dict, token):
    h = L.embed(p, token[:, None])[:, 0]

    def body(h, xs):
        lp, s_st, c_st = xs
        y, st = S.mamba2_step(lp["ssm"],
                              L.rms_norm(h, lp["ln"], cfg.norm_eps),
                              {"ssm": s_st, "conv": c_st}, cfg)
        return h + y, (st["ssm"], st["conv"])

    h, (ssm_new, conv_new) = jax.lax.scan(
        body, h, (p["layers"], cache["ssm"], cache["conv"]))
    cache = dict(cache, ssm=ssm_new, conv=conv_new, pos=cache["pos"] + 1)
    h = L.rms_norm(h[:, None], p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)[:, 0], cache
