from .base import ModelConfig, get_config, list_archs, register
from . import api, layers, moe, ssm

__all__ = ["ModelConfig", "get_config", "list_archs", "register",
           "api", "layers", "moe", "ssm"]
