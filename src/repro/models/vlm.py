"""LLaVA-NeXT-style VLM: anyres patch frontend (STUB) + dense LM backbone.

Per the assignment, the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings (B, num_patches, d_model) — the
anyres tiling (4 tiles + 1 base image, 576 patches each ≈ 2880) is
represented by the patch count only.  The backbone (the systems-relevant
part: 60L, d=7168) is the shared dense transformer; patches are prepended
to the text tokens, loss applies to text positions.
"""
from __future__ import annotations

from . import transformer as T
from .base import ModelConfig

init = T.init
init_cache = T.init_cache


def forward(p, cfg: ModelConfig, tokens, patches):
    return T.forward(p, cfg, tokens, extra_embeds=patches)


def loss_fn(p, cfg: ModelConfig, batch, aux_weight: float = 0.0, ctx=None):
    return T.loss_fn(p, cfg, batch, ctx=ctx)


def prefill(p, cfg: ModelConfig, tokens, cache, patches=None):
    return T.prefill(p, cfg, tokens, cache, extra_embeds=patches)


decode_step = T.decode_step
