"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

81 SSM layers; after every ``attn_every``-th SSM layer the single shared
attention+MLP block (one parameter set, reused) runs — Zamba2's
parameter-efficient global-mixing trick.  Layout for scan-friendliness:

    G = num_layers // attn_every   super-blocks of (attn_every SSM + attn)
    R = num_layers % attn_every    tail SSM layers

SSM params are stacked (G, attn_every, ...) + tail (R, ...); the shared
block's KV cache is stacked per application: (G, B, T, K, hd).

Long-context decode (long_500k) is the point of this family: per-token
state is O(1) in sequence for the SSM stack and the few shared-attention
caches are sequence-sharded over the "model" mesh axis.
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .base import ModelConfig

Params = typing.Dict[str, typing.Any]


def _gr(cfg: ModelConfig):
    g = cfg.num_layers // cfg.attn_every
    r = cfg.num_layers - g * cfg.attn_every
    return g, r


def init(rng, cfg: ModelConfig) -> Params:
    rs = L.split_rngs(rng, 5)
    dt = cfg.jnp_dtype
    G, R = _gr(cfg)
    K = cfg.attn_every

    def stack_gk(rng_):
        outs = [S.init_mamba2(r, cfg) for r in L.split_rngs(rng_, G * K)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return jax.tree.map(lambda x: x.reshape((G, K) + x.shape[1:]), stacked)

    p: Params = L.init_embed(rs[0], cfg)
    p["blocks"] = {"ssm": stack_gk(rs[1]),
                   "ln": jnp.ones((G, K, cfg.d_model), dt)}
    if R:
        outs = [S.init_mamba2(r, cfg) for r in L.split_rngs(rs[2], R)]
        p["tail"] = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *outs),
                     "ln": jnp.ones((R, cfg.d_model), dt)}
    p["shared"] = {
        "attn": L.init_attention(rs[3], cfg),
        "mlp": L.init_swiglu(rs[4], cfg),
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    p["ln_f"] = jnp.ones((cfg.d_model,), dt)
    return p


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _ssm_sub(lp, h, cfg, ctx=None):
    return h + S.mamba2_block(lp["ssm"], L.rms_norm(h, lp["ln"], cfg.norm_eps),
                              cfg, ctx=ctx)


def _shared_block(sp, h, cfg, positions, kv_cache=None, cache_pos=None):
    a, kv = L.attention_block(sp["attn"],
                              L.rms_norm(h, sp["ln1"], cfg.norm_eps), cfg,
                              positions=positions, causal=kv_cache is None,
                              kv_cache=kv_cache, cache_pos=cache_pos)
    h = h + a
    h = h + L.swiglu(sp["mlp"], L.rms_norm(h, sp["ln2"], cfg.norm_eps))
    return h, kv


def forward(p: Params, cfg: ModelConfig, tokens, extra_embeds=None,
            ctx=None):
    h = L.embed(p, tokens)
    Sq = h.shape[1]
    positions = jnp.arange(Sq)
    G, R = _gr(cfg)

    def super_block(h, bp):
        def inner(h, lp):
            return _ssm_sub(lp, h, cfg, ctx), None
        if cfg.remat:
            # nested remat: one SSM layer's internals live at a time
            # during the super-block backward (zamba2 §Perf iteration 2)
            inner = jax.checkpoint(inner)
        h, _ = jax.lax.scan(inner, h, bp)
        h, _ = _shared_block(p["shared"], h, cfg, positions)
        return h, None

    body = jax.checkpoint(super_block) if cfg.remat else super_block
    h, _ = jax.lax.scan(body, h, p["blocks"])
    if R:
        def tail_body(h, lp):
            return _ssm_sub(lp, h, cfg, ctx), None
        tb = jax.checkpoint(tail_body) if cfg.remat else tail_body
        h, _ = jax.lax.scan(tb, h, p["tail"])
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg), 0.0


def loss_fn(p: Params, cfg: ModelConfig, batch, aux_weight: float = 0.0,
            ctx=None):
    logits, _ = forward(p, cfg, batch["tokens"], ctx=ctx)
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    G, R = _gr(cfg)
    K = cfg.attn_every
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cache = {
        "k": jnp.zeros((G, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((G, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt),
        "ssm": jnp.zeros((G, K, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((G, K, batch, cfg.ssm_conv_width - 1, cfg.conv_dim),
                          jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
    if R:
        cache["ssm_tail"] = jnp.zeros((R, batch, H, N, P), jnp.float32)
        cache["conv_tail"] = jnp.zeros(
            (R, batch, cfg.ssm_conv_width - 1, cfg.conv_dim), jnp.float32)
    return cache


def prefill(p: Params, cfg: ModelConfig, tokens, cache: dict):
    B, Sq = tokens.shape
    h = L.embed(p, tokens)
    positions = jnp.arange(Sq)
    G, R = _gr(cfg)

    def super_block(h, bp):
        def inner(h, lp):
            y, st = S.mamba2_block(
                lp["ssm"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                return_state=True)
            return h + y, st
        h, states = jax.lax.scan(inner, h, bp)
        h, kv = _shared_block(p["shared"], h, cfg, positions)
        return h, (states, kv)

    h, (blk_states, kvs) = jax.lax.scan(super_block, h, p["blocks"])
    cache = dict(cache)
    cache["ssm"] = blk_states["ssm"]
    cache["conv"] = blk_states["conv"]
    k_new, v_new = kvs
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    if R:
        def tail_body(h, lp):
            y, st = S.mamba2_block(
                lp["ssm"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                return_state=True)
            return h + y, st
        h, tail_states = jax.lax.scan(tail_body, h, p["tail"])
        cache["ssm_tail"] = tail_states["ssm"]
        cache["conv_tail"] = tail_states["conv"]
    cache["pos"] = jnp.asarray(Sq, jnp.int32)
    h = L.rms_norm(h[:, -1:], p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)[:, 0], cache


def decode_step(p: Params, cfg: ModelConfig, cache: dict, token):
    B = token.shape[0]
    h = L.embed(p, token[:, None])[:, 0]               # (B,d)
    pos = cache["pos"]                                 # scalar or (B,) slots
    positions = pos[:, None] if pos.ndim else \
        pos[None, None] + jnp.zeros((1, 1), jnp.int32)
    G, R = _gr(cfg)

    def super_block(h, xs):
        bp, ssm_st, conv_st, kc, vc = xs
        # explicit (static) loop over the K inner SSM layers keeps state
        # plumbing simple; K is small (6) so HLO stays compact.
        new_ssm, new_conv = [], []
        for i in range(cfg.attn_every):
            lp = jax.tree.map(lambda x: x[i], bp)
            st = {"ssm": ssm_st[i], "conv": conv_st[i]}
            y, st2 = S.mamba2_step(
                lp["ssm"], L.rms_norm(h, lp["ln"], cfg.norm_eps), st, cfg)
            h = h + y
            new_ssm.append(st2["ssm"])
            new_conv.append(st2["conv"])
        h2, (kc2, vc2) = _shared_block(p["shared"], h[:, None], cfg,
                                       positions, kv_cache=(kc, vc),
                                       cache_pos=pos)
        return h2[:, 0], (jnp.stack(new_ssm), jnp.stack(new_conv), kc2, vc2)

    h, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
        super_block, h, (p["blocks"], cache["ssm"], cache["conv"],
                         cache["k"], cache["v"]))
    cache = dict(cache, ssm=ssm_new, conv=conv_new, k=k_new, v=v_new)
    if R:
        new_s, new_c = [], []
        for i in range(R):
            lp = jax.tree.map(lambda x: x[i], p["tail"])
            st = {"ssm": cache["ssm_tail"][i], "conv": cache["conv_tail"][i]}
            y, st2 = S.mamba2_step(
                lp["ssm"], L.rms_norm(h, lp["ln"], cfg.norm_eps), st, cfg)
            h = h + y
            new_s.append(st2["ssm"])
            new_c.append(st2["conv"])
        cache["ssm_tail"] = jnp.stack(new_s)
        cache["conv_tail"] = jnp.stack(new_c)
    cache["pos"] = pos + 1
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h[:, None], cfg)[:, 0], cache
