"""Unified model facade: family dispatch for init / loss / serve.

Every architecture exposes the same five entry points regardless of
family, which is what launch/dryrun.py, train/loop.py and serve/engine.py
program against:

    init(rng, cfg)                      -> params
    loss(params, cfg, batch)            -> scalar f32
    init_cache(cfg, batch, max_seq)     -> cache pytree
    prefill(params, cfg, cache, batch)  -> (last_logits, cache)
    decode_step(params, cfg, cache, tok)-> (logits, cache)

``batch`` carries modality extras under fixed keys: "frames" (audio stub),
"patches" (VLM stub).
"""
from __future__ import annotations

import typing

import jax

from . import encdec, hybrid, mamba_lm, transformer, vlm
from .base import ModelConfig

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": vlm,
    "encdec": encdec,
    "ssm": mamba_lm,
    "hybrid": hybrid,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init(rng, cfg: ModelConfig):
    return module_for(cfg).init(rng, cfg)


def loss(params, cfg: ModelConfig, batch, ctx=None) -> jax.Array:
    return module_for(cfg).loss_fn(params, cfg, batch, ctx=ctx)


def forward(params, cfg: ModelConfig, batch):
    m = module_for(cfg)
    if cfg.family == "encdec":
        return m.forward(params, cfg, batch["tokens"], batch["frames"])
    if cfg.family == "vlm":
        return m.forward(params, cfg, batch["tokens"], batch["patches"])
    return m.forward(params, cfg, batch["tokens"])


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    return module_for(cfg).init_cache(cfg, batch, max_seq, dtype)


def prefill(params, cfg: ModelConfig, cache, batch):
    m = module_for(cfg)
    if cfg.family == "encdec":
        return m.prefill(params, cfg, batch["tokens"], cache,
                         frames=batch["frames"])
    if cfg.family == "vlm":
        return m.prefill(params, cfg, batch["tokens"], cache,
                         patches=batch["patches"])
    return m.prefill(params, cfg, batch["tokens"], cache)


def decode_step(params, cfg: ModelConfig, cache, token):
    return module_for(cfg).decode_step(params, cfg, cache, token)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
