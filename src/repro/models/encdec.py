"""Whisper-style encoder-decoder transformer.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d_model).  The backbone
is the real deliverable: a bidirectional encoder + causal decoder with
cross-attention.  Positional encoding is sinusoidal for both stacks
(adaptation note in DESIGN.md: whisper's learned decoder positions carry
no systems-relevant structure).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from . import layers as L
from .base import ModelConfig

Params = typing.Dict[str, typing.Any]


def init(rng, cfg: ModelConfig) -> Params:
    rs = L.split_rngs(rng, 6)
    dt = cfg.jnp_dtype
    ne, nd = cfg.encoder_layers, cfg.num_layers
    p: Params = L.init_embed(rs[0], cfg)
    p["encoder"] = {
        "attn": L._stack_init(L.init_attention, rs[1], ne, cfg),
        "mlp": L._stack_init(L.init_gelu_mlp, rs[2], ne, cfg),
        "ln1": jnp.ones((ne, cfg.d_model), dt),
        "ln2": jnp.ones((ne, cfg.d_model), dt),
    }
    p["decoder"] = {
        "self_attn": L._stack_init(L.init_attention, rs[3], nd, cfg),
        "cross_attn": L._stack_init(L.init_attention, rs[4], nd, cfg),
        "mlp": L._stack_init(L.init_gelu_mlp, rs[5], nd, cfg),
        "ln1": jnp.ones((nd, cfg.d_model), dt),
        "ln2": jnp.ones((nd, cfg.d_model), dt),
        "ln3": jnp.ones((nd, cfg.d_model), dt),
    }
    p["ln_enc"] = jnp.ones((cfg.d_model,), dt)
    p["ln_f"] = jnp.ones((cfg.d_model,), dt)
    return p


def encode(p: Params, cfg: ModelConfig, frames):
    """frames (B, T_enc, d) stub embeddings -> encoder states."""
    B, T, d = frames.shape
    h = frames.astype(cfg.jnp_dtype) + L.sinusoidal_pos(T, d, cfg.jnp_dtype)

    def body(h, lp):
        a, _ = L.attention_block(lp["attn"],
                                 L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cfg, causal=False)
        h = h + a
        h = h + L.gelu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["encoder"])
    return L.rms_norm(h, p["ln_enc"], cfg.norm_eps)


def _cross_kv(lp, enc, cfg):
    B, T, _ = enc.shape
    k = (enc @ lp["wk"] + (lp["bk"] if "bk" in lp else 0)
         ).reshape(B, T, cfg.num_kv_heads, cfg.hd)
    v = (enc @ lp["wv"] + (lp["bv"] if "bv" in lp else 0)
         ).reshape(B, T, cfg.num_kv_heads, cfg.hd)
    return k, v


def decode(p: Params, cfg: ModelConfig, tokens, enc):
    """Teacher-forced decoder pass. tokens (B,S) -> logits (B,S,V)."""
    B, S = tokens.shape
    h = L.embed(p, tokens) + L.sinusoidal_pos(S, cfg.d_model, cfg.jnp_dtype)

    def body(h, lp):
        a, _ = L.attention_block(lp["self_attn"],
                                 L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cfg, causal=True)
        h = h + a
        kv = _cross_kv(lp["cross_attn"], enc, cfg)
        c, _ = L.attention_block(lp["cross_attn"],
                                 L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 cfg, causal=False, kv_override=kv)
        h = h + c
        h = h + L.gelu_mlp(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, p["decoder"])
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)


def forward(p: Params, cfg: ModelConfig, tokens, frames):
    enc = encode(p, cfg, frames)
    return decode(p, cfg, tokens, enc), 0.0


def loss_fn(p: Params, cfg: ModelConfig, batch, aux_weight: float = 0.0,
            ctx=None):
    logits, _ = forward(p, cfg, batch["tokens"], batch["frames"])
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    nd = cfg.num_layers
    return {
        "k": jnp.zeros((nd, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((nd, batch, max_seq, cfg.num_kv_heads, cfg.hd), dt),
        "xk": jnp.zeros((nd, batch, cfg.encoder_seq, cfg.num_kv_heads,
                         cfg.hd), dt),
        "xv": jnp.zeros((nd, batch, cfg.encoder_seq, cfg.num_kv_heads,
                         cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(p: Params, cfg: ModelConfig, tokens, cache: dict, frames=None):
    """Encode audio, precompute cross KV, run the prompt through the
    decoder filling the self-attention cache."""
    enc = encode(p, cfg, frames)
    B, S = tokens.shape
    h = L.embed(p, tokens) + L.sinusoidal_pos(S, cfg.d_model, cfg.jnp_dtype)

    def body(h, lp):
        a, kv = L.attention_block(lp["self_attn"],
                                  L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                  cfg, causal=True)
        h = h + a
        xk, xv = _cross_kv(lp["cross_attn"], enc, cfg)
        c, _ = L.attention_block(lp["cross_attn"],
                                 L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 cfg, causal=False, kv_override=(xk, xv))
        h = h + c
        h = h + L.gelu_mlp(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
        return h, (kv[0], kv[1], xk, xv)

    h, (ks, vs, xks, xvs) = jax.lax.scan(body, h, p["decoder"])
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["xk"] = xks.astype(cache["xk"].dtype)
    cache["xv"] = xvs.astype(cache["xv"].dtype)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    h = L.rms_norm(h[:, -1:], p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)[:, 0], cache


def decode_step(p: Params, cfg: ModelConfig, cache: dict, token):
    B = token.shape[0]
    pos = cache["pos"]
    S_max = cache["k"].shape[2]
    pe = L.sinusoidal_pos(S_max, cfg.d_model, cfg.jnp_dtype)
    h = L.embed(p, token[:, None]) + \
        jax.lax.dynamic_slice(pe, (pos, 0), (1, cfg.d_model))[None]

    def body(h, xs):
        lp, kc, vc, xk, xv = xs
        a, (kc2, vc2) = L.attention_block(
            lp["self_attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
            causal=False, kv_cache=(kc, vc), cache_pos=pos)
        h = h + a
        c, _ = L.attention_block(lp["cross_attn"],
                                 L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 cfg, causal=False, kv_override=(xk, xv))
        h = h + c
        h = h + L.gelu_mlp(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
        return h, (kc2, vc2)

    h, (k_new, v_new) = jax.lax.scan(
        body, h, (p["decoder"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]))
    cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
    h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
    return L.unembed(p, h, cfg)[:, 0], cache
