"""Pure-jnp oracles for every Pallas kernel (the DP-4 ground truth).

Each function is the semantic definition its kernel must match;
tests/test_kernels.py sweeps shapes and dtypes asserting allclose.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    """q (B,Sq,H,hd); k/v (B,Skv,K,hd) GQA -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, kf) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def ssd_chunk_ref(x, dt, cs, Bm, Cm):
    """Intra-chunk SSD. x (R,H,Q,P); dt/cs (R,H,Q); Bm/Cm (R,H,Q,N)
    -> (y_diag (R,H,Q,P) f32, states (R,H,N,P) f32)."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    cs = cs.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    Q = x.shape[2]
    seg = cs[..., :, None] - cs[..., None, :]           # (R,H,Q,Q) i,j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    att = jnp.einsum("rhin,rhjn->rhij", Cm, Bm) * decay * dt[..., None, :]
    y = jnp.einsum("rhij,rhjp->rhip", att, x)
    w = jnp.exp(cs[..., -1:] - cs) * dt                 # (R,H,Q)
    s = jnp.einsum("rhqn,rhq,rhqp->rhnp", Bm, w, x)
    return y, s


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            w.astype(jnp.float32)).astype(x.dtype)


def stencil2d_ref(img, kern):
    """Same-padded KxK correlation-style stencil matching stencil2d."""
    K = kern.shape[0]
    r = K // 2
    pad = jnp.pad(img.astype(jnp.float32), r)
    out = jnp.zeros(img.shape, jnp.float32)
    for dy in range(K):
        for dx in range(K):
            out = out + kern[dy, dx].astype(jnp.float32) * \
                jax.lax.dynamic_slice(pad, (dy, dx), img.shape)
    return out.astype(img.dtype)


def bitonic_stage_ref(x, dist: int, size: int):
    """One compare-exchange stage: partner = i ^ dist, ascending iff
    (i & size) == 0."""
    L = x.shape[0]
    idx = jnp.arange(L)
    partner = idx ^ dist
    other = x[partner]
    asc = (idx & size) == 0
    take_min = (idx < partner) == asc
    return jnp.where(take_min, jnp.minimum(x, other), jnp.maximum(x, other))


def bitonic_sort_ref(x):
    """Full bitonic sort (power-of-two length) from stage_ref."""
    L = x.shape[0]
    size = 2
    while size <= L:
        dist = size // 2
        while dist >= 1:
            x = bitonic_stage_ref(x, dist, size)
            dist //= 2
        size *= 2
    return x
