"""Causal flash attention — Pallas TPU kernel.

TPU adaptation of FlashAttention: the online-softmax tiling is reshaped
for the MXU/VMEM hierarchy rather than CUDA warps/shared-memory:

* grid = (batch, q_heads, Sq/bq, Skv/bk); the kv axis is the innermost
  (sequential) grid dim, so the (m, l, acc) running state lives in VMEM
  scratch across kv steps — no HBM spills between tiles;
* block shapes are (bq, head_dim) / (bk, head_dim) with bq=bk=128 —
  MXU-aligned (128x128 systolic tiles);
* GQA is handled in the k/v BlockSpec index maps (q head h reads kv head
  h // group_size) — zero-copy, no repeated KV in HBM;
* causal: kv tiles strictly above the diagonal are skipped via pl.when
  (the mosaic grid still visits them, but no FLOPs/VMEM traffic happen).

f32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, bq: int, bk: int, causal: bool):
    i = pl.program_id(2)                     # q tile
    j = pl.program_id(3)                     # kv tile (innermost, sequential)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    should_run = True
    if causal:
        should_run = (j * bk) <= (i * bq + bq - 1)   # tile intersects lower tri

    @pl.when(should_run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (q @ k.T) * scale                          # (bq, bk)
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= kj, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + p.sum(-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = None):
    """q (B,Sq,H,hd); k/v (B,Skv,K,hd); H % K == 0 -> out (B,Sq,H,hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    qt = jnp.moveaxis(q, 1, 2)               # (B,H,Sq,hd)
    kt = jnp.moveaxis(k, 1, 2)               # (B,K,Skv,hd)
    vt = jnp.moveaxis(v, 1, 2)
    grid = (B, H, Sq // bq, Skv // bk)
    kernel = functools.partial(_flash_kernel, scale=1.0 / math.sqrt(hd),
                               bq=bq, bk=bk, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)           # (B,Sq,H,hd)
