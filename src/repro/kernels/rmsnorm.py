"""Fused RMSNorm — Pallas TPU kernel.

RMSNorm is bandwidth-bound; XLA usually fuses it already, but a fused
kernel pins the pattern: one pass over (rows, d) tiles in VMEM with the
mean-square reduction and the scale applied in-register, f32 math,
output in the input dtype.  Grid over row blocks; the full feature dim
stays resident (d <= 8192 -> 32 KB/row tile at f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, w, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = None):
    """x (..., d), w (d,) -> rmsnorm(x) * w, fused."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(shape)
