"""2-D convolution stencil — Pallas TPU kernel (MGMark SC workload).

The Adjacent-Access pattern's compute: a KxK stencil over an image tile.
Halo handling is done TPU-style: the input is passed through THREE
BlockSpecs whose index maps point at the tile above, the tile itself and
the tile below (clamped at the edges) — overlapping reads are expressed
as multiple views instead of CUDA-style shared-memory staging.  Columns
keep the full width so only row halos are needed (images are row-major
and W*4B <= VMEM budget for the benchmark sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(top_ref, mid_ref, bot_ref, k_ref, o_ref, *,
                    br: int, K: int):
    H = pl.num_programs(0) * br
    i = pl.program_id(0)
    r = K // 2
    top = top_ref[...].astype(jnp.float32)
    mid = mid_ref[...].astype(jnp.float32)
    bot = bot_ref[...].astype(jnp.float32)
    kern = k_ref[...].astype(jnp.float32)
    W = mid.shape[1]
    # assemble (br + 2r, W + 2r) working tile with zero column pads
    stacked = jnp.concatenate([top[-r:], mid, bot[:r]], axis=0)
    # row halos are invalid at the global edges -> zero them
    row_idx = i * br - r + jax.lax.broadcasted_iota(
        jnp.int32, (br + 2 * r, 1), 0)
    stacked = jnp.where((row_idx >= 0) & (row_idx < H), stacked, 0.0)
    padded = jnp.pad(stacked, ((0, 0), (r, r)))
    acc = jnp.zeros((br, W), jnp.float32)
    for dy in range(K):                       # static K (3 or 5)
        for dx in range(K):
            acc = acc + kern[dy, dx] * \
                jax.lax.dynamic_slice(padded, (dy, dx), (br, W))
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stencil2d(img, kern, block_rows: int = 128, interpret: bool = None):
    """img (H, W), kern (K, K) -> same-padded 2-D convolution (H, W)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    H, W = img.shape
    K = kern.shape[0]
    br = min(block_rows, H)
    assert H % br == 0, (H, br)
    n = H // br
    clamp = lambda i: jnp.clip(i, 0, n - 1)
    out = pl.pallas_call(
        functools.partial(_stencil_kernel, br=br, K=K),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, W), lambda i: (clamp(i - 1), 0)),
            pl.BlockSpec((br, W), lambda i: (i, 0)),
            pl.BlockSpec((br, W), lambda i: (clamp(i + 1), 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), img.dtype),
        interpret=interpret,
    )(img, img, img, kern)
    return out
