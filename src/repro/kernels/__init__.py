"""Pallas TPU kernels for the framework's compute hot-spots.

Kernels (each with a pure-jnp oracle in ref.py, wrapper in ops.py):
  * flash_attention — causal GQA attention (models' attn_impl="flash")
  * ssd             — Mamba2 SSD intra-chunk quadratic form
  * rmsnorm         — fused normalisation
  * stencil         — 2-D stencil (MGMark SC, Adjacent-Access pattern)
  * bitonic         — bitonic compare-exchange stage (MGMark BS, Irregular)

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling,
128-aligned MXU shapes) and are validated on CPU in interpret mode.
"""
from . import ops, ref
from .ops import (flash_attention, rmsnorm, ssd_chunk_kernel, ssd_pallas,
                  stencil2d, bitonic_stage)

__all__ = ["ops", "ref", "flash_attention", "rmsnorm", "ssd_chunk_kernel",
           "ssd_pallas", "stencil2d", "bitonic_stage"]
