"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU (this container)
they execute in interpret mode — same kernel body, Python-evaluated —
which is how tests validate them against the ref.py oracles.  Model code
selects kernels with ``cfg.use_pallas`` / ``cfg.attn_impl``; the dry-run
path stays pure-JAX (a TPU custom-call cannot lower for the CPU target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .ssd import ssd_chunk_kernel
from .stencil import stencil2d
from .bitonic import bitonic_stage
from . import ref

__all__ = ["flash_attention", "rmsnorm", "ssd_chunk_kernel", "stencil2d",
           "bitonic_stage", "ssd_pallas", "ref"]


def ssd_pallas(x, dt, A, Bm, Cm, chunk: int = 256, interpret: bool = None):
    """Drop-in for models.ssm.ssd_reference using the Pallas intra-chunk
    kernel + the jnp inter-chunk recurrence.

    x (B,L,H,P); dt (B,L,H) (post-softplus); A (H,); Bm/Cm (B,L,G=1,N).
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    C = L // Q
    # arrange (R=B*C, H, Q, ...) for the kernel
    xr = x.reshape(B, C, Q, H, P).transpose(0, 1, 3, 2, 4) \
        .reshape(B * C, H, Q, P)
    dtr = dt.reshape(B, C, Q, H).transpose(0, 1, 3, 2).reshape(B * C, H, Q)
    dA = dtr * A[None, :, None].astype(dtr.dtype)
    cs = jnp.cumsum(dA, axis=-1)
    G = Bm.shape[2]
    hpg = H // G
    Br = jnp.repeat(Bm, hpg, axis=2).reshape(B, C, Q, H, N) \
        .transpose(0, 1, 3, 2, 4).reshape(B * C, H, Q, N)
    Cr = jnp.repeat(Cm, hpg, axis=2).reshape(B, C, Q, H, N) \
        .transpose(0, 1, 3, 2, 4).reshape(B * C, H, Q, N)
    y_diag, states = ssd_chunk_kernel(xr, dtr, cs, Br, Cr,
                                      interpret=interpret)
    # ---- inter-chunk recurrence (jnp; tiny) ----
    y_diag = y_diag.reshape(B, C, H, Q, P)
    states = states.reshape(B, C, H, N, P)
    cs_b = cs.reshape(B, C, H, Q)
    chunk_decay = jnp.exp(cs_b[..., -1])                 # (B,C,H)
    s0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(s, inp):
        d, snew = inp
        return d[:, :, None, None] * s + snew, s

    _, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                      # (B,C,H,N,P)
    Cr_b = Cr.reshape(B, C, H, Q, N)
    y_off = jnp.einsum("bchqn,bchnp,bchq->bchqp", Cr_b, s_in,
                       jnp.exp(cs_b))
    y = (y_diag + y_off).transpose(0, 1, 3, 2, 4).reshape(B, L, H, P)
    return y
