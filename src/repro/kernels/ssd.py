"""Mamba2 SSD intra-chunk kernel — Pallas TPU.

The SSD hot-spot is the per-chunk quadratic form (Dao & Gu 2024, alg. 2):

    att[i,j] = (C_i . B_j) * exp(cs_i - cs_j) * dt_j   for i >= j
    Y_diag   = att @ X                    (Q x Q) @ (Q x P)
    S_chunk  = (B * exp(cs_Q - cs) * dt)^T @ X          (N x P)

One grid cell computes one (batch*chunk, head) tile entirely in VMEM —
Q=256, N<=128, P=64 gives a ~0.5 MB working set, and both matmuls are
MXU-shaped.  The inter-chunk recurrence (tiny: one (N,P) state per head
per chunk) stays in jnp (`repro.models.ssm.ssd_reference`) — it is
O(L/Q) sequential and bandwidth-trivial.

Inputs are pre-arranged by ops.ssd_chunk:
    x  (R, H, Q, P)   dt (R, H, Q)   cs (R, H, Q)   B/C (R, H, Q, N)
with R = batch * n_chunks, cs = inclusive cumsum of dt*A within chunk.
Outputs: y_diag (R, H, Q, P), states (R, H, N, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, cs_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0, 0].astype(jnp.float32)       # (Q,P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,1) -- padded trailing dim
    cs = cs_ref[0, 0].astype(jnp.float32)     # (Q,1)
    Bm = b_ref[0, 0].astype(jnp.float32)      # (Q,N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (Q,N)
    Q = x.shape[0]
    # decay matrix exp(cs_i - cs_j), lower-triangular
    seg = cs - cs.reshape(1, Q)               # (Q,Q) i,j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    att = (Cm @ Bm.T) * decay * dt.reshape(1, Q)
    y_ref[0, 0] = (att @ x).astype(y_ref.dtype)
    # chunk state: sum_j B_j dt_j exp(cs_last - cs_j) x_j
    w = jnp.exp(cs[Q - 1] - cs) * dt          # (Q,1)
    s_ref[0, 0] = ((Bm * w).T @ x).astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(x, dt, cs, Bm, Cm, interpret: bool = None):
    """x (R,H,Q,P); dt/cs (R,H,Q); Bm/Cm (R,H,Q,N) ->
    (y_diag (R,H,Q,P) f32, states (R,H,N,P) f32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    R, H, Q, P = x.shape
    N = Bm.shape[-1]
    dt2 = dt[..., None]                        # (R,H,Q,1)
    cs2 = cs[..., None]
    grid = (R, H)
    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda r, h: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda r, h: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda r, h: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda r, h: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda r, h: (r, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda r, h: (r, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda r, h: (r, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((R, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt2, cs2, Bm, Cm)
    return y, s
