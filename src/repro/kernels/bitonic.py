"""Bitonic compare-exchange stage — Pallas TPU kernel (MGMark BS workload).

One bitonic stage with compare distance ``dist`` inside a contiguous
block: partner(i) = i XOR dist; the ascending/descending direction flips
with bit ``size`` of the global index.  Stages with dist >= block size
are the *cross-shard* part of the Irregular pattern and are handled at
the jnp/shard_map level (patterns/bs.py) — this kernel owns the dense
in-VMEM stages, which dominate op count (log^2 factor).

Vectorized TPU formulation: with the block viewed as (block/2/dist rows
of [2*dist]), the exchange is a reshape to (?, 2, dist), a min/max pair
and a reshape back — no per-element scatter, VPU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(x_ref, o_ref, *, dist: int, size: int, block: int):
    i = pl.program_id(0)
    x = x_ref[...]
    v = x.reshape(block // (2 * dist), 2, dist)
    lo = jnp.minimum(v[:, 0], v[:, 1])
    hi = jnp.maximum(v[:, 0], v[:, 1])
    # direction: ascending iff (global_index & size) == 0
    base = i * block + jax.lax.broadcasted_iota(
        jnp.int32, (block // (2 * dist), dist), 0) * 2 * dist
    asc = (base & size) == 0
    first = jnp.where(asc, lo, hi)
    second = jnp.where(asc, hi, lo)
    o_ref[...] = jnp.stack([first, second], axis=1).reshape(block)


@functools.partial(jax.jit, static_argnames=("dist", "size", "block",
                                             "interpret"))
def bitonic_stage(x, dist: int, size: int, block: int = 2048,
                  interpret: bool = None):
    """One compare-exchange stage. x (L,), dist < block <= L, L % block == 0.
    ``size`` is the bitonic run length of the enclosing phase."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = x.shape[0]
    block = min(block, L)
    assert dist < block and L % block == 0 and block % (2 * dist) == 0
    return pl.pallas_call(
        functools.partial(_bitonic_kernel, dist=dist, size=size, block=block),
        grid=(L // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), x.dtype),
        interpret=interpret,
    )(x)
