"""Version compatibility shims for the pinned toolchain.

The repo targets current jax, but the baked image may carry an older
release where ``shard_map`` still lives under ``jax.experimental``.
Import it from here so every module gets the same resolution order.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.4.40 re-exports it at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    def shard_map(f, *args, **kwargs):
        """Old-jax shim: ``check_vma`` was spelled ``check_rep``."""
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, *args, **kwargs)


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    import jax
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` with a fallback for jax versions predating it.

    ``psum(1, axis)`` of a static value folds to the axis size as a
    Python int without emitting a collective, so traffic analysis is
    unaffected.
    """
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    Older jax returns a one-element list of per-module dicts; newer jax
    returns the dict directly. Either way the caller gets a dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


__all__ = ["shard_map", "make_auto_mesh", "axis_size", "cost_analysis_dict"]
