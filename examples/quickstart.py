"""Quickstart: the full public API in one file, CPU-runnable.

  PYTHONPATH=src python examples/quickstart.py

1. pick an assigned architecture (reduced config), init params;
2. train a few steps with the fault-tolerant loop (AdamW, checkpoints);
3. serve a few requests with the continuous-batching engine;
4. dry-run style analysis: lower the step, parse the machine-level HLO,
   replay it on the MGSim-TPU system model and print the roofline.
"""
import tempfile

import jax
from repro.compat import cost_analysis_dict
import numpy as np

from repro.core import SINGLE_POD, analyze, build_terms, simulate
from repro.launch.mesh import make_mesh
from repro.models import api, get_config
from repro.serve import Engine, Request
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, run
from repro.train.optim import OptConfig

ARCH = "qwen2-1.5b-smoke"


def main():
    cfg = get_config(ARCH)
    mesh = make_mesh((1, 1), ("data", "model"))

    # ---- 2. train -------------------------------------------------------
    print(f"== training {ARCH} ==")
    # fresh checkpoint dir: a leftover checkpoint at step 20 would resume
    # past the loop and train zero steps
    with tempfile.TemporaryDirectory(prefix="quickstart_ckpt_") as ckpt_dir:
        report = run(cfg, mesh,
                     DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=4),
                     opt_cfg=OptConfig(lr=1e-3, total_steps=20,
                                       warmup_steps=2),
                     loop_cfg=LoopConfig(total_steps=20, ckpt_every=10,
                                         ckpt_dir=ckpt_dir, log_every=5))
    print(f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f}")

    # ---- 3. serve -------------------------------------------------------
    print("== serving ==")
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, slots=2, max_seq=64)
    for i in range(4):
        engine.submit(Request(uid=i,
                              prompt=np.arange(3 + i, dtype=np.int32),
                              max_new_tokens=5))
    done = engine.run_until_drained()
    print(f"served {len(done)} requests; first output: {done[0].output}")

    # ---- 4. analyze -----------------------------------------------------
    print("== machine-level analysis (MGSim-TPU) ==")
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jax.numpy.int32),
             "targets": jax.ShapeDtypeStruct((4, 32), jax.numpy.int32)}
    compiled = jax.jit(lambda p, b: api.loss(p, cfg, b)).lower(
        jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), cfg)),
        batch).compile()
    cost = analyze(compiled.as_text())
    terms = build_terms(f"{ARCH}/quickstart", "(1,1)", 1,
                        cost_analysis_dict(compiled), cost, SINGLE_POD)
    rep = simulate(cost=cost, spec=SINGLE_POD, device_limit=1)
    print(f"flops={terms.flops_per_device:.3g} "
          f"hbm={terms.hbm_bytes_per_device:.3g}B "
          f"dominant={terms.dominant}")
    print(f"simulated step time on a v5e chip: {rep.time_s * 1e3:.3f} ms "
          f"(util {rep.compute_util:.2f})")


if __name__ == "__main__":
    main()
