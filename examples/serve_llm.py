"""Serve a small model with batched requests (deliverable b, serving).

  PYTHONPATH=src python examples/serve_llm.py

Continuous batching over a mixed request stream (variable prompt length
and output budget), with slot reuse, on the mamba2 family (O(1) decode
state — the arch built for long-context serving).
"""
import time

import jax
import numpy as np

from repro.models import api, get_config
from repro.serve import Engine, Request


def main():
    cfg = get_config("mamba2-1.3b-smoke")
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, slots=4, max_seq=96)
    rng = np.random.default_rng(7)
    n_req = 12
    for i in range(n_req):
        plen = int(rng.integers(3, 24))
        engine.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=int(rng.integers(4, 16))))
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"{len(done)}/{n_req} requests done, {toks} new tokens "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s)")
    print(f"engine stats: {engine.stats()}")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")
    assert len(done) == n_req


if __name__ == "__main__":
    main()
