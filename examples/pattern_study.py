import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
"""The paper's case study as a runnable example (deliverable b):
U-MGPU vs D-MGPU across the five collaborative-execution patterns.

  PYTHONPATH=src python examples/pattern_study.py

Prints, per workload x mode: oracle-checked correctness, cross-device
traffic from the compiled HLO, and simulated execution time on the
4-chip system model — the Fig. 9 bars in table form, plus the paper's
four design lessons evaluated against our numbers.
"""
import jax
from repro.compat import make_auto_mesh
import jax.numpy as jnp
import numpy as np


def main():
    from repro.patterns import WORKLOADS, evaluate
    mesh = make_auto_mesh((4,), ("dev",))
    sizes = {"aes": 64 * 1024, "km": 32 * 1024, "fir": 64 * 1024,
             "sc": 512, "gd": 16 * 1024, "mt": 512, "bs": 32 * 1024}
    rows = []
    with mesh:
        for name, mod in WORKLOADS.items():
            args = mod.make_args(sizes[name])
            if name == "aes":
                plain, key, rk, sb = args
                oracle = mod.reference(plain, key)
                jargs = (jnp.asarray(plain), jnp.asarray(rk),
                         jnp.asarray(sb))
            else:
                oracle = mod.reference(*args)
                jargs = tuple(jnp.asarray(a) for a in args)
            for mode, mk in [("umode", mod.make_umode),
                             ("dmode", mod.make_dmode)]:
                rows.append(evaluate(name, mod.PATTERN, mode, mk(mesh),
                                     jargs, oracle))
    print(f"{'workload':9s} {'pattern':12s} {'mode':6s} {'ok':3s} "
          f"{'traffic(B)':>12s} {'sim time':>10s}")
    for r in rows:
        print(f"{r.name:9s} {r.pattern:12s} {r.mode:6s} "
              f"{'yes' if r.correct else 'NO ':3s} "
              f"{r.collective_bytes:12.0f} {r.sim_time_s * 1e6:8.1f}us")

    by = {(r.name, r.mode): r for r in rows}
    print("\npaper lessons, evaluated:")
    print(f" 1. partitioned => zero traffic: AES D-mode "
          f"{by[('aes', 'dmode')].collective_bytes:.0f} B")
    savings = [(n, by[(n, 'umode')].collective_bytes
                - by[(n, 'dmode')].collective_bytes) for n in WORKLOADS]
    print(f" 2. explicit placement saves traffic on: "
          f"{[n for n, s in savings if s > 0]}")
    # 3. traffic <-> time correlation: compare the U-D deltas per workload
    #    (the paper's Fig. 9 claim is about the same workload under more
    #    vs less cross-device traffic, not across unlike algorithms)
    db = np.array([by[(n, 'umode')].collective_bytes
                   - by[(n, 'dmode')].collective_bytes for n in WORKLOADS])
    dt = np.array([by[(n, 'umode')].sim_time_s
                   - by[(n, 'dmode')].sim_time_s for n in WORKLOADS])
    corr = np.corrcoef(db, dt)[0, 1] if db.std() > 0 else float("nan")
    print(f" 3. corr(extra traffic, extra time) U vs D = {corr:.2f} "
          f"(paper: 'strongly correlated')")
    print(f" 4. traffic-heaviest pattern under the unified model: "
          f"{max(WORKLOADS, key=lambda n: by[(n, 'umode')].collective_bytes)}"
          f" (paper: Irregular/BS)")


if __name__ == "__main__":
    main()
