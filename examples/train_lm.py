"""End-to-end driver: train a ~100M-param qwen2-family LM for a few
hundred steps on CPU with the production code path (deliverable b).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is the qwen2-1.5b architecture scaled to ~100M params
(8 layers, d_model=512, GQA kv=2, SwiGLU, QKV bias — same family,
same code path as the full config the dry-run compiles for 256 chips).
Loss on the synthetic Markov stream should drop well below the uniform
baseline ln(V).
"""
import argparse
import math

from repro.launch.mesh import make_mesh
from repro.models import get_config
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, run
from repro.train.optim import OptConfig


def config_100m():
    return get_config("qwen2-1.5b").replace(
        name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=1536, vocab_size=8192,
        dtype="float32", attn_impl="ref", seq_shard_activations=False,
        fsdp=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = config_100m()
    n = cfg.param_count()
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    mesh = make_mesh((1, 1), ("data", "model"))
    report = run(
        cfg, mesh,
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, structure=31),
        opt_cfg=OptConfig(lr=3e-4, total_steps=args.steps,
                          warmup_steps=args.steps // 20),
        loop_cfg=LoopConfig(total_steps=args.steps, ckpt_every=100,
                            ckpt_dir="/tmp/train_lm_ckpt", log_every=20))
    uniform = math.log(cfg.vocab_size)
    print(f"uniform baseline {uniform:.3f}; "
          f"first loss {report.losses[0]:.3f}; "
          f"final loss {report.final_loss:.3f}")
    assert report.final_loss < report.losses[0]


if __name__ == "__main__":
    main()
