# Makes tools/ importable (tests and benchmarks import tools.sweep);
# every module here remains runnable as a plain script too.
