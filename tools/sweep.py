"""Fleet-scale design-space exploration: sweep grids across worker processes.

The simulator used to answer one question per process; this driver
turns it into the throughput product the ROADMAP names: a declarative
**scenario x topology x scheduler x fabric x fault-plan** grid fanned
across *long-lived* worker processes.  Each worker simulates many
independent configs, so process startup (interpreter + imports) is
amortized across the whole sweep -- sidestepping the per-round
message-passing wall that caps the ``procs`` executor on weak hosts
(``BENCH_fabric.json`` ``replay_procs``): independent sims need no
mid-run IPC at all.

Two cache tiers make repeat sweeps cheap:

* **plan cache** (``repro.fabric.plancache``): decomposed collective
  plans are content-hashed and shared through an on-disk directory, so
  every worker -- and every *rerun* -- skips ``decompose()`` for plans
  it has already seen (hit rate reported per sweep);
* **result cache**: each config's row is keyed by a content hash of
  the full config; a repeat run against the same results file skips
  configs that already have rows (``--force`` re-simulates).

Results merge-write into one queryable JSON (the BENCH merge-write
idiom generalized): ``{"meta": ..., "rows": {config_id: row}}``.

Usage::

  PYTHONPATH=src python tools/sweep.py run --grid quick --workers 4
  PYTHONPATH=src python tools/sweep.py run --grid my_grid.json
  PYTHONPATH=src python tools/sweep.py query fabric=event scheduler=serial \\
      --select scenario,topology,time_s,wall_s
  PYTHONPATH=src python tools/sweep.py grids     # list axes + presets

A grid JSON names values for each axis (omitted axes take the quick
preset's defaults)::

  {"scenario": ["allreduce_ladder", "moe_alltoall"],
   "topology": ["pod4x4", "pod4x4x2"],
   "scheduler": ["serial"],
   "fabric": ["analytic", "event"],
   "faults": ["none", "straggler_chip"],
   "sim": {"device_limit": 16, "repeat_cap": 8}}
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import multiprocessing
import os
import signal
import sys
import time
import typing

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(REPO, "src") not in sys.path:
    sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import SystemSpec, simulate               # noqa: E402
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp  # noqa: E402
from repro.core.hw import ChipSpec                        # noqa: E402
from repro.fabric import plancache                        # noqa: E402
from repro.serve import sim as serve_sim                  # noqa: E402


# --------------------------------------------------------------------------
# grid axes: scenarios, topologies, fault plans
# --------------------------------------------------------------------------

def _coll(cost: HloCost, kind: str, name: str, nbytes: float,
          groups: typing.List[typing.List[int]]) -> None:
    rec = CollectiveRecord(kind, name, int(nbytes), int(nbytes),
                           int(nbytes), groups)
    cost.collectives.append(rec)
    cost.trace.append(TraceOp("collective", name, collective=rec))


def _compute(cost: HloCost, name: str, flops: float, hbm: float) -> None:
    cost.trace.append(TraceOp("compute", name, flops=flops, hbm_bytes=hbm))


def _rows(spec: SystemSpec) -> typing.List[typing.List[int]]:
    Y, X = spec.pod_shape
    return [[p * spec.chips_per_pod + y * X + x for x in range(X)]
            for p in range(spec.num_pods) for y in range(Y)]


def scenario_allreduce_ladder(spec: SystemSpec, layers: int = 8) -> HloCost:
    """Data-parallel ladder: compute segment + global all-reduce, the
    MGMark AES-analog shape (compute-heavy with periodic sync)."""
    cost = HloCost()
    groups = [list(range(spec.total_chips))]
    for i in range(layers):
        _compute(cost, f"seg{i}", 4e9, 1e8)
        _coll(cost, "all-reduce", f"ar{i}", 1e6, groups)
    return cost


def scenario_ring_exchange(spec: SystemSpec, layers: int = 6) -> HloCost:
    """Model-parallel rows: per-x-ring all-gather + reduce-scatter, with
    per-row groups -- exercises the ring formulas and, on the event
    fabric, every chip's own ICI links."""
    cost = HloCost()
    rows = _rows(spec)
    for i in range(layers):
        _compute(cost, f"mm{i}", 2e9, 5e7)
        _coll(cost, "all-gather", f"ag{i}", 2e6, rows)
        _coll(cost, "reduce-scatter", f"rs{i}", 2e6, rows)
    return cost


def scenario_moe_alltoall(spec: SystemSpec, layers: int = 6) -> HloCost:
    """MoE dispatch/combine: all-to-all over 2-D blocks (one per pod),
    bisection-limited -- plus a closing global all-reduce."""
    cost = HloCost()
    pods = [list(range(p * spec.chips_per_pod,
                       (p + 1) * spec.chips_per_pod))
            for p in range(spec.num_pods)]
    for i in range(layers):
        _compute(cost, f"expert{i}", 3e9, 8e7)
        _coll(cost, "all-to-all", f"dispatch{i}", 4e6, pods)
        _coll(cost, "all-to-all", f"combine{i}", 4e6, pods)
    _coll(cost, "all-reduce", "grad_sync", 1e6,
          [list(range(spec.total_chips))])
    return cost


def scenario_cross_pod_sync(spec: SystemSpec,
                            layers: int = 6) -> typing.Optional[HloCost]:
    """Pod-axis data parallelism: per-chip cross-pod all-reduce pairs
    sharing the DCN uplinks (the paper's D-MGPU traffic shape).  Only
    meaningful with >= 2 pods -- returns None (skip) otherwise."""
    if spec.num_pods < 2:
        return None
    cost = HloCost()
    cpp = spec.chips_per_pod
    pairs = [[k + p * cpp for p in range(spec.num_pods)] for k in range(cpp)]
    for i in range(layers):
        _compute(cost, f"step{i}", 5e9, 1e8)
        _coll(cost, "all-reduce", f"dcn_ar{i}", 8e6, pairs)
    return cost


def scenario_multi_tenant(spec: SystemSpec, layers: int = 5) -> HloCost:
    """Two tenants on disjoint halves of each pod, both running ring
    all-reduces plus a permute pipeline -- disjoint groups in one trace,
    so the event fabric sees concurrent tenants on neighboring links."""
    cost = HloCost()
    rows = _rows(spec)
    half = len(rows) // 2 or 1
    a, b = rows[:half], rows[half:] or rows[:half]
    for i in range(layers):
        _compute(cost, f"t{i}", 2.5e9 * (1.0 + 0.37 * (i % 2)), 6e7)
        _coll(cost, "all-reduce", f"tenantA_ar{i}", 2e6, a)
        _coll(cost, "all-reduce", f"tenantB_ar{i}", 1.5e6, b)
        _coll(cost, "collective-permute", f"pipe{i}", 5e5,
              [rows[0][:2]])
    return cost


# -- serving scenarios (open-loop traces; see docs/serving.md) -------------
# These return a ServingScenario instead of an HloCost; run_config
# dispatches them to repro.serve.sim.run_serving, and their rows carry
# p50/p99/goodput next to the shared columns.  None = can't host the
# tenants on this topology (skipped at grid expansion, same contract).

def scenario_serving_poisson(spec: SystemSpec):
    """Two tenants, steady Poisson arrivals below the saturation knee."""
    return serve_sim.build_scenario(spec, name="serving_poisson",
                                    arrival="poisson", rate_rps=600.0,
                                    duration_s=0.02, seed=11)


def scenario_serving_overload(spec: SystemSpec):
    """Same shape offered well past the knee: queue-dominated latency."""
    return serve_sim.build_scenario(spec, name="serving_overload",
                                    arrival="poisson", rate_rps=4000.0,
                                    duration_s=0.02, seed=11)


def scenario_serving_burst(spec: SystemSpec):
    """MMPP bursts: calm/burst states stress admission + slot reuse."""
    return serve_sim.build_scenario(spec, name="serving_burst",
                                    arrival="bursty", rate_rps=600.0,
                                    duration_s=0.02, seed=11)


def scenario_serving_diurnal(spec: SystemSpec):
    """Sinusoidal rate swing (day/night) over the trace window."""
    return serve_sim.build_scenario(spec, name="serving_diurnal",
                                    arrival="diurnal", rate_rps=600.0,
                                    duration_s=0.02, seed=11)


def scenario_serving_moe(spec: SystemSpec):
    """MoE tenants: per-iteration a2a dispatch/combine rides the shared
    bisection channel, the multi-tenant contention the event fabric
    prices and analytic can't."""
    return serve_sim.build_scenario(spec, name="serving_moe",
                                    arrival="poisson", rate_rps=600.0,
                                    duration_s=0.02, seed=11, moe=True)


def scenario_serving_spare(spec: SystemSpec):
    """The poisson pair plus one reserved spare chip: a chip-kill plan
    exercises spare claim + KV migration (docs/faults.md "Spare pool,
    migration & quorum").  None when no chip is left over."""
    return serve_sim.build_scenario(spec, name="serving_spare",
                                    arrival="poisson", rate_rps=600.0,
                                    duration_s=0.02, seed=11, spares=1)


def scenario_serving_spare2(spec: SystemSpec):
    """Two shared spares: survives a double kill at full capacity."""
    return serve_sim.build_scenario(spec, name="serving_spare2",
                                    arrival="poisson", rate_rps=600.0,
                                    duration_s=0.02, seed=11, spares=2)


SCENARIOS = {
    "allreduce_ladder": scenario_allreduce_ladder,
    "ring_exchange": scenario_ring_exchange,
    "moe_alltoall": scenario_moe_alltoall,
    "cross_pod_sync": scenario_cross_pod_sync,
    "multi_tenant": scenario_multi_tenant,
    "serving_poisson": scenario_serving_poisson,
    "serving_overload": scenario_serving_overload,
    "serving_burst": scenario_serving_burst,
    "serving_diurnal": scenario_serving_diurnal,
    "serving_moe": scenario_serving_moe,
    "serving_spare": scenario_serving_spare,
    "serving_spare2": scenario_serving_spare2,
}


def _chip(**kw) -> ChipSpec:
    return dataclasses.replace(ChipSpec(), **kw)


TOPOLOGIES = {
    "pod2x2": lambda: SystemSpec(pod_shape=(2, 2)),
    "pod2x2x2": lambda: SystemSpec(pod_shape=(2, 2), num_pods=2),
    "pod4x4": lambda: SystemSpec(pod_shape=(4, 4)),
    "pod4x4x2": lambda: SystemSpec(pod_shape=(4, 4), num_pods=2),
    "pod8x8": lambda: SystemSpec(pod_shape=(8, 8)),
    "pod8x8x2": lambda: SystemSpec(pod_shape=(8, 8), num_pods=2),
    "pod4x4_slow_ici": lambda: SystemSpec(
        pod_shape=(4, 4), chip=_chip(ici_link_bandwidth=25e9)),
    "pod4x4x2_fat_dcn": lambda: SystemSpec(
        pod_shape=(4, 4), num_pods=2, dcn_bandwidth_per_pod=3.2e12),
}

SCHEDULERS = ("serial", "batch", "lookahead", "bounded")
FABRICS = ("analytic", "event")


def _faults_none(spec, fabric):
    return {}


def _faults_straggler_chip(spec, fabric):
    return {"chip0.core": [(0.0, "slow", 2.0)]}


def _faults_slow_link(spec, fabric):
    if fabric != "event":
        return None                      # link targets need the event fabric
    return {"fabric.pod0.ici[0,0]+x": [(0.0, "slow", 4.0)]}


def _faults_transient_link(spec, fabric):
    if fabric != "event":
        return None
    return {"fabric.pod0.ici[0,0]+x": [(1e-4, "transient", 2e-4)]}


def _faults_chip_kill(spec, fabric):
    """Permanent chip death mid-trace.  Pair with ``sim.deadline_s`` (and
    ``sim.recovery`` for serving scenarios, as the ``serving_recovery``
    grid does) so the death surfaces as collective timeouts instead of a
    stall bounded only by the per-config timeout."""
    return {"chip1.prog": [(5e-3, "fail", None)]}


def _faults_chip_kill_rejoin(spec, fabric):
    """Chip death + rolling-restart rejoin inside the serving window:
    the recovered chip re-registers and its tenant re-meshes back up."""
    return {"chip1.prog": [(5e-3, "fail", None), (1.2e-2, "recover", None)]}


def _faults_double_kill(spec, fabric):
    """A second chip dies while the first failure is still recovering:
    the stateful-failover stress case (spares drain one by one)."""
    return {"chip1.prog": [(5e-3, "fail", None)],
            "chip2.prog": [(8e-3, "fail", None)]}


def _faults_spare_kill(spec, fabric):
    """Kill chip1, then kill the spare (chip4) its tenant claimed.  Only
    meaningful where chip4 exists and is the first pool spare."""
    if spec.total_chips <= 4:
        return None
    return {"chip1.prog": [(5e-3, "fail", None)],
            "chip4.prog": [(9e-3, "fail", None)]}


FAULT_PLANS = {
    "none": _faults_none,
    "straggler_chip": _faults_straggler_chip,
    "slow_link": _faults_slow_link,
    "transient_link": _faults_transient_link,
    "chip_kill": _faults_chip_kill,
    "chip_kill_rejoin": _faults_chip_kill_rejoin,
    "double_kill": _faults_double_kill,
    "spare_kill": _faults_spare_kill,
}


# Named recovery-policy presets (the "policy" grid axis).  "default"
# adds no config key, so pre-existing grids keep their config hashes.
POLICY_PRESETS = {
    "default": {},
    "quorum1": {"quorum": 1},
    "quorum2": {"quorum": 2},
    "quorum3": {"quorum": 3},
    "no_backoff_cap": {"backoff_max_s": None},
}


GRIDS = {
    # CI smoke: small but crosses every axis (including an invalid
    # combo -- slow_link x analytic -- that must be *skipped*, not die)
    "quick": {
        "scenario": ["allreduce_ladder", "ring_exchange"],
        "topology": ["pod2x2", "pod4x4"],
        "scheduler": ["serial"],
        "fabric": ["analytic", "event"],
        "faults": ["none", "slow_link"],
        "sim": {"device_limit": None, "repeat_cap": 4},
    },
    # offered load x topology x scheduler x fabric x fault for the
    # open-loop serving scenarios (docs/serving.md)
    "serving": {
        "scenario": ["serving_poisson", "serving_overload", "serving_burst",
                     "serving_diurnal", "serving_moe"],
        "topology": ["pod2x2", "pod4x4"],
        "scheduler": ["serial", "bounded"],
        "fabric": ["analytic", "event"],
        "faults": ["none", "slow_link", "straggler_chip"],
        "sim": {"device_limit": None, "repeat_cap": 4},
    },
    # serve-through-faults: chip kill / kill+rejoin against the recovery
    # layer (docs/faults.md "Detection & recovery"); sim carries the
    # deadline + recovery policy that run_serving needs
    "serving_recovery": {
        "scenario": ["serving_poisson", "serving_moe"],
        "topology": ["pod2x2"],
        "scheduler": ["serial", "bounded"],
        "fabric": ["analytic", "event"],
        "faults": ["none", "chip_kill", "chip_kill_rejoin"],
        "sim": {"device_limit": None, "repeat_cap": 4,
                "deadline_s": 5e-4, "recovery": True},
    },
    # stateful failover: spares x quorum x kill plans on a topology with
    # room for a shared pool; rows carry migrated_bytes / spare_claims /
    # effective availability (docs/faults.md "Spare pool, migration &
    # quorum")
    "serving_spare": {
        "scenario": ["serving_poisson", "serving_spare", "serving_spare2"],
        "topology": ["pod2x2x2"],
        "scheduler": ["serial", "bounded"],
        "fabric": ["analytic", "event"],
        "faults": ["chip_kill", "chip_kill_rejoin", "double_kill",
                   "spare_kill"],
        "policy": ["default", "quorum2"],
        "sim": {"device_limit": None, "repeat_cap": 4,
                "deadline_s": 5e-4, "recovery": True},
    },
    # the fleet sweep: thousands of scenario points per CI run is the
    # point, but the checked-in preset stays tractable on one host
    "full": {
        "scenario": sorted(SCENARIOS),
        "topology": ["pod2x2", "pod4x4", "pod4x4x2", "pod4x4_slow_ici",
                     "pod4x4x2_fat_dcn"],
        "scheduler": ["serial", "lookahead"],
        "fabric": ["analytic", "event"],
        "faults": ["none", "straggler_chip", "slow_link"],
        "sim": {"device_limit": None, "repeat_cap": 4},
    },
}


# --------------------------------------------------------------------------
# grid expansion + config hashing
# --------------------------------------------------------------------------

def expand_grid(grid: dict) -> typing.List[dict]:
    """Cross the axes into config dicts, each with a content-hashed id.

    Unknown axis values fail here -- before any worker spins up -- and
    invalid combinations (a fault plan that needs the event fabric
    paired with analytic; a cross-pod scenario on a single-pod
    topology) are *not* expanded: they are structurally impossible
    runs, counted by the caller via the returned list's length vs the
    raw product.
    """
    spec = {**GRIDS["quick"], **grid}
    sim = {**GRIDS["quick"]["sim"], **(grid.get("sim") or {})}
    policies = list(spec.get("policy") or ["default"])
    for axis, known in (("scenario", SCENARIOS), ("topology", TOPOLOGIES),
                        ("scheduler", SCHEDULERS), ("fabric", FABRICS),
                        ("faults", FAULT_PLANS)):
        unknown = set(spec[axis]) - set(known)
        if unknown:
            raise ValueError(f"unknown {axis} values {sorted(unknown)}; "
                             f"known: {sorted(known)}")
    unknown = set(policies) - set(POLICY_PRESETS)
    if unknown:
        raise ValueError(f"unknown policy values {sorted(unknown)}; "
                         f"known: {sorted(POLICY_PRESETS)}")
    configs = []
    for scen in spec["scenario"]:
        for topo in spec["topology"]:
            sys_spec = TOPOLOGIES[topo]()
            if SCENARIOS[scen](sys_spec) is None:
                continue                      # scenario can't run here
            for sched in spec["scheduler"]:
                for fabric in spec["fabric"]:
                    for fault in spec["faults"]:
                        if FAULT_PLANS[fault](sys_spec, fabric) is None:
                            continue          # plan needs another fabric
                        for pol in policies:
                            cfg = {"scenario": scen, "topology": topo,
                                   "scheduler": sched, "fabric": fabric,
                                   "faults": fault, "sim": dict(sim)}
                            if pol != "default":
                                # "default" adds no key, so grids that
                                # predate the axis keep their hashes
                                cfg["policy"] = pol
                            cfg["config_id"] = config_id(cfg)
                            configs.append(cfg)
    return configs


def config_id(cfg: dict) -> str:
    """Content hash of one config -- the result-cache key."""
    blob = json.dumps({k: v for k, v in cfg.items() if k != "config_id"},
                      sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def grid_size(grid: dict) -> int:
    spec = {**GRIDS["quick"], **grid}
    n = 1
    for axis in ("scenario", "topology", "scheduler", "fabric", "faults"):
        n *= len(spec[axis])
    return n * len(spec.get("policy") or ["default"])


# --------------------------------------------------------------------------
# per-config execution (runs inside workers)
# --------------------------------------------------------------------------

_scenario_memo: dict = {}      # (scenario, topology) -> HloCost, per process


def run_config(cfg: dict) -> dict:
    """Simulate one config; returns its result row.  Pure function of
    the config (plus the read-only plan cache), so workers need no
    coordination."""
    spec = TOPOLOGIES[cfg["topology"]]()
    memo_key = (cfg["scenario"], cfg["topology"])
    cost = _scenario_memo.get(memo_key)
    if cost is None:
        cost = _scenario_memo[memo_key] = SCENARIOS[cfg["scenario"]](spec)
    faults = FAULT_PLANS[cfg["faults"]](spec, cfg["fabric"])
    before = plancache.stats()
    t0 = time.perf_counter()
    if isinstance(cost, serve_sim.ServingScenario):
        pol_name = cfg.get("policy", "default")
        recovery = cfg["sim"].get("recovery")
        if recovery and pol_name != "default":
            recovery = serve_sim.RecoveryPolicy(**POLICY_PRESETS[pol_name])
        rep = serve_sim.run_serving(cost, spec=spec,
                                    scheduler=cfg["scheduler"],
                                    fabric=cfg["fabric"],
                                    faults=faults or None,
                                    deadline_s=cfg["sim"].get("deadline_s"),
                                    recovery=recovery)
        wall = time.perf_counter() - t0
        after = plancache.stats()
        return {
            **{k: cfg[k] for k in ("config_id", "scenario", "topology",
                                   "scheduler", "fabric", "faults")},
            "policy": pol_name,
            "time_s": rep.time_s,
            "wall_s": round(wall, 4),
            "events": rep.events,
            "devices": rep.devices,
            "collectives_completed": rep.collectives_completed,
            "collective_timeouts": rep.collective_timeouts,
            "compute_util": round(rep.compute_util, 4),
            "offered": rep.offered,
            "completed": rep.completed,
            "offered_rps": round(rep.offered_rps, 2),
            "goodput_rps": round(rep.goodput_rps, 2),
            "p50_s": rep.p50_s,
            "p99_s": rep.p99_s,
            "queue_mean_s": rep.queue_mean_s,
            "retries": rep.retries,
            "dropped": rep.dropped,
            "recoveries": rep.recoveries,
            "rejoins": rep.rejoins,
            "chip_deaths": rep.chip_deaths,
            "tenant_availability": rep.tenant_availability,
            "tenant_effective_availability":
                rep.tenant_effective_availability,
            "spare_claims": rep.spare_claims,
            "spare_returns": rep.spare_returns,
            "migrated_bytes": rep.migrated_bytes,
            "prefill_saved_tokens": rep.prefill_saved_tokens,
            "prefill_recompute_tokens": rep.prefill_recompute_tokens,
            "plan_lookups": after["lookups"] - before["lookups"],
            "plan_misses": after["misses"] - before["misses"],
        }
    rep = simulate(cost=cost, spec=spec, scheduler=cfg["scheduler"],
                   fabric=cfg["fabric"], faults=faults or None,
                   device_limit=cfg["sim"].get("device_limit"),
                   repeat_cap=cfg["sim"].get("repeat_cap", 64),
                   deadline_s=cfg["sim"].get("deadline_s"))
    wall = time.perf_counter() - t0
    after = plancache.stats()
    return {
        **{k: cfg[k] for k in ("config_id", "scenario", "topology",
                               "scheduler", "fabric", "faults")},
        "time_s": rep.time_s,
        "wall_s": round(wall, 4),
        "events": rep.events,
        "devices": rep.devices,
        "collectives_completed": rep.collectives_completed,
        "collective_timeouts": rep.collective_timeouts,
        "compute_util": round(rep.compute_util, 4),
        "plan_lookups": after["lookups"] - before["lookups"],
        "plan_misses": after["misses"] - before["misses"],
    }


_CFG_TIMEOUT: typing.Optional[float] = None   # per-config wall budget (s)


class _ConfigTimeout(Exception):
    """One config exceeded its wall-clock budget (raised from SIGALRM)."""


def _on_alarm(signum, frame):
    raise _ConfigTimeout()


def _configure_timeout(timeout_s: typing.Optional[float]) -> None:
    global _CFG_TIMEOUT
    _CFG_TIMEOUT = timeout_s
    if timeout_s and hasattr(signal, "SIGALRM"):
        signal.signal(signal.SIGALRM, _on_alarm)


def _worker_init(cache_dir: typing.Optional[str],
                 config_timeout_s: typing.Optional[float] = None) -> None:
    plancache.configure(cache_dir)
    plancache.reset_stats()
    _configure_timeout(config_timeout_s)


def _run_one(cfg: dict) -> dict:
    """Run one config under a wall-clock budget, with one retry.

    ``_run_one`` has always caught exceptions (one bad config != dead
    sweep), but a *wedged* simulation -- a fault plan that stalls the
    event loop with no deadline to cut it -- used to hang its worker and
    with it the whole pool.  With a configured ``config_timeout_s`` each
    attempt runs under a SIGALRM itimer: the first timeout gets one
    retry (transient host stalls deserve a second chance and the memo /
    plan caches are warm now), the second yields an error row so the
    sweep always completes.  Every row records ``attempts``.
    """
    base = {k: cfg[k] for k in ("config_id", "scenario", "topology",
                                "scheduler", "fabric", "faults")}
    timed_out = None
    for attempt in (1, 2):
        armed = bool(_CFG_TIMEOUT) and hasattr(signal, "SIGALRM")
        try:
            if armed:
                signal.setitimer(signal.ITIMER_REAL, _CFG_TIMEOUT)
            row = run_config(cfg)
            row["attempts"] = attempt
            return row
        except _ConfigTimeout:
            timed_out = (f"_ConfigTimeout: exceeded "
                         f"{_CFG_TIMEOUT}s (attempt {attempt})")
        except Exception as e:                # one bad config != dead sweep
            return {**base, "attempts": attempt,
                    "error": f"{type(e).__name__}: {e}"}
        finally:
            if armed:
                signal.setitimer(signal.ITIMER_REAL, 0.0)
    return {**base, "attempts": 2, "error": timed_out}


# --------------------------------------------------------------------------
# results file: merge-write + query
# --------------------------------------------------------------------------

def load_results(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"meta": {}, "rows": {}}


def merge_results(path: str, rows: typing.List[dict], meta: dict) -> dict:
    """Read-merge-write (the BENCH_*.json idiom): concurrent sweeps over
    different grids may share one results file; neither clobbers the
    other's rows."""
    data = load_results(path)
    for row in rows:
        data["rows"][row["config_id"]] = row
    data["meta"].update(meta)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return data


def query_rows(data: dict, where: dict = None,
               select: typing.List[str] = None) -> typing.List[dict]:
    """Filter result rows by exact field match; optionally project.
    Values compare as strings so CLI ``key=value`` tokens work for
    numeric fields too."""
    out = []
    for row in sorted(data.get("rows", {}).values(),
                      key=lambda r: r["config_id"]):
        if where and any(str(row.get(k)) != str(v)
                         for k, v in where.items()):
            continue
        out.append({k: row.get(k) for k in select} if select else row)
    return out


# --------------------------------------------------------------------------
# the sweep itself
# --------------------------------------------------------------------------

def run_sweep(grid: dict, out: str, workers: int = None,
              cache_dir: str = None, force: bool = False,
              quiet: bool = False,
              config_timeout_s: float = None) -> dict:
    """Expand, fan out, merge-write.  Returns the sweep stats dict
    (also merged into the results file's ``meta``).

    ``workers=0`` runs inline (no pool) -- for tests and tiny grids;
    ``workers=None`` picks ``os.cpu_count()``.  Workers are long-lived:
    one pool serves the entire grid.

    ``config_timeout_s`` bounds each config's wall time (SIGALRM, so
    inline and forked workers alike): first breach retries once, second
    writes an error row -- a wedged simulation can no longer hang the
    sweep.  ``None`` (default) keeps the old unbounded behavior.
    """
    t_start = time.perf_counter()
    _configure_timeout(config_timeout_s)
    configs = expand_grid(grid)
    raw = grid_size(grid)
    existing = load_results(out)["rows"] if not force else {}
    todo = [c for c in configs if c["config_id"] not in existing]
    cached = len(configs) - len(todo)
    if workers is None:
        workers = os.cpu_count() or 1
    plancache.reset_stats()
    if cache_dir:
        plancache.configure(cache_dir)
    rows: typing.List[dict] = []
    if todo:
        if workers <= 0 or len(todo) == 1:
            rows = [_run_one(c) for c in todo]
            pstats = plancache.stats()
        else:
            ctx = multiprocessing.get_context("fork")
            with ctx.Pool(processes=min(workers, len(todo)),
                          initializer=_worker_init,
                          initargs=(cache_dir, config_timeout_s)) as pool:
                rows = list(pool.imap_unordered(_run_one, todo, chunksize=1))
            # workers are gone; their plan-cache traffic survives in the
            # per-row counters
            pstats = {"lookups": sum(r.get("plan_lookups", 0) for r in rows),
                      "misses": sum(r.get("plan_misses", 0) for r in rows)}
            pstats["hits"] = pstats["lookups"] - pstats["misses"]
            pstats["hit_rate"] = (pstats["hits"] / pstats["lookups"]
                                  if pstats["lookups"] else 0.0)
    else:
        pstats = plancache.stats()
    errors = [r for r in rows if "error" in r]
    wall = time.perf_counter() - t_start
    stats = {
        "grid_points": len(configs),
        "grid_points_raw": raw,
        "skipped_invalid": raw - len(configs),
        "simulated": len(todo),
        "result_cache_hits": cached,
        "errors": len(errors),
        "wall_s": round(wall, 3),
        "configs_per_sec": round(len(configs) / wall, 2),
        "workers": workers,
        "plan_cache_lookups": pstats["lookups"],
        "plan_cache_hit_rate": round(pstats["hit_rate"], 4),
        "written_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    merge_results(out, rows, stats)
    if not quiet:
        print(f"# sweep: {stats['grid_points']} grid points "
              f"({stats['skipped_invalid']} invalid combos skipped), "
              f"{stats['simulated']} simulated / "
              f"{stats['result_cache_hits']} cached rows, "
              f"{stats['errors']} errors, {wall:.2f}s "
              f"({stats['configs_per_sec']:.1f} configs/s, "
              f"{workers} workers, plan-cache hit rate "
              f"{stats['plan_cache_hit_rate']:.2f})")
        for r in errors[:5]:
            print(f"#   ERROR {r['config_id']}: {r['error']}")
        print(f"# results -> {out}")
    return stats


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _load_grid(name_or_path: str) -> dict:
    if name_or_path in GRIDS:
        return GRIDS[name_or_path]
    with open(name_or_path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    run_p = sub.add_parser("run", help="expand a grid and sweep it")
    run_p.add_argument("--grid", default="quick",
                       help="preset name (%s) or a grid JSON path"
                            % "/".join(sorted(GRIDS)))
    run_p.add_argument("--out", default="sweep_results.json")
    run_p.add_argument("--workers", type=int, default=None,
                       help="worker processes (0 = inline; "
                            "default: cpu count)")
    run_p.add_argument("--cache-dir", default=".sweep_cache",
                       help="plan-cache directory shared by workers "
                            "('' disables the disk tier)")
    run_p.add_argument("--force", action="store_true",
                       help="re-simulate configs already in the results")
    run_p.add_argument("--timeout", type=float, default=None,
                       help="per-config wall budget in seconds (one "
                            "retry, then an error row; default: none)")

    q_p = sub.add_parser("query", help="filter merged sweep results")
    q_p.add_argument("filters", nargs="*",
                     help="key=value exact-match filters")
    q_p.add_argument("--results", default="sweep_results.json")
    q_p.add_argument("--select", default=None,
                     help="comma-separated fields to project")

    sub.add_parser("grids", help="list axes and grid presets")

    args = ap.parse_args(argv)
    if hasattr(signal, "SIGPIPE"):      # `sweep.py query | head` etc.
        signal.signal(signal.SIGPIPE, signal.SIG_DFL)
    if args.cmd == "grids":
        print(json.dumps({"scenarios": sorted(SCENARIOS),
                          "topologies": sorted(TOPOLOGIES),
                          "schedulers": list(SCHEDULERS),
                          "fabrics": list(FABRICS),
                          "fault_plans": sorted(FAULT_PLANS),
                          "policies": sorted(POLICY_PRESETS),
                          "grids": GRIDS}, indent=2))
        return 0
    if args.cmd == "query":
        where = dict(tok.split("=", 1) for tok in args.filters)
        select = args.select.split(",") if args.select else None
        rows = query_rows(load_results(args.results), where, select)
        print(json.dumps(rows, indent=2, sort_keys=True))
        return 0
    stats = run_sweep(_load_grid(args.grid), out=args.out,
                      workers=args.workers,
                      cache_dir=args.cache_dir or None, force=args.force,
                      config_timeout_s=args.timeout)
    return 1 if stats["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
