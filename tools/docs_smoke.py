"""Docs smoke check — keeps README.md and docs/*.md from rotting.

Three checks, exit nonzero on any failure:

1. every relative markdown link in README.md and docs/*.md resolves to
   a file that exists (anchors and external URLs are skipped);
2. every ```python code block parses, and its top-level import
   statements execute (so renamed/removed APIs break CI, not readers);
3. README.md python blocks are additionally *executed in full* — the
   quickstart must actually run, not just import.

Run as: PYTHONPATH=src python tools/docs_smoke.py
(CI runs it next to examples/quickstart.py.)
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys
import traceback

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

CODE_RE = re.compile(r"```python\n(.*?)```", re.S)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def doc_files():
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def check_links(path: pathlib.Path, text: str, errors: list) -> int:
    n = 0
    for target in LINK_RE.findall(text):
        target = target.split("#", 1)[0].strip()
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        n += 1
        if not (path.parent / target).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    return n


def check_code(path: pathlib.Path, text: str, errors: list,
               run_full: bool = False) -> int:
    n = 0
    for i, block in enumerate(CODE_RE.findall(text)):
        n += 1
        where = f"{path.relative_to(ROOT)} python block #{i + 1}"
        try:
            tree = ast.parse(block)
        except SyntaxError as e:
            errors.append(f"{where}: syntax error: {e}")
            continue
        try:
            if run_full:
                exec(compile(tree, where, "exec"), {"__name__": "__docs__"})
            else:
                for node in tree.body:
                    if isinstance(node, (ast.Import, ast.ImportFrom)):
                        mod = ast.Module(body=[node], type_ignores=[])
                        exec(compile(mod, where, "exec"), {})
        except Exception:
            errors.append(f"{where}: {'execution' if run_full else 'import'}"
                          f" failed:\n{traceback.format_exc(limit=3)}")
    return n


def main() -> int:
    errors: list = []
    links = blocks = 0
    for path in doc_files():
        text = path.read_text()
        links += check_links(path, text, errors)
        blocks += check_code(path, text, errors,
                             run_full=path.name == "README.md")
    print(f"docs_smoke: {len(doc_files())} files, {links} relative links, "
          f"{blocks} python blocks checked")
    for e in errors:
        print(f"FAIL {e}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
