"""Hot-path profiling harness for the event engine.

Replays the multi-tenant event-fabric trace from
``benchmarks/fabric_contention.py`` (the densest event producer in the
repo) under any scheduler and prints either

* a timeit-style throughput summary (default), or
* a cProfile per-function hot-path table (``--profile``),

so perf PRs have a one-command, apples-to-apples baseline:

    python tools/profile_engine.py                      # serial throughput
    python tools/profile_engine.py --scheduler bounded --workers 4
    python tools/profile_engine.py --scheduler lookahead --executor procs
    python tools/profile_engine.py --profile --sort tottime --limit 25
    python tools/profile_engine.py --all                # every scheduler
    python tools/profile_engine.py --ipc                # pipe vs ring RTT

(``--profile`` with ``--executor procs`` profiles only the parent's
routing/commit side -- handlers run in the shard workers; profile them
under threads, where execution is in-process.)

Wall-clock numbers here are what ``BENCH_fabric.json``'s ``replay``
section tracks; the per-function table is what tells you *which* layer
(queue, dispatch, handlers, commit) to attack next.

``--ipc`` measures the procs executor's two transports head-to-head --
``multiprocessing.Pipe`` vs the shared-memory SPSC ring of
:mod:`repro.core.engine.executor.rings` -- and folds the round-trip
times into the ``machine_calibration`` block of ``BENCH_fabric.json``
so perf gates can adapt to the host.
"""
from __future__ import annotations

import argparse
import cProfile
import io
import os
import pstats
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.fabric_contention import SPEC, _tenant_ops  # noqa: E402
from repro.core import System  # noqa: E402


def build_system(scheduler: str, workers: int, tenants: int, rounds: int,
                 executor: str = None):
    system = System(SPEC, fabric="event", scheduler=scheduler,
                    max_workers=workers, executor=executor)
    for tid in range(tenants):
        ops, devs = _tenant_ops(tid, rounds)
        system.load_trace(ops, devs)
    return system


def run_once(args, scheduler: str) -> dict:
    executor = args.executor if scheduler != "serial" else None
    system = build_system(scheduler, args.workers, args.tenants, args.rounds,
                          executor=executor)
    t0 = time.perf_counter()
    system.run()
    wall = time.perf_counter() - t0
    eng = system.engine
    return {"scheduler": scheduler, "executor": executor or "-",
            "wall_s": wall, "events": eng.events_processed,
            "events_per_sec": eng.events_processed / wall if wall else 0.0,
            "rounds": len(eng.window_widths or eng.batch_widths)}


def print_row(r: dict) -> None:
    print(f"{r['scheduler']:>10}/{r['executor']:<7}  {r['wall_s']*1e3:9.1f} ms  "
          f"{r['events']:7d} events  {r['events_per_sec']:10.0f} ev/s  "
          f"{r['rounds']:6d} rounds")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="profile the engine over the event-fabric replay trace")
    ap.add_argument("--scheduler", default="serial",
                    choices=("serial", "batch", "lookahead", "bounded"))
    ap.add_argument("--executor", default=None,
                    choices=("threads", "procs"),
                    help="executor backend for round schedulers "
                         "(default: threads; ignored for serial)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6,
                    help="trace rounds per tenant (trace length)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timing repetitions (best is reported)")
    ap.add_argument("--all", action="store_true",
                    help="time every scheduler instead of --scheduler")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile one run and print the hot-path table")
    ap.add_argument("--ipc", action="store_true",
                    help="microbenchmark pipe vs shared-memory-ring RTT "
                         "and fold the numbers into BENCH_fabric.json's "
                         "machine_calibration block")
    ap.add_argument("--ipc-n", type=int, default=2000,
                    help="round trips per IPC transport measurement")
    ap.add_argument("--sort", default="cumulative",
                    choices=("cumulative", "tottime", "ncalls"),
                    help="cProfile sort column")
    ap.add_argument("--limit", type=int, default=30,
                    help="rows of the cProfile table")
    args = ap.parse_args(argv)

    if args.ipc:
        from benchmarks.fabric_contention import merge_bench
        from repro.core.engine.executor import rings
        pipe = rings.pipe_rtt_us(reps=args.ipc_n)
        ring = rings.ring_rtt_us(reps=args.ipc_n)
        cal = {"pipe_rtt_us": round(pipe, 1) if pipe == pipe else None,
               "ring_rtt_us": round(ring, 1) if ring == ring else None,
               "ipc_reps": args.ipc_n, "cpu_count": os.cpu_count()}
        print(f"# pipe rtt: {cal['pipe_rtt_us']}us   "
              f"ring rtt: {cal['ring_rtt_us']}us   "
              f"({args.ipc_n} round trips, 256B frames, "
              f"{cal['cpu_count']} cpus)")
        if cal["ring_rtt_us"] is None:
            print("# shared-memory rings unavailable on this host "
                  "(no fork or no shared_memory); procs executor will "
                  "use the pipe transport")
        elif (os.cpu_count() or 1) == 1:
            print("# single-CPU host: both transports pay a context "
                  "switch per message, parity expected; rings win on "
                  "multi-core hosts by removing the syscall")
        path = merge_bench({"machine_calibration": cal})
        print(f"# wrote {path} (machine_calibration)")
        return 0

    if args.profile:
        system = build_system(args.scheduler, args.workers, args.tenants,
                              args.rounds,
                              executor=args.executor
                              if args.scheduler != "serial" else None)
        prof = cProfile.Profile()
        prof.enable()
        system.run()
        prof.disable()
        eng = system.engine
        print(f"# scheduler={args.scheduler} workers={args.workers} "
              f"events={eng.events_processed}")
        buf = io.StringIO()
        stats = pstats.Stats(prof, stream=buf)
        stats.strip_dirs().sort_stats(args.sort).print_stats(args.limit)
        print(buf.getvalue())
        return 0

    print(f"# tenants={args.tenants} rounds={args.rounds} "
          f"workers={args.workers} repeat={args.repeat} (best shown)")
    print(f"{'scheduler':>10}  {'wall':>12}  {'':>14}  {'throughput':>15}")
    scheds = (("serial", "batch", "lookahead", "bounded") if args.all
              else (args.scheduler,))
    for sched in scheds:
        best = min((run_once(args, sched) for _ in range(args.repeat)),
                   key=lambda r: r["wall_s"])
        print_row(best)
    return 0


if __name__ == "__main__":
    sys.exit(main())
