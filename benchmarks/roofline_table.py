"""§Roofline table — renders the dry-run results (assignment g).

Reads results/dryrun_single.json (+ _multi.json if present) produced by
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun_single.json
and prints the per-cell roofline terms table.  If the JSON is missing it
dry-runs a 3-cell subset inline (slow: full compiles on 256 fake devices).
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def fmt(t):
    if t is None:
        return "-"
    for unit, s in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if abs(t) >= s:
            return f"{t / s:.3g}{unit}"
    return f"{t:.1e}s"


def render(rows) -> str:
    hdr = ["cell", "mesh", "status", "t_compute", "t_memory", "t_coll(sim)",
           "dominant", "useful", "roofline%", "peak_GB/dev"]
    out = [" | ".join(hdr), " | ".join(["---"] * len(hdr))]
    for r in rows:
        cell = f"{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            out.append(f"{cell} | {r['mesh']} | {r['status']} | " +
                       " | ".join(["-"] * 6) +
                       f" | {r.get('reason', r.get('error', ''))[:60]}")
            continue
        peak = (r.get("peak_bytes_per_device") or 0) / 1e9
        out.append(" | ".join([
            cell, r["mesh"], "ok", fmt(r["t_compute"]), fmt(r["t_memory"]),
            fmt(r["t_collective_sim"]), r["dominant"],
            f"{r['useful_ratio']:.2f}",
            f"{100 * r['roofline_fraction']:.1f}%", f"{peak:.2f}",
        ]))
    return "\n".join(out)


def main() -> int:
    print("name,us_per_call,derived")
    found = False
    for tag in ("single", "multi"):
        path = os.path.join(RESULTS, f"dryrun_{tag}.json")
        if not os.path.exists(path):
            continue
        found = True
        rows = json.load(open(path))
        ok = [r for r in rows if r["status"] == "ok"]
        print(f"# ---- {tag}-pod mesh: {len(ok)}/{len(rows)} cells ok ----")
        print(render(rows))
        for r in ok:
            print(f"{r['arch']}/{r['shape']}_{tag},"
                  f"{1e6 * r['bound_time']:.1f},"
                  f"dominant={r['dominant']}"
                  f"|roofline={100 * r['roofline_fraction']:.1f}%")
    if not found:
        print("# no results/dryrun_*.json — run repro.launch.dryrun --all "
              "--out results/dryrun_single.json first", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
