"""Fig. 6 analog — micro-benchmarks isolating ONE model parameter each.

The paper fits/validates per-component latencies (ALU pipeline, L1/L2/
DRAM) with micro-kernels.  Our system model's parameters are the TPU
chip constants; each micro-benchmark builds a minimal synthetic trace
that exerces exactly one parameter and checks the simulated time against
the closed-form expectation:

  mxu_staircase   op-launch overhead + MXU FLOP rate (ALU analog)
  hbm_latency     HBM bandwidth occupancy (DRAM analog)
  ici_hop         single collective-permute hop (L1/L2 hit analog)
  ring_allreduce  full ring formula (memory-hierarchy traversal analog)
  dcn_crosspod    cross-pod DCN latency + bandwidth

Prints name,us_per_call,derived CSV (derived = analytic expectation;
sim must match within 1%).
"""
from __future__ import annotations

import sys

from repro.core import SystemSpec, simulate
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp


def _sim_compute(flops, nbytes, spec):
    cost = HloCost(trace=[TraceOp("compute", "op", flops=flops,
                                  hbm_bytes=nbytes)])
    return simulate(cost=cost, spec=spec, device_limit=1).time_s


def _sim_collective(kind, nbytes, group, spec):
    rec = CollectiveRecord(kind, "c", nbytes, int(nbytes), int(nbytes),
                           [group])
    cost = HloCost(collectives=[rec],
                   trace=[TraceOp("collective", "c", collective=rec)])
    return simulate(cost=cost, spec=spec, device_limit=None).time_s


def rows():
    spec = SystemSpec(pod_shape=(4, 4), num_pods=2)
    c = spec.chip
    out = []

    # 1) MXU staircase: time vs flops is launch_overhead + flops/peak
    for flops in (1e9, 4e9, 16e9):
        t = _sim_compute(flops, 0.0, spec)
        expect = c.op_launch_overhead_s + flops / c.peak_bf16_flops
        out.append((f"mxu_{flops:.0e}flop", t * 1e6, expect * 1e6))

    # 2) HBM occupancy
    for nbytes in (1e8, 8e8):
        t = _sim_compute(1.0, nbytes, spec)
        expect = c.op_launch_overhead_s + nbytes / c.hbm_bandwidth
        out.append((f"hbm_{nbytes:.0e}B", t * 1e6, expect * 1e6))

    # 3) single ICI hop (collective-permute)
    t = _sim_collective("collective-permute", 1e6, [0, 1], spec)
    expect = 1e6 / c.ici_link_bandwidth + c.ici_hop_latency_s
    out.append(("ici_hop_1MB", t * 1e6, expect * 1e6))

    # 4) ring all-reduce over an x ring
    n, B = 4, 1e7
    t = _sim_collective("all-reduce", B, [0, 1, 2, 3], spec)
    expect = 2 * (n - 1) / n * B / (2 * c.ici_link_bandwidth) \
        + 2 * (n - 1) * c.ici_hop_latency_s
    out.append(("ring_ar_10MB", t * 1e6, expect * 1e6))

    # 5) cross-pod pair over DCN
    t = _sim_collective("all-reduce", 1e7, [0, 16], spec)
    assert t >= c.dcn_latency_s
    expect = 1e7 / spec.dcn_bandwidth_per_pod + c.dcn_latency_s
    out.append(("dcn_pair_10MB", t * 1e6, expect * 1e6))
    return out


def main() -> int:
    print("name,us_per_call,derived_us")
    worst = 0.0
    for name, got, expect in rows():
        print(f"{name},{got:.3f},{expect:.3f}")
        worst = max(worst, abs(got - expect) / max(expect, 1e-9))
    print(f"# max relative error vs closed form: {100 * worst:.3f}%")
    return 0 if worst < 0.01 else 1


if __name__ == "__main__":
    sys.exit(main())
