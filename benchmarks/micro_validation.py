"""Fig. 6 analog — micro-benchmarks isolating ONE model parameter each.

The paper fits/validates per-component latencies (ALU pipeline, L1/L2/
DRAM) with micro-kernels.  Our system model's parameters are the TPU
chip constants; each micro-benchmark builds a minimal synthetic trace
that exerces exactly one parameter and checks the simulated time against
the closed-form expectation:

  mxu_staircase   op-launch overhead + MXU FLOP rate (ALU analog)
  hbm_latency     HBM bandwidth occupancy (DRAM analog)
  ici_hop         single collective-permute hop (L1/L2 hit analog)
  ring_allreduce  full ring formula (memory-hierarchy traversal analog)
  dcn_crosspod    cross-pod DCN latency + bandwidth

Prints name,us_per_call,derived CSV (derived = analytic expectation;
sim must match within 1%).

Each micro-benchmark runs under BOTH fabric backends (the analytic
closed-form pricer and the event-driven per-hop replay): on an idle,
single-collective fabric the two must agree with the derivation --
``analytic`` within 1%, ``event`` within the 5% parity budget
(docs/fabric.md).  This is the CI fabric-validation smoke step.
"""
from __future__ import annotations

import sys

from repro.core import SystemSpec, simulate
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp

FABRICS = ("analytic", "event")
TOLERANCE = {"analytic": 0.01, "event": 0.05}


def _sim_compute(flops, nbytes, spec, fabric):
    cost = HloCost(trace=[TraceOp("compute", "op", flops=flops,
                                  hbm_bytes=nbytes)])
    return simulate(cost=cost, spec=spec, device_limit=1,
                    fabric=fabric).time_s


def _sim_collective(kind, nbytes, group, spec, fabric):
    rec = CollectiveRecord(kind, "c", nbytes, int(nbytes), int(nbytes),
                           [group])
    cost = HloCost(collectives=[rec],
                   trace=[TraceOp("collective", "c", collective=rec)])
    return simulate(cost=cost, spec=spec, device_limit=None,
                    fabric=fabric).time_s


def rows(fabric: str = "analytic"):
    spec = SystemSpec(pod_shape=(4, 4), num_pods=2)
    c = spec.chip
    out = []

    # 1) MXU staircase: time vs flops is launch_overhead + flops/peak
    for flops in (1e9, 4e9, 16e9):
        t = _sim_compute(flops, 0.0, spec, fabric)
        expect = c.op_launch_overhead_s + flops / c.peak_bf16_flops
        out.append((f"mxu_{flops:.0e}flop", t * 1e6, expect * 1e6))

    # 2) HBM occupancy
    for nbytes in (1e8, 8e8):
        t = _sim_compute(1.0, nbytes, spec, fabric)
        expect = c.op_launch_overhead_s + nbytes / c.hbm_bandwidth
        out.append((f"hbm_{nbytes:.0e}B", t * 1e6, expect * 1e6))

    # 3) single ICI hop (collective-permute).  Collective derivations
    # include the coordinator control-plane round trip (join + done, one
    # SystemSpec.ctrl_latency_s hop each way) introduced with the
    # pluggable-scheduler engine.
    ctrl = 2 * spec.ctrl_latency_s
    t = _sim_collective("collective-permute", 1e6, [0, 1], spec, fabric)
    expect = 1e6 / c.ici_link_bandwidth + c.ici_hop_latency_s + ctrl
    out.append(("ici_hop_1MB", t * 1e6, expect * 1e6))

    # 4) ring all-reduce over an x ring
    n, B = 4, 1e7
    t = _sim_collective("all-reduce", B, [0, 1, 2, 3], spec, fabric)
    expect = 2 * (n - 1) / n * B / (2 * c.ici_link_bandwidth) \
        + 2 * (n - 1) * c.ici_hop_latency_s + ctrl
    out.append(("ring_ar_10MB", t * 1e6, expect * 1e6))

    # 5) cross-pod pair over DCN
    t = _sim_collective("all-reduce", 1e7, [0, 16], spec, fabric)
    assert t >= c.dcn_latency_s
    expect = 1e7 / spec.dcn_bandwidth_per_pod + c.dcn_latency_s + ctrl
    out.append(("dcn_pair_10MB", t * 1e6, expect * 1e6))
    return out


def main() -> int:
    print("name,us_per_call,derived_us")
    failed = False
    for fabric in FABRICS:
        worst = 0.0
        for name, got, expect in rows(fabric):
            print(f"{name}:{fabric},{got:.3f},{expect:.3f}")
            worst = max(worst, abs(got - expect) / max(expect, 1e-9))
        print(f"# [{fabric}] max relative error vs closed form: "
              f"{100 * worst:.3f}% (budget {100 * TOLERANCE[fabric]:.0f}%)")
        failed |= worst >= TOLERANCE[fabric]
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
