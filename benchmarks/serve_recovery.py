"""Serve-through-faults: a permanent chip kill mid-trace, end to end.

A pod2x2 two-tenant trace loses ``chip1.prog`` (tenant 0's second chip)
at t=1s.  The recovery layer (docs/faults.md "Detection & recovery")
must detect the death via the collective deadline, abort and re-mesh
the affected tenant onto its surviving chip, requeue the interrupted
requests, and keep serving -- the run *completes* rather than stalling.

Two sections, merged into ``BENCH_serve.json`` (read-merge-write, the
BENCH idiom; ``--quick`` writes ``*_quick`` sections):

* ``recovery`` -- the outage anatomy on both fabrics: zero stuck
  requests, nonzero retries/recoveries, exactly one chip death,
  availability < 1 only for the affected tenant, time-to-recovery, the
  goodput dip inside the outage window, and the restore gate --
  completions-per-arrival in the post-recovery window within
  ``RESTORE_GATE`` of the pre-fault window.  (Per-arrival, not
  per-second: with a fixed Poisson seed the offered rate itself
  fluctuates window to window; normalizing by arrivals isolates what
  recovery controls -- whether offered work still completes.)
* ``recovery_identity`` -- the mid-recovery determinism matrix: per
  fabric, every round scheduler x executor combination must reproduce
  the serial oracle's ``ServeReport.summary()`` exactly, *while* the
  trace contains a death + abort + re-mesh + requeue; across fabrics
  the behavioral fields (everything but the fabric-artifact ones) must
  match too.  Recovery control flow rides engine events, so the
  determinism guarantee may not narrow under faults.
* ``spare_failover`` -- the same kill on a pod2x2x2 with and without a
  reserved spare chip: the spare arm must restore goodput at least as
  well as the shrink-to-survivors baseline, strictly improve
  capacity-weighted availability, checkpoint prefill KV
  (``prefill_saved_tokens > 0``) and price its migration over the
  fabric (``migrated_bytes > 0``).
* ``spare_identity`` -- the determinism matrix repeated on the
  spare-claim + KV-migration trace.

All gates are deterministic simulation quantities (no wall-clock), so
they hold on any host.  ``--quick`` shrinks the trace for CI and exits
nonzero if any gate fails; ``benchmarks/fault_tolerance.py --quick``
reuses the quick gates so the CI workflow runs them in one place.

Run as: PYTHONPATH=src:. python -m benchmarks.serve_recovery [--quick]
"""
from __future__ import annotations

import argparse
import time

from repro.core import SystemSpec
from repro.serve.sim import build_scenario, run_serving

from benchmarks.serve_latency import merge_bench

SPEC = SystemSpec(pod_shape=(2, 2))
SPARE_SPEC = SystemSpec(pod_shape=(2, 2), num_pods=2)   # room for a pool
SEED = 11
DEADLINE_S = 5e-4
FAULT_CHIP = "chip1.prog"      # tenant 0's second chip on pod2x2
AFFECTED_TENANT = 0

# full: the acceptance trace -- kill at t=1s, ~1.4k requests; quick: the
# same anatomy inside a 20ms CI-sized window (kill mid-iteration too).
FULL = {"rate_rps": 300.0, "duration_s": 1.5, "fault_at_s": 1.0}
QUICK = {"rate_rps": 600.0, "duration_s": 0.02, "fault_at_s": 5e-3}

RESTORE_GATE = 0.95            # post-recovery completions-per-arrival
                               # vs pre-fault, same faulted run

MATRIX = [(s, e) for s in ("batch", "lookahead", "bounded")
          for e in ("threads", "procs")]
MATRIX_QUICK = [("batch", "threads"), ("lookahead", "procs"),
                ("bounded", "procs")]

# summary() fields that legitimately differ between fabrics (the fabric
# names itself + its own bookkeeping); everything else must match
_FABRIC_ARTIFACTS = ("events", "fabric", "link_report", "link_utilization")


def _run(params: dict, fabric: str, **kw):
    scen = build_scenario(SPEC, rate_rps=params["rate_rps"],
                          duration_s=params["duration_s"], seed=SEED)
    assert scen is not None
    faults = {FAULT_CHIP: [(params["fault_at_s"], "fail", None)]}
    return run_serving(scen, spec=SPEC, fabric=fabric, faults=faults,
                       deadline_s=DEADLINE_S, recovery=True, **kw)


def restore_ratio(rep, fault_at_s: float) -> dict:
    """Goodput-restored metric: completions per arrival in the
    post-recovery window over the same in the pre-fault window.  The
    post window may exceed 1x (it also drains the requeued backlog);
    an unrecovered tenant would roughly halve it."""
    windows = rep.outage_windows[AFFECTED_TENANT]
    recover_s = max((e for _, e in windows), default=fault_at_s)
    done = [(r["arrival_s"], r["arrival_s"] + r["e2e_s"])
            for r in rep.per_request]
    pre_a = sum(1 for a, _ in done if a < fault_at_s)
    pre_c = sum(1 for _, d in done if d < fault_at_s)
    post_a = sum(1 for a, _ in done if a >= recover_s)
    post_c = sum(1 for _, d in done if d >= recover_s)
    pre = pre_c / pre_a if pre_a else 0.0
    post = post_c / post_a if post_a else 0.0
    return {
        "time_to_recovery_s": round(recover_s - fault_at_s, 9),
        "pre_fault_completions_per_arrival": round(pre, 4),
        "post_recovery_completions_per_arrival": round(post, 4),
        "restore_ratio": round(post / pre, 4) if pre else None,
    }


def recovery_anatomy(params: dict) -> dict:
    """The outage view on both fabrics, plus every per-run gate."""
    out = {"params": dict(params), "deadline_s": DEADLINE_S,
           "fault_chip": FAULT_CHIP}
    for fabric in ("analytic", "event"):
        t0 = time.perf_counter()
        rep = _run(params, fabric)
        stuck = rep.offered - rep.completed - rep.dropped
        restore = restore_ratio(rep, params["fault_at_s"])
        avail = rep.tenant_availability
        out[fabric] = {
            "offered": rep.offered,
            "completed": rep.completed,
            "dropped": rep.dropped,
            "stuck": stuck,
            "retries": rep.retries,
            "recoveries": rep.recoveries,
            "rejoins": rep.rejoins,
            "chip_deaths": rep.chip_deaths,
            "collective_timeouts": rep.collective_timeouts,
            "tenant_availability": [round(a, 6) for a in avail],
            "tenant_outage_ms": [round(o * 1e3, 4)
                                 for o in rep.tenant_outage_s],
            "outage_windows_s": rep.outage_windows[AFFECTED_TENANT],
            "goodput_in_outage_rps": round(rep.goodput_in_outage_rps, 2),
            "goodput_outside_outage_rps": round(
                rep.goodput_outside_outage_rps, 2),
            "p99_ms": round(rep.p99_s * 1e3, 4),
            "wall_s": round(time.perf_counter() - t0, 3),
            **restore,
            "gates": {
                "zero_stuck": stuck == 0,
                "retries_nonzero": rep.retries > 0,
                "recovered": rep.recoveries >= 1,
                "one_death": rep.chip_deaths == 1,
                "availability_dips_only_affected": (
                    avail[AFFECTED_TENANT] < 1.0
                    and all(a == 1.0 for i, a in enumerate(avail)
                            if i != AFFECTED_TENANT)),
                "goodput_dip_visible": (rep.goodput_in_outage_rps
                                        < rep.goodput_outside_outage_rps),
                "goodput_restored": (
                    restore["restore_ratio"] is not None
                    and restore["restore_ratio"] >= RESTORE_GATE),
            },
        }
    return out


def recovery_identity(params: dict, combos) -> dict:
    """Mid-recovery determinism: scheduler x executor per fabric, then
    behavioral equality across fabrics."""
    results, identical = {}, True
    oracles = {}
    for fabric in ("analytic", "event"):
        oracle = _run(params, fabric)
        oracles[fabric] = oracle.summary()
        matrix = {}
        for sched, executor in combos:
            rep = _run(params, fabric, scheduler=sched, executor=executor,
                       max_workers=2)
            ok = rep.summary() == oracle.summary()
            matrix[f"{sched}+{executor}"] = ok
            identical = identical and ok
        results[fabric] = {"retries": oracle.retries,
                           "recoveries": oracle.recoveries,
                           "p99_ms": round(oracle.p99_s * 1e3, 4),
                           "matrix": matrix}
    behave = {f: {k: v for k, v in s.items() if k not in _FABRIC_ARTIFACTS}
              for f, s in oracles.items()}
    results["cross_fabric_behavioral"] = behave["analytic"] == behave["event"]
    results["bit_identical"] = identical
    results["combos_per_fabric"] = len(combos)
    return results


def gates_pass(anatomy: dict, ident: dict) -> bool:
    return (ident["bit_identical"]
            and ident["cross_fabric_behavioral"]
            and all(anatomy[f]["gates"].values()
                    for f in ("analytic", "event")))


# -- stateful failover: spare pool + KV migration (ISSUE 10) ----------------

def _run_spare(params: dict, fabric: str, spares: int, **kw):
    scen = build_scenario(SPARE_SPEC, rate_rps=params["rate_rps"],
                          duration_s=params["duration_s"], seed=SEED,
                          spares=spares)
    assert scen is not None
    faults = {FAULT_CHIP: [(params["fault_at_s"], "fail", None)]}
    return run_serving(scen, spec=SPARE_SPEC, fabric=fabric, faults=faults,
                       deadline_s=DEADLINE_S, recovery=True, **kw)


def spare_failover(params: dict) -> dict:
    """The same kill with and without one reserved spare, per fabric.
    Gates: the spare arm's goodput-restore ratio is at least the
    no-spare baseline's, its capacity-weighted availability strictly
    improves, migrated retries resume decode from checkpointed KV
    (``prefill_saved_tokens > 0``) over a priced transfer
    (``migrated_bytes > 0``), and nothing sticks."""
    out = {"params": dict(params), "deadline_s": DEADLINE_S,
           "fault_chip": FAULT_CHIP, "spares": 1}
    for fabric in ("analytic", "event"):
        t0 = time.perf_counter()
        base = _run_spare(params, fabric, spares=0)
        spare = _run_spare(params, fabric, spares=1)
        arms = {}
        for label, rep in (("no_spare", base), ("spare", spare)):
            stuck = rep.offered - rep.completed - rep.dropped
            arms[label] = {
                "offered": rep.offered,
                "completed": rep.completed,
                "dropped": rep.dropped,
                "stuck": stuck,
                "retries": rep.retries,
                "chip_deaths": rep.chip_deaths,
                "spare_claims": rep.spare_claims,
                "spare_returns": rep.spare_returns,
                "migrated_bytes": rep.migrated_bytes,
                "prefill_saved_tokens": rep.prefill_saved_tokens,
                "prefill_recompute_tokens": rep.prefill_recompute_tokens,
                "availability_t0": round(
                    rep.tenant_availability[AFFECTED_TENANT], 6),
                "effective_availability_t0": round(
                    rep.tenant_effective_availability[AFFECTED_TENANT], 6),
                **restore_ratio(rep, params["fault_at_s"]),
            }
        b, s = arms["no_spare"], arms["spare"]
        arms["wall_s"] = round(time.perf_counter() - t0, 3)
        arms["gates"] = {
            "zero_stuck": b["stuck"] == 0 and s["stuck"] == 0,
            "one_death_each": (b["chip_deaths"] == 1
                               and s["chip_deaths"] == 1),
            "spare_claimed": s["spare_claims"] == 1,
            "restore_at_least_baseline": (
                s["restore_ratio"] is not None
                and b["restore_ratio"] is not None
                and s["restore_ratio"] >= b["restore_ratio"] - 1e-9),
            "availability_strictly_improves": (
                s["effective_availability_t0"]
                > b["effective_availability_t0"]),
            "prefill_checkpointed": s["prefill_saved_tokens"] > 0,
            "migration_priced": s["migrated_bytes"] > 0,
        }
        out[fabric] = arms
    return out


def spare_identity(params: dict, combos) -> dict:
    """The mid-failover determinism matrix on the spare-claim trace:
    spare re-placement, KV migration and quorum verdicts are all engine
    events, so the scheduler x executor x fabric guarantee must hold
    through them too."""
    results, identical = {}, True
    oracles = {}
    for fabric in ("analytic", "event"):
        oracle = _run_spare(params, fabric, spares=1)
        oracles[fabric] = oracle.summary()
        matrix = {}
        for sched, executor in combos:
            rep = _run_spare(params, fabric, spares=1, scheduler=sched,
                             executor=executor, max_workers=2)
            ok = rep.summary() == oracle.summary()
            matrix[f"{sched}+{executor}"] = ok
            identical = identical and ok
        results[fabric] = {"spare_claims": oracle.spare_claims,
                           "migrated_bytes": oracle.migrated_bytes,
                           "p99_ms": round(oracle.p99_s * 1e3, 4),
                           "matrix": matrix}
    behave = {f: {k: v for k, v in s.items() if k not in _FABRIC_ARTIFACTS}
              for f, s in oracles.items()}
    results["cross_fabric_behavioral"] = behave["analytic"] == behave["event"]
    results["bit_identical"] = identical
    results["combos_per_fabric"] = len(combos)
    return results


def spare_gates_pass(fail: dict, ident: dict) -> bool:
    return (ident["bit_identical"]
            and ident["cross_fabric_behavioral"]
            and all(fail[f]["gates"].values()
                    for f in ("analytic", "event")))


def run_quick_gate() -> dict:
    """The CI-sized recovery gate, callable from fault_tolerance.py:
    returns {"anatomy", "identity", "spare", "spare_identity", "ok"}
    for the quick trace."""
    anatomy = recovery_anatomy(QUICK)
    ident = recovery_identity(QUICK, MATRIX_QUICK)
    fail = spare_failover(QUICK)
    sident = spare_identity(QUICK, MATRIX_QUICK)
    return {"anatomy": anatomy, "identity": ident,
            "spare": fail, "spare_identity": sident,
            "ok": (gates_pass(anatomy, ident)
                   and spare_gates_pass(fail, sident))}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 20ms trace, 3 identity combos; "
                         "writes *_quick sections")
    args = ap.parse_args(argv)

    params = QUICK if args.quick else FULL
    combos = MATRIX_QUICK if args.quick else MATRIX

    anatomy = recovery_anatomy(params)
    ident = recovery_identity(params, combos)
    fail = spare_failover(params)
    sident = spare_identity(params, combos)

    suffix = "_quick" if args.quick else ""
    path = merge_bench({f"recovery{suffix}": anatomy,
                        f"recovery_identity{suffix}": ident,
                        f"spare_failover{suffix}": fail,
                        f"spare_identity{suffix}": sident})

    print("fabric,offered,completed,stuck,retries,recoveries,"
          "availability_t0,time_to_recovery_ms,restore_ratio")
    for fabric in ("analytic", "event"):
        a = anatomy[fabric]
        print(f"{fabric},{a['offered']},{a['completed']},{a['stuck']},"
              f"{a['retries']},{a['recoveries']},"
              f"{a['tenant_availability'][AFFECTED_TENANT]},"
              f"{a['time_to_recovery_s'] * 1e3:.4f},{a['restore_ratio']}")
    print(f"# identity: {ident['combos_per_fabric']} scheduler x executor "
          f"combos per fabric mid-recovery, identical="
          f"{ident['bit_identical']}, cross-fabric behavioral="
          f"{ident['cross_fabric_behavioral']}")
    print("# spare: fabric,restore_no_spare,restore_spare,"
          "effav_no_spare,effav_spare,migrated_bytes,prefill_saved")
    for fabric in ("analytic", "event"):
        f = fail[fabric]
        print(f"#   {fabric},{f['no_spare']['restore_ratio']},"
              f"{f['spare']['restore_ratio']},"
              f"{f['no_spare']['effective_availability_t0']},"
              f"{f['spare']['effective_availability_t0']},"
              f"{f['spare']['migrated_bytes']},"
              f"{f['spare']['prefill_saved_tokens']}")
    print(f"# spare identity: {sident['combos_per_fabric']} combos per "
          f"fabric on the spare-claim trace, identical="
          f"{sident['bit_identical']}, cross-fabric behavioral="
          f"{sident['cross_fabric_behavioral']}")
    ok = gates_pass(anatomy, ident) and spare_gates_pass(fail, sident)
    print(f"# gates {'pass' if ok else 'FAIL'}; wrote {path}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
