"""Benchmark driver: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run

Each module prints ``name,us_per_call,derived`` CSV.  Modules that need
a multi-device mesh set XLA_FLAGS for themselves, so every benchmark
runs in its own subprocess (device count is locked at first jax init).
"""
from __future__ import annotations

import os
import subprocess
import sys

MODULES = [
    ("micro_validation", "Fig.6 — one-parameter micro-benchmarks"),
    ("engine_scalability",
     "Fig.2+8 — widths + scheduler scaling -> BENCH_engine.json"),
    ("mgmark_validation", "Fig.7 — workload sim vs analytic bound"),
    ("case_study", "Fig.9 — U-mode vs D-mode traffic/time"),
    ("fault_tolerance", "straggler / failure / ckpt-interval what-ifs"),
    ("roofline_table", "§Roofline — dry-run cell table"),
    ("sweep_throughput",
     "vectorized pricing + fleet sweep -> BENCH_fabric.json 'sweep'"),
]


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo
    env.pop("XLA_FLAGS", None)
    failures = []
    for mod, title in MODULES:
        print(f"\n=== benchmarks.{mod} — {title} ===", flush=True)
        proc = subprocess.run(
            [sys.executable, "-m", f"benchmarks.{mod}"], env=env, cwd=repo,
            capture_output=True, text=True, timeout=3000)
        sys.stdout.write(proc.stdout)
        if proc.returncode != 0:
            failures.append(mod)
            sys.stdout.write(f"[FAILED rc={proc.returncode}]\n"
                             + proc.stderr[-2000:] + "\n")
    bench_json = os.path.join(repo, "BENCH_engine.json")
    if os.path.exists(bench_json):
        print(f"\nengine perf trajectory: {bench_json}")
    print(f"\n{len(MODULES) - len(failures)}/{len(MODULES)} benchmarks ok"
          + (f"; FAILED: {failures}" if failures else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
