"""Fabric contention benchmark — congestion the analytic model cannot see.

The analytic backend prices every collective against an idle fabric: two
concurrent collectives that share a link are each billed as if they
owned it.  The event backend queues transfers on per-link components, so
overlap costs real simulated time.  Three scenarios, each a multi-tenant
trace (two disjoint device sets replaying different programs through one
``System``):

  dcn_overlap      two pod-axis all-reduce pairs share a pod's DCN uplink
  bisect_overlap   two 8-chip block all-to-alls share the pod bisection
  ring_disjoint    control: disjoint x-rings share nothing (ratio ~1)

Prints name,analytic_us,event_us,event/analytic CSV and exits non-zero
unless the overlapped scenarios show a >=1.25x congestion effect while
the control stays within 2%: the separation between backends is the
deliverable, not a point estimate.

A second section measures how well the lookahead scheduler parallelizes
event-fabric *replay* now that fabric legs carry latency (each chip's
DMA + links is its own cluster): a multi-tenant, event-dense trace runs
under serial/batch/lookahead (bit-identity asserted) and the results —
wall clock plus the paper-style *architectural* speedup (critical-path
events at N workers vs total events; under CPython's GIL threads add no
physical parallelism, so the architectural number is the Fig. 8-analog
deliverable, exactly as the paper reports core-count speedup for its Go
runtime) — merge into ``BENCH_fabric.json`` under ``"replay"``.  Exits
non-zero unless the architectural lookahead-over-serial speedup at 4
workers is >= 1.5x with all schedulers bit-identical.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import LookaheadScheduler, SystemSpec, System
from repro.core.system import _RunOp

SPEC = SystemSpec(pod_shape=(4, 4), num_pods=2)


def _coll(name, kind, nbytes, group):
    return _RunOp(kind="collective", name=name, coll_kind=kind,
                  bytes=nbytes, group=(tuple(group),))


def _run(fabric, tenants):
    """tenants: list of (runop, devices); returns end-to-end seconds."""
    system = System(SPEC, fabric=fabric)
    for op, devices in tenants:
        system.load_trace([op], devices)
    return system.run()["time_s"]


def scenarios():
    return {
        "dcn_overlap": [
            (_coll("arA", "all-reduce", 1e7, [0, 16]), [0, 16]),
            (_coll("arB", "all-reduce", 1e7, [1, 17]), [1, 17]),
        ],
        "bisect_overlap": [
            (_coll("a2aA", "all-to-all", 4e6, range(8)), list(range(8))),
            (_coll("a2aB", "all-to-all", 4e6, range(8, 16)),
             list(range(8, 16))),
        ],
        "ring_disjoint": [
            (_coll("arA", "all-reduce", 1e7, [0, 1, 2, 3]), [0, 1, 2, 3]),
            (_coll("arB", "all-reduce", 1e7, [4, 5, 6, 7]), [4, 5, 6, 7]),
        ],
    }


# -- event-fabric replay parallelism (lookahead vs serial) -------------------

def _tenant_ops(tid: int, rounds: int) -> tuple:
    """One tenant: an 8-chip block replaying `rounds` x (compute segment
    + ring all-reduce + all-gather).  Per-tenant flop/byte scaling
    staggers the tenants' timestamps, so same-timestamp batching finds
    little parallelism and the lookahead window has to earn it."""
    devs = tuple(range(8 * tid, 8 * tid + 8))
    ops = []
    for r in range(rounds):
        ops.append(_RunOp(kind="compute", name=f"seg{tid}_{r}",
                          flops=2e9 * (1.0 + 0.37 * tid), hbm_bytes=1e6))
        ops.append(_coll(f"ar{tid}_{r}", "all-reduce",
                         1e6 * (1.0 + 0.23 * tid), devs))
        ops.append(_coll(f"ag{tid}_{r}", "all-gather",
                         5e5 * (1.0 + 0.31 * tid), devs))
    return ops, list(devs)


def _replay_run(scheduler, workers: int = 4, record: bool = False,
                tenants: int = 4, rounds: int = 6):
    sched = scheduler
    if record:
        sched = LookaheadScheduler(max_workers=workers)
        sched.record_group_sizes = True
    system = System(SPEC, fabric="event", scheduler=sched,
                    max_workers=workers)
    for tid in range(tenants):
        ops, devs = _tenant_ops(tid, rounds)
        system.load_trace(ops, devs)
    t0 = time.time()
    res = system.run()
    wall = time.time() - t0
    state = (res, system.fabric.link_utilization(), system.fabric.link_report())
    return state, system.engine, wall


def _architectural_speedup(round_groups, workers: int) -> float:
    """Critical-path events at `workers` cores vs total events, using the
    pool's actual round-robin chunking of sorted cluster groups."""
    total = critical = 0
    for sizes in round_groups:
        total += sum(sizes)
        n = min(workers, len(sizes))
        critical += max(sum(sizes[i::n]) for i in range(n))
    return total / max(1, critical)


def replay_speedup(workers: int = 4) -> dict:
    oracle, eng_s, wall_s = _replay_run("serial", workers=1)
    rows = {"events": eng_s.events_processed, "workers": workers,
            "wall_serial_s": round(wall_s, 4)}
    identical = True
    for sched in ("batch", "lookahead"):
        state, eng, wall = _replay_run(sched, workers=workers)
        identical &= state == oracle
        rows[f"wall_{sched}{workers}_s"] = round(wall, 4)
        rows[f"rounds_{sched}"] = len(eng.window_widths
                                      or eng.batch_widths)
    state, eng, _ = _replay_run("lookahead", workers=workers, record=True)
    identical &= state == oracle
    rows["bit_identical"] = identical
    rows["clusters_busy_max"] = max(
        (len(g) for g in eng.round_group_sizes), default=0)
    rows["speedup_lookahead_vs_serial_4w"] = round(
        _architectural_speedup(eng.round_group_sizes, workers), 2)
    return rows


def merge_bench(update: dict) -> str:
    """Read-merge-write BENCH_fabric.json: this benchmark owns the
    "replay" section, engine_scalability owns "runs" -- neither may
    clobber the other (both import this one helper)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_fabric.json")
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(update)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def main() -> int:
    print("name,analytic_us,event_us,ratio")
    ratios = {}
    for name, tenants in scenarios().items():
        t_a = _run("analytic", tenants)
        t_e = _run("event", tenants)
        ratios[name] = t_e / t_a
        print(f"{name},{t_a * 1e6:.3f},{t_e * 1e6:.3f},{ratios[name]:.3f}")
    ok = (ratios["dcn_overlap"] >= 1.25 and ratios["bisect_overlap"] >= 1.25
          and abs(ratios["ring_disjoint"] - 1.0) < 0.02)
    print(f"# congestion visible to event backend only: {ok}")

    replay = replay_speedup()
    path = merge_bench({"replay": replay})
    speedup = replay["speedup_lookahead_vs_serial_4w"]
    print(f"# replay: {replay['events']} events, lookahead architectural "
          f"speedup over serial at 4 workers: {speedup:.2f}x "
          f"(bit_identical={replay['bit_identical']}); wrote {path}")
    ok = ok and replay["bit_identical"] and speedup >= 1.5
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
