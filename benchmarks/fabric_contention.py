"""Fabric contention benchmark — congestion the analytic model cannot see.

The analytic backend prices every collective against an idle fabric: two
concurrent collectives that share a link are each billed as if they
owned it.  The event backend queues transfers on per-link components, so
overlap costs real simulated time.  Three scenarios, each a multi-tenant
trace (two disjoint device sets replaying different programs through one
``System``):

  dcn_overlap      two pod-axis all-reduce pairs share a pod's DCN uplink
  bisect_overlap   two 8-chip block all-to-alls share the pod bisection
  ring_disjoint    control: disjoint x-rings share nothing (ratio ~1)

Prints name,analytic_us,event_us,event/analytic CSV and exits non-zero
unless the overlapped scenarios show a >=1.25x congestion effect while
the control stays within 2%: the separation between backends is the
deliverable, not a point estimate.
"""
from __future__ import annotations

import sys

from repro.core import SystemSpec, System
from repro.core.system import _RunOp

SPEC = SystemSpec(pod_shape=(4, 4), num_pods=2)


def _coll(name, kind, nbytes, group):
    return _RunOp(kind="collective", name=name, coll_kind=kind,
                  bytes=nbytes, group=(tuple(group),))


def _run(fabric, tenants):
    """tenants: list of (runop, devices); returns end-to-end seconds."""
    system = System(SPEC, fabric=fabric)
    for op, devices in tenants:
        system.load_trace([op], devices)
    return system.run()["time_s"]


def scenarios():
    return {
        "dcn_overlap": [
            (_coll("arA", "all-reduce", 1e7, [0, 16]), [0, 16]),
            (_coll("arB", "all-reduce", 1e7, [1, 17]), [1, 17]),
        ],
        "bisect_overlap": [
            (_coll("a2aA", "all-to-all", 4e6, range(8)), list(range(8))),
            (_coll("a2aB", "all-to-all", 4e6, range(8, 16)),
             list(range(8, 16))),
        ],
        "ring_disjoint": [
            (_coll("arA", "all-reduce", 1e7, [0, 1, 2, 3]), [0, 1, 2, 3]),
            (_coll("arB", "all-reduce", 1e7, [4, 5, 6, 7]), [4, 5, 6, 7]),
        ],
    }


def main() -> int:
    print("name,analytic_us,event_us,ratio")
    ratios = {}
    for name, tenants in scenarios().items():
        t_a = _run("analytic", tenants)
        t_e = _run("event", tenants)
        ratios[name] = t_e / t_a
        print(f"{name},{t_a * 1e6:.3f},{t_e * 1e6:.3f},{ratios[name]:.3f}")
    ok = (ratios["dcn_overlap"] >= 1.25 and ratios["bisect_overlap"] >= 1.25
          and abs(ratios["ring_disjoint"] - 1.0) < 0.02)
    print(f"# congestion visible to event backend only: {ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
