"""Fabric contention benchmark — congestion the analytic model cannot see.

The analytic backend prices every collective against an idle fabric: two
concurrent collectives that share a link are each billed as if they
owned it.  The event backend queues transfers on per-link components, so
overlap costs real simulated time.  Three scenarios, each a multi-tenant
trace (two disjoint device sets replaying different programs through one
``System``):

  dcn_overlap      two pod-axis all-reduce pairs share a pod's DCN uplink
  bisect_overlap   two 8-chip block all-to-alls share the pod bisection
  ring_disjoint    control: disjoint x-rings share nothing (ratio ~1)

Prints name,analytic_us,event_us,event/analytic CSV and exits non-zero
unless the overlapped scenarios show a >=1.25x congestion effect while
the control stays within 2%: the separation between backends is the
deliverable, not a point estimate.

A second section measures how well the lookahead scheduler parallelizes
event-fabric *replay* now that fabric legs carry latency (each chip's
DMA + links is its own cluster): a multi-tenant, event-dense trace runs
under serial/batch/lookahead (bit-identity asserted) and the results —
wall clock plus the paper-style *architectural* speedup (critical-path
events at N workers vs total events; under CPython's GIL threads add no
physical parallelism, so the architectural number is the Fig. 8-analog
deliverable, exactly as the paper reports core-count speedup for its Go
runtime) — merge into ``BENCH_fabric.json`` under ``"replay"``.  Exits
non-zero unless the architectural lookahead-over-serial speedup at 4
workers is >= 1.5x with all schedulers bit-identical.

A third section reruns the replay under ``executor="procs"`` — shard-
resident worker processes, the backend that converts architectural
parallelism into real cores (paper Fig. 9 territory) — and merges it
under ``"replay_procs"`` together with a machine calibration
(``cpu_count``, measured 2-process scaling, pipe round-trip) so wall
ratios are attributable to the host.  The procs wall-ratio gate adapts
to that calibration: on a capable host (>= 4 cores that actually
scale, sub-50us pipes) the gate is the paper-style <= 0.67; on shared/
throttled CI containers — where even two pure-CPU-bound processes may
deliver < 1.3x aggregate and a pipe round-trip costs ~200us, making
*any* per-round message-passing speedup physically impossible — it
degrades to a lenient regression canary, and the recorded calibration
fields say exactly why.

Both replay sections also run the **bounded-lag** scheduler
(``scheduler="bounded"``, docs/engine.md "Bounded lag"): per-cluster
windows replace the global round barrier, so the replay trace commits
in ~2x fewer globally synchronized rounds (``rounds_lookahead`` vs
``rounds_bounded`` in the BENCH sections) while staying bit-identical
to serial.  The calibration block records ``ring_rtt_us`` next to
``pipe_rtt_us`` — the shared-memory SPSC ring transport the procs
executor prefers (``transport`` field) vs the pipe fallback.
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import sys
import time

from repro.core import LookaheadScheduler, SystemSpec, System
from repro.core.system import _RunOp

SPEC = SystemSpec(pod_shape=(4, 4), num_pods=2)


def _coll(name, kind, nbytes, group):
    return _RunOp(kind="collective", name=name, coll_kind=kind,
                  bytes=nbytes, group=(tuple(group),))


def _run(fabric, tenants):
    """tenants: list of (runop, devices); returns end-to-end seconds."""
    system = System(SPEC, fabric=fabric)
    for op, devices in tenants:
        system.load_trace([op], devices)
    return system.run()["time_s"]


def scenarios():
    return {
        "dcn_overlap": [
            (_coll("arA", "all-reduce", 1e7, [0, 16]), [0, 16]),
            (_coll("arB", "all-reduce", 1e7, [1, 17]), [1, 17]),
        ],
        "bisect_overlap": [
            (_coll("a2aA", "all-to-all", 4e6, range(8)), list(range(8))),
            (_coll("a2aB", "all-to-all", 4e6, range(8, 16)),
             list(range(8, 16))),
        ],
        "ring_disjoint": [
            (_coll("arA", "all-reduce", 1e7, [0, 1, 2, 3]), [0, 1, 2, 3]),
            (_coll("arB", "all-reduce", 1e7, [4, 5, 6, 7]), [4, 5, 6, 7]),
        ],
    }


# -- event-fabric replay parallelism (lookahead vs serial) -------------------

def _tenant_ops(tid: int, rounds: int) -> tuple:
    """One tenant: an 8-chip block replaying `rounds` x (compute segment
    + ring all-reduce + all-gather).  Per-tenant flop/byte scaling
    staggers the tenants' timestamps, so same-timestamp batching finds
    little parallelism and the lookahead window has to earn it."""
    devs = tuple(range(8 * tid, 8 * tid + 8))
    ops = []
    for r in range(rounds):
        ops.append(_RunOp(kind="compute", name=f"seg{tid}_{r}",
                          flops=2e9 * (1.0 + 0.37 * tid), hbm_bytes=1e6))
        ops.append(_coll(f"ar{tid}_{r}", "all-reduce",
                         1e6 * (1.0 + 0.23 * tid), devs))
        ops.append(_coll(f"ag{tid}_{r}", "all-gather",
                         5e5 * (1.0 + 0.31 * tid), devs))
    return ops, list(devs)


def _replay_once(scheduler, workers: int = 4, record: bool = False,
                 tenants: int = 4, rounds: int = 6, executor=None):
    sched = scheduler
    if record:
        sched = LookaheadScheduler(max_workers=workers)
        sched.record_group_sizes = True
    system = System(SPEC, fabric="event", scheduler=sched,
                    max_workers=workers, executor=executor)
    for tid in range(tenants):
        ops, devs = _tenant_ops(tid, rounds)
        system.load_trace(ops, devs)
    t0 = time.perf_counter()
    res = system.run()
    wall = time.perf_counter() - t0
    state = (res, system.fabric.link_utilization(),
             system.fabric.link_report())
    return state, system.engine, wall


def _architectural_speedup(round_groups, workers: int) -> float:
    """Critical-path events at `workers` cores vs total events, using the
    pool's actual sticky cluster->worker assignment (worker = cluster id
    mod workers).  ``round_groups`` holds per-round tuples of
    (cluster id, events executed) pairs."""
    total = critical = 0
    for groups in round_groups:
        per_worker = [0] * workers
        for gid, n in groups:
            total += n
            per_worker[gid % workers] += n
        critical += max(per_worker)
    return total / max(1, critical)


def replay_speedup(workers: int = 4, tenants: int = 4,
                   rounds: int = 6, repeat: int = 16) -> dict:
    """Wall clocks are the best of ``repeat`` *interleaved* repetitions
    (serial, batch, lookahead round-robin): single-shot timings on a
    small shared CI host swing 30%+ with neighbor noise, and
    interleaving keeps a noise burst from biasing one scheduler's
    number.  The wall *ratio* is the median of per-repetition ratios --
    adjacent runs share their noise window, and a median of ratios is
    robust to a quiet slice that only one scheduler's best-of happened
    to catch (min/min is not).  Bit-identity against the serial oracle
    is asserted on every repetition."""
    names = ("serial", "batch", "lookahead", "bounded")
    best = {}
    walls = {n: [] for n in names}
    engines = {}
    oracle = None
    identical = True
    for _ in range(max(1, repeat)):
        for sched in names:
            w = 1 if sched == "serial" else workers
            state, eng, wall = _replay_once(sched, workers=w,
                                            tenants=tenants, rounds=rounds)
            if oracle is None:
                oracle = state
            identical &= state == oracle
            walls[sched].append(wall)
            if sched not in best or wall < best[sched]:
                best[sched] = wall
            engines[sched] = eng
    eng_s = engines["serial"]
    rows = {"events": eng_s.events_processed, "workers": workers,
            "wall_serial_s": round(best["serial"], 4),
            "events_per_sec_serial": round(
                eng_s.events_processed / best["serial"])}
    for sched in ("batch", "lookahead", "bounded"):
        eng = engines[sched]
        n_rounds = len(eng.window_widths or eng.batch_widths)
        rows[f"wall_{sched}{workers}_s"] = round(best[sched], 4)
        rows[f"events_per_sec_{sched}{workers}"] = round(
            eng.events_processed / best[sched])
        rows[f"rounds_{sched}"] = n_rounds
        rows[f"rounds_per_sec_{sched}{workers}"] = round(
            n_rounds / best[sched])
        rows.update(sync_overhead_fields(
            f"sync_overhead_us_per_round_{sched}",
            best[sched], best["serial"], n_rounds))
    for sched in ("lookahead", "bounded"):
        ratios = sorted(l / s for l, s in zip(walls[sched],
                                              walls["serial"]))
        rows[f"wall_ratio_{sched}4_over_serial"] = round(
            ratios[len(ratios) // 2], 2)
    # the bounded-lag deliverable: global synchronization rounds removed
    rows["rounds_reduction_bounded_vs_lookahead"] = round(
        rows["rounds_lookahead"] / max(1, rows["rounds_bounded"]), 2)
    state, eng, _ = _replay_once("lookahead", workers=workers, record=True,
                                 tenants=tenants, rounds=rounds)
    identical &= state == oracle
    rows["bit_identical"] = identical
    rows["clusters_busy_max"] = max(
        (len(g) for g in eng.round_group_sizes), default=0)
    rows["speedup_lookahead_vs_serial_4w"] = round(
        _architectural_speedup(eng.round_group_sizes, workers), 2)
    return rows


def _burn(n: int) -> int:
    s = 0
    for i in range(n):
        s += i * i
    return s


def machine_calibration(n: int = 1_500_000) -> dict:
    """How much multi-process speedup this host can physically deliver.

    ``mp_scaling_2p`` is the aggregate throughput of two concurrent
    CPU-bound processes relative to one (2.0 = two real cores, ~1.0 =
    one core / a fully throttled cgroup); ``pipe_rtt_us`` is a small-
    message duplex pipe round-trip.  Recorded next to every procs
    wall ratio so a regression is attributable to code vs host."""
    t0 = time.perf_counter()
    _burn(n)
    one = time.perf_counter() - t0
    ctx = multiprocessing.get_context("fork")
    procs = [ctx.Process(target=_burn, args=(n,)) for _ in range(2)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    two = time.perf_counter() - t0

    def _echo(conn):
        while True:
            b = conn.recv_bytes()
            if b == b"q":
                break
            conn.send_bytes(b)

    parent, child = ctx.Pipe(duplex=True)
    proc = ctx.Process(target=_echo, args=(child,), daemon=True)
    proc.start()
    child.close()
    msg = b"x" * 256
    for _ in range(50):                      # warm
        parent.send_bytes(msg)
        parent.recv_bytes()
    reps = 400
    t0 = time.perf_counter()
    for _ in range(reps):
        parent.send_bytes(msg)
        parent.recv_bytes()
    rtt = (time.perf_counter() - t0) / reps
    parent.send_bytes(b"q")
    proc.join(timeout=5)

    # Same echo protocol over the procs executor's shared-memory ring
    # transport, so pipe_rtt_us and ring_rtt_us are directly comparable.
    # On a multi-core host the ring wins (no syscall per message); on a
    # single-CPU host both pay a context switch and come out at parity.
    try:
        from repro.core.engine.executor.rings import ring_rtt_us
        ring = ring_rtt_us()
        ring = None if ring != ring else round(ring, 1)   # NaN -> None
    except Exception:                # shared_memory unavailable
        ring = None
    return {"cpu_count": os.cpu_count(),
            "mp_scaling_2p": round(2 * one / two, 2),
            "pipe_rtt_us": round(rtt * 1e6, 1),
            "ring_rtt_us": ring}


def procs_gate_ratio(cal: dict) -> float:
    """The wall-ratio bound the procs replay is gated against.

    A host with >= 4 cores that genuinely scale and fast pipes must hit
    the paper-style >= 1.5x real speedup (ratio <= 0.67).  Anything
    weaker cannot, by arithmetic: per-round message passing costs
    ~2 x pipe_rtt on the critical path and the handler work can shrink
    by at most ``mp_scaling_2p``, so on a throttled 2-vCPU container
    the gate degrades to a regression canary while the calibration
    fields explain the host."""
    capable = ((cal["cpu_count"] or 1) >= 4
               and cal["mp_scaling_2p"] >= 1.6
               and cal["pipe_rtt_us"] <= 50)
    # The canary bound is deliberately loose: on hosts this weak the
    # measured ratio itself swings ~1.5x with neighbor load (observed
    # 8-12x on a 2-vCPU container whose own calibration drifts between
    # runs), so only order-of-magnitude regressions are actionable.
    return 0.67 if capable else 25.0


def bounded_gate_ratio(cal: dict) -> float:
    """Host-adaptive wall-ratio bound for the bounded-lag scheduler on
    the *threads* executor.  Bounded-lag pays a per-round horizon
    computation (EIT relaxation over the cluster graph) to buy fewer,
    wider rounds; on a capable multi-core host the fewer barriers win
    and the ratio must stay near lookahead's, while on a single-CPU /
    throttled container the horizon work is pure overhead on the one
    core and only order-of-magnitude regressions are actionable.  The
    deterministic deliverables (bit-identity, rounds_bounded <
    rounds_lookahead) are gated unconditionally either way."""
    capable = ((cal["cpu_count"] or 1) >= 4
               and cal["mp_scaling_2p"] >= 1.6)
    return 2.0 if capable else 8.0


def replay_speedup_procs(workers: int = 4, tenants: int = 4,
                         rounds: int = 6, repeat: int = 5) -> dict:
    """Replay under ``executor="procs"``: shard-resident worker
    processes execute the rounds, the parent only routes windows and
    commits.  Bit-identity against the serial oracle is asserted every
    repetition (it covers the cross-process payload routing AND the
    end-of-run state sync -- link utilization is read from the parent
    replica).  Walls are best-of-``repeat`` interleaved with serial;
    the ratio is the median of per-repetition ratios, like the threads
    section.  The bounded-lag scheduler rides along: same worker
    processes, but windows advance per cluster instead of behind one
    global barrier, so the per-round IPC tax is paid ~2x less often."""
    best = {}
    walls = {"serial": [], "lookahead": [], "bounded": []}
    engines = {}
    oracle = None
    identical = True
    for _ in range(max(1, repeat)):
        for sched, ex, w in (("serial", None, 1),
                             ("lookahead", "procs", workers),
                             ("bounded", "procs", workers)):
            state, eng, wall = _replay_once(sched, workers=w,
                                            tenants=tenants, rounds=rounds,
                                            executor=ex)
            if oracle is None:
                oracle = state
            identical &= state == oracle
            walls[sched].append(wall)
            if sched not in best or wall < best[sched]:
                best[sched] = wall
            engines[sched] = eng
    eng_l = engines["lookahead"]
    rows = {"executor": "procs", "workers": workers,
            "processes": eng_l.scheduler.executor.processes
            if eng_l.scheduler.executor else workers,
            "transport": getattr(eng_l.scheduler.executor, "transport",
                                 None),
            "events": engines["serial"].events_processed,
            "wall_serial_s": round(best["serial"], 4),
            "events_per_sec_serial": round(
                engines["serial"].events_processed / best["serial"]),
            "bit_identical": identical}
    for sched in ("lookahead", "bounded"):
        eng = engines[sched]
        n_rounds = len(eng.window_widths or eng.batch_widths)
        rows[f"wall_{sched}4_s"] = round(best[sched], 4)
        rows[f"events_per_sec_{sched}4"] = round(
            eng.events_processed / best[sched])
        rows[f"rounds_{sched}"] = n_rounds
        rows[f"rounds_per_sec_{sched}4"] = round(n_rounds / best[sched])
        rows.update(sync_overhead_fields(
            f"sync_overhead_us_per_round_{sched}",
            best[sched], best["serial"], n_rounds))
        ratios = sorted(l / s for l, s in zip(walls[sched],
                                              walls["serial"]))
        rows[f"wall_ratio_{sched}4_over_serial"] = round(
            ratios[len(ratios) // 2], 2)
    rows.update(machine_calibration())
    return rows


def sync_overhead_fields(key: str, wall: float, serial_wall: float,
                         n_rounds: int) -> dict:
    """Per-round synchronization tax over the serial oracle, amortized
    across this scheme's rounds.  Interleaved best-of-N walls still
    leave the delta of two noisy minima: when the parallel scheduler's
    best repetition lands in a quieter slice than serial's, the raw
    delta goes *negative*, which is measurement noise, not a negative
    tax.  The headline field is clamped at 0; the signed value is kept
    in ``<key>_raw`` so the noise floor stays visible in the trend."""
    raw = 1e6 * (wall - serial_wall) / max(1, n_rounds)
    return {key: round(max(0.0, raw), 2), key + "_raw": round(raw, 2)}


def merge_bench(update: dict) -> str:
    """Read-merge-write BENCH_fabric.json: this benchmark owns the
    "replay" section, engine_scalability owns "runs" -- neither may
    clobber the other (both import this one helper)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_fabric.json")
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(update)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="perf smoke: replay section only, on a smaller "
                         "trace; gates wall_lookahead4/wall_serial <= 1.3 "
                         "(CI-lenient) and writes the 'replay_quick' "
                         "BENCH section instead of 'replay'")
    args = ap.parse_args(argv)

    if args.quick:
        replay = replay_speedup(tenants=3, rounds=3)
        procs = replay_speedup_procs(tenants=3, rounds=3, repeat=3)
        path = merge_bench({"replay_quick": replay,
                            "replay_quick_procs": procs})
        ratio = replay["wall_ratio_lookahead4_over_serial"]
        bratio = replay["wall_ratio_bounded4_over_serial"]
        bgate = bounded_gate_ratio(procs)
        pratio = procs["wall_ratio_lookahead4_over_serial"]
        pbratio = procs["wall_ratio_bounded4_over_serial"]
        pgate = procs_gate_ratio(procs)
        eps = replay["events_per_sec_serial"]
        ring = procs.get("ring_rtt_us")
        print(f"# replay (quick): {replay['events']} events, serial "
              f"{eps} events/s, lookahead4/serial wall ratio {ratio:.2f} "
              f"(bit_identical={replay['bit_identical']}); wrote {path}")
        print(f"# replay (quick, bounded): rounds "
              f"{replay['rounds_lookahead']} -> {replay['rounds_bounded']} "
              f"({replay['rounds_reduction_bounded_vs_lookahead']:.2f}x "
              f"fewer barriers), wall ratio {bratio:.2f} "
              f"(gate <= {bgate:.2f})")
        print(f"# replay (quick, procs): wall ratio {pratio:.2f}, "
              f"bounded {pbratio:.2f} (gate <= {pgate:.2f}; transport "
              f"{procs['transport']}; host: {procs['cpu_count']} cpus, "
              f"2p scaling {procs['mp_scaling_2p']:.2f}x, pipe rtt "
              f"{procs['pipe_rtt_us']:.0f}us, ring rtt "
              f"{ring if ring is not None else 'n/a'}us; "
              f"bit_identical={procs['bit_identical']})")
        ok = (replay["bit_identical"] and ratio is not None and ratio <= 1.3
              and replay["rounds_bounded"] < replay["rounds_lookahead"]
              and bratio <= bgate
              and procs["bit_identical"] and pratio <= pgate
              and pbratio <= pgate
              and procs["rounds_bounded"] < procs["rounds_lookahead"])
        return 0 if ok else 1

    print("name,analytic_us,event_us,ratio")
    ratios = {}
    for name, tenants in scenarios().items():
        t_a = _run("analytic", tenants)
        t_e = _run("event", tenants)
        ratios[name] = t_e / t_a
        print(f"{name},{t_a * 1e6:.3f},{t_e * 1e6:.3f},{ratios[name]:.3f}")
    ok = (ratios["dcn_overlap"] >= 1.25 and ratios["bisect_overlap"] >= 1.25
          and abs(ratios["ring_disjoint"] - 1.0) < 0.02)
    print(f"# congestion visible to event backend only: {ok}")

    replay = replay_speedup()
    procs = replay_speedup_procs()
    path = merge_bench({"replay": replay, "replay_procs": procs})
    speedup = replay["speedup_lookahead_vs_serial_4w"]
    wall_ratio = replay["wall_ratio_lookahead4_over_serial"]
    bratio = replay["wall_ratio_bounded4_over_serial"]
    bgate = bounded_gate_ratio(procs)
    pratio = procs["wall_ratio_lookahead4_over_serial"]
    pbratio = procs["wall_ratio_bounded4_over_serial"]
    pgate = procs_gate_ratio(procs)
    ring = procs.get("ring_rtt_us")
    print(f"# replay: {replay['events']} events, serial "
          f"{replay['events_per_sec_serial']} events/s, lookahead "
          f"architectural speedup over serial at 4 workers: {speedup:.2f}x, "
          f"lookahead4/serial wall ratio {wall_ratio:.2f} "
          f"(bit_identical={replay['bit_identical']}); wrote {path}")
    print(f"# replay (bounded-lag): global rounds "
          f"{replay['rounds_lookahead']} -> {replay['rounds_bounded']} "
          f"({replay['rounds_reduction_bounded_vs_lookahead']:.2f}x fewer "
          f"barriers), wall ratio {bratio:.2f} (gate <= {bgate:.2f})")
    print(f"# replay (procs, {procs['processes']} worker processes, "
          f"transport {procs['transport']}): "
          f"wall ratio {pratio:.2f}, bounded {pbratio:.2f} "
          f"(gate <= {pgate:.2f}; host: "
          f"{procs['cpu_count']} cpus, 2p scaling "
          f"{procs['mp_scaling_2p']:.2f}x, pipe rtt "
          f"{procs['pipe_rtt_us']:.0f}us, ring rtt "
          f"{ring if ring is not None else 'n/a'}us; "
          f"bit_identical={procs['bit_identical']})")
    ok = (ok and replay["bit_identical"] and speedup >= 1.5
          and wall_ratio is not None and wall_ratio <= 1.3
          and replay["rounds_bounded"] <= 400      # issue #6: 789 -> <=400
          and replay["rounds_bounded"] < replay["rounds_lookahead"]
          and bratio <= bgate
          and procs["bit_identical"] and pratio <= pgate
          and pbratio <= pgate
          and procs["rounds_bounded"] < procs["rounds_lookahead"])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
