"""Vectorized pricing + fleet-sweep throughput -> BENCH_fabric.json "sweep".

Two claims from the vectorized-analytic work, measured and gated:

1. **Pricing parity + speedup**: ``repro.fabric.pricing.price`` over a
   >= 1,000-point (config x traffic) grid must be bit-EQUAL to looping
   ``Topology.price`` (the scalar oracle) point by point, and >= 50x
   faster.  Parity is exact float equality -- the kernels mirror the
   scalar expression trees -- so any drift is a bug, not tolerance.
2. **Sweep machinery**: the quick grid sweeps end to end through
   ``tools.sweep`` worker processes; a repeat run skips every config
   via the result cache, and a forced rerun hits the content-hashed
   plan cache on disk.  The merged results file must parse and be
   queryable.

The gated numbers land in the ``sweep`` section of BENCH_fabric.json
(merge-written; "replay"/"runs" sections belong to other benchmarks):

  grid_points                points priced in the parity/speedup grid
  configs_per_sec            quick-sweep simulation throughput
  cache_hit_rate             plan-cache hit rate on the forced rerun
  pricing_speedup_vs_scalar  vectorized-vs-looped-scalar speedup

Usage::

  PYTHONPATH=src:. python -m benchmarks.sweep_throughput [--quick]
"""
from __future__ import annotations

import argparse
import itertools
import os
import shutil
import sys
import tempfile
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(REPO, "src"), REPO):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import SystemSpec                          # noqa: E402
from repro.core.hw import ChipSpec                          # noqa: E402
from repro.core.topology import Topology                    # noqa: E402
from repro.fabric import pricing                            # noqa: E402
from benchmarks.fabric_contention import merge_bench        # noqa: E402
from tools import sweep                                     # noqa: E402

MIN_SPEEDUP = 50.0
MIN_GRID = 1000


def _parity_grid():
    """(config x traffic) grid: every kind x group-class x payload x
    group-size point, crossed with several SystemSpecs."""
    specs = [
        SystemSpec(pod_shape=(4, 4)),
        SystemSpec(pod_shape=(8, 8), num_pods=2),
        SystemSpec(pod_shape=(4, 8), num_pods=4),
        SystemSpec(pod_shape=(4, 4),
                   chip=ChipSpec(ici_link_bandwidth=25e9)),
    ]
    payloads = [64.0, 4096.0, 1e6, 4e6, 64e6, 1e9]
    sizes = [1, 2, 4, 8, 16, 64]
    points = []          # (spec_idx, kind_code, cls_code, B, n)
    for si, spec in enumerate(specs):
        for kind, cls, B, n in itertools.product(
                pricing.KINDS, pricing.CLASSES, payloads, sizes):
            if cls == "cross_pod" and spec.num_pods < 2:
                continue
            points.append((si, pricing.KIND_CODES[kind],
                           pricing.CLASS_CODES[cls], B, float(n)))
    return specs, points


def pricing_parity() -> dict:
    """Exact-equality check of the vectorized kernels against the
    scalar oracle (``Topology.price_point``) on the exhaustive grid --
    including (class, n) combinations no real group produces."""
    specs, points = _parity_grid()
    si = np.array([p[0] for p in points])
    kind = np.array([p[1] for p in points])
    cls = np.array([p[2] for p in points])
    B = np.array([p[3] for p in points])
    n = np.array([p[4] for p in points])
    stacked = pricing.FabricParams.stack(
        [specs[i] for i in si])       # one param row per point
    topos = [Topology(s) for s in specs]
    scalar = np.array([
        topos[si[i]].price_point(pricing.KINDS[kind[i]],
                                 pricing.CLASSES[cls[i]],
                                 float(B[i]), int(n[i]))
        for i in range(len(points))])
    vec = pricing.price(kind, cls, B, n, stacked)
    exact = bool(np.array_equal(scalar, vec))
    if not exact:
        for i in np.nonzero(scalar != vec)[0][:5]:
            print(f"  MISMATCH {points[i]}: scalar={scalar[i]!r} "
                  f"vec={vec[i]!r}")
    return {"parity_grid_points": len(points), "exact_parity": exact}


def _real_groups(spec: SystemSpec):
    """Representative replica groups of every class the spec supports:
    x rows, y columns, 2-D blocks, and cross-pod pairs -- actual member
    lists, so the scalar baseline pays the same ``classify_group`` walk
    the pre-vectorization sweep paid on every single call."""
    Y, X = spec.pod_shape
    cpp = spec.chips_per_pod
    groups = [[y * X + x for x in range(X)] for y in range(Y)]          # rows
    groups += [[y * X + x for y in range(Y)] for x in range(X)]         # cols
    groups += [list(range(2 * X)), list(range(cpp))]                    # blocks
    if spec.num_pods > 1:
        groups += [[k + p * cpp for p in range(spec.num_pods)]
                   for k in range(4)]                                   # x-pod
    return groups


def pricing_speedup(repeats: int = 3) -> dict:
    """Best-of-N wall clock: vector-pricing a declarative
    (kind x group x payload) grid vs the looped scalar path
    (``Topology.price`` once per point, classify included) that was the
    only way to price before vectorization.  Results must stay exactly
    equal point by point."""
    specs = [SystemSpec(pod_shape=(8, 8)),
             SystemSpec(pod_shape=(8, 8), num_pods=2)]
    payloads = np.geomspace(64.0, 4e9, 40)
    t_vec = t_scalar = 0.0
    grid_points = 0
    for spec in specs:
        topo = Topology(spec)
        groups = _real_groups(spec)
        grid_points += len(pricing.KINDS) * len(groups) * len(payloads)

        # vectorized: classify each distinct group once (memoized -- a
        # sweep prices the same groups at every timestep, so steady
        # state is the warm memo), then cross kinds x groups x payloads
        # into flat arrays with repeat/tile -- O(unique groups) Python,
        # O(points) numpy.
        best = float("inf")
        memo: dict = {}
        for _ in range(repeats):
            t0 = time.perf_counter()
            cls_u = np.array([pricing.classify_cached(topo, memo, tuple(g))
                              for g in groups])
            n_u = np.array([float(len(g)) for g in groups])
            nk, ng, nb = len(pricing.KINDS), len(groups), len(payloads)
            kind = np.repeat(np.arange(nk), ng * nb)
            cls = np.tile(np.repeat(cls_u, nb), nk)
            n = np.tile(np.repeat(n_u, nb), nk)
            B = np.tile(payloads, nk * ng)
            vec = pricing.price(kind, cls, B, n,
                                pricing.FabricParams.from_spec(spec))
            best = min(best, time.perf_counter() - t0)
        t_vec += best

        t0 = time.perf_counter()
        scalar = [topo.price(k, float(b), [g])
                  for k in pricing.KINDS for g in groups for b in payloads]
        t_scalar += time.perf_counter() - t0
        assert np.array_equal(np.asarray(scalar), vec), \
            "vectorized grid pricing drifted from the scalar loop"
    return {"grid_points": grid_points,
            "t_scalar_s": round(t_scalar, 4), "t_vec_s": round(t_vec, 6),
            "pricing_speedup_vs_scalar": round(t_scalar / t_vec, 1)}


def sweep_smoke(workers: int = 2) -> dict:
    """Quick-grid sweep through real worker processes + both cache
    tiers; returns throughput/caching numbers for the sweep section."""
    d = tempfile.mkdtemp(prefix="sweep_bench_")
    out = os.path.join(d, "results.json")
    cache = os.path.join(d, "plancache")
    try:
        first = sweep.run_sweep(sweep.GRIDS["quick"], out=out,
                                workers=workers, cache_dir=cache,
                                quiet=True)
        assert first["errors"] == 0, f"sweep errors: {first}"
        # repeat run: every row must come from the result cache
        again = sweep.run_sweep(sweep.GRIDS["quick"], out=out,
                                workers=workers, cache_dir=cache,
                                quiet=True)
        assert again["simulated"] == 0, f"result cache missed: {again}"
        assert again["result_cache_hits"] == first["grid_points"]
        # forced rerun: simulations repeat but decompose() doesn't --
        # fresh workers hit the on-disk plan cache
        forced = sweep.run_sweep(sweep.GRIDS["quick"], out=out,
                                 workers=workers, cache_dir=cache,
                                 force=True, quiet=True)
        assert forced["errors"] == 0
        data = sweep.load_results(out)          # must parse + query
        rows = sweep.query_rows(data, {"fabric": "event"},
                                ["scenario", "time_s"])
        assert rows and all("time_s" in r for r in rows)
        return {"sweep_grid_points": first["grid_points"],
                "configs_per_sec": first["configs_per_sec"],
                "cache_hit_rate": forced["plan_cache_hit_rate"],
                "repeat_result_cache_hits": again["result_cache_hits"]}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: same gates, fewer timing repeats "
                         "and fewer sweep workers")
    args = ap.parse_args(argv)

    parity = pricing_parity()
    print(f"pricing_parity,{parity['parity_grid_points']},"
          f"exact={parity['exact_parity']}")
    speed = pricing_speedup(repeats=2 if args.quick else 5)
    print(f"pricing_speedup,{speed['t_vec_s'] * 1e6:.1f}us,"
          f"{speed['pricing_speedup_vs_scalar']}x on "
          f"{speed['grid_points']} points")

    smoke = sweep_smoke(workers=2 if args.quick else 4)
    print(f"sweep_quick,{smoke['sweep_grid_points']} points,"
          f"{smoke['configs_per_sec']} configs/s")
    print(f"sweep_caches,plan_hit_rate={smoke['cache_hit_rate']},"
          f"result_hits={smoke['repeat_result_cache_hits']}")

    section = {
        "grid_points": speed["grid_points"],
        "configs_per_sec": smoke["configs_per_sec"],
        "cache_hit_rate": smoke["cache_hit_rate"],
        "pricing_speedup_vs_scalar": speed["pricing_speedup_vs_scalar"],
        "exact_parity": parity["exact_parity"],
        "parity_grid_points": parity["parity_grid_points"],
        "written_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    path = merge_bench({"sweep": section})
    print(f"# merged 'sweep' section -> {path}")

    ok = (parity["exact_parity"]
          and speed["grid_points"] >= MIN_GRID
          and speed["pricing_speedup_vs_scalar"] >= MIN_SPEEDUP
          and smoke["cache_hit_rate"] > 0.95)
    if not ok:
        print(f"# GATE FAILED: need exact parity on >= {MIN_GRID} points, "
              f">= {MIN_SPEEDUP}x speedup, cache hit rate > 0.95; "
              f"got {section}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
