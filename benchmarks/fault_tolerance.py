"""Fault-tolerance what-ifs on the system model (paper Sec. 4.1 hooks).

Uses the FaultInjector hook + collective-deadline detection to quantify:
  * straggler amplification: one chip at kx slowdown -> whole-step cost
    (the collective barrier makes it global — the paper's lesson);
  * failure detection latency: how long until survivors observe a
    collective timeout after a chip dies;
  * checkpoint-overhead trade-off: optimal checkpoint interval per MTBF
    (Young's approximation) for the measured step/save times;
  * serve-through-faults (``--quick``, the CI gate): a mid-trace chip
    kill with recovery enabled must end with zero stuck requests and
    goodput restored to within 5% of pre-fault — the quick gates from
    ``benchmarks/serve_recovery.py``, run here so the workflow checks
    detection *and* recovery in one step.

``--quick`` trims the what-ifs (fewer straggler points, shorter
workload) and exits nonzero if the recovery gate fails.

Run as: PYTHONPATH=src:. python -m benchmarks.fault_tolerance [--quick]
"""
from __future__ import annotations

import argparse
import math
import sys

from repro.core import SystemSpec, simulate, what_if_failure, \
    what_if_straggler
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp


def _workload(n_devices: int, layers: int = 16) -> HloCost:
    cost = HloCost()
    groups = [list(range(n_devices))]
    for i in range(layers):
        cost.trace.append(TraceOp("compute", f"seg{i}", flops=2e10,
                                  hbm_bytes=5e8))
        rec = CollectiveRecord("all-reduce", f"ar{i}", 5e7, int(5e7),
                               int(5e7), groups)
        cost.collectives.append(rec)
        cost.trace.append(TraceOp("collective", f"ar{i}", collective=rec))
    return cost


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: trimmed what-ifs + the serving "
                         "recovery gate (nonzero exit on failure)")
    args = ap.parse_args(argv)

    spec = SystemSpec(pod_shape=(4, 4))
    cost = _workload(16, layers=8 if args.quick else 16)
    print("name,us_per_call,derived")

    base = simulate(cost=cost, spec=spec, device_limit=None)
    print(f"step_base,{base.time_s * 1e6:.1f},util={base.compute_util:.2f}")
    for k in ((2.0,) if args.quick else (1.5, 2.0, 4.0)):
        _, slow = what_if_straggler(cost, spec, device=5, slow_factor=k,
                                    device_limit=None)
        print(f"straggler_x{k},{slow.time_s * 1e6:.1f},"
              f"amplification={slow.time_s / base.time_s:.2f}")

    rep = what_if_failure(cost, spec, device=3, fail_at_s=0.0,
                          deadline_s=base.time_s / 4, device_limit=None)
    print(f"failure_detect,{rep.time_s * 1e6:.1f},"
          f"timeouts={rep.collective_timeouts}"
          f"|aborted={rep.devices_aborted}")

    # Young's optimal checkpoint interval for measured costs
    step_s = base.time_s
    save_s = 30.0                      # sharded ckpt write (measured class)
    for mtbf_h in (6.0, 24.0):
        interval = math.sqrt(2 * save_s * mtbf_h * 3600)
        print(f"ckpt_interval_mtbf{mtbf_h:.0f}h,"
              f"{interval:.0f},steps={interval / step_s:.0f}")

    if args.quick:
        # the recovery gate: chip kill mid-trace, serve *through* it
        from benchmarks.serve_recovery import AFFECTED_TENANT, run_quick_gate
        gate = run_quick_gate()
        for fabric in ("analytic", "event"):
            a = gate["anatomy"][fabric]
            print(f"recovery_{fabric},"
                  f"{a['time_to_recovery_s'] * 1e6:.1f},"
                  f"stuck={a['stuck']}|retries={a['retries']}"
                  f"|recoveries={a['recoveries']}"
                  f"|avail_t{AFFECTED_TENANT}="
                  f"{a['tenant_availability'][AFFECTED_TENANT]}"
                  f"|restore={a['restore_ratio']}")
        ident = gate["identity"]
        print(f"# mid-recovery identity: {ident['combos_per_fabric']} "
              f"combos/fabric, identical={ident['bit_identical']}, "
              f"cross-fabric={ident['cross_fabric_behavioral']}")
        print(f"# recovery gates {'pass' if gate['ok'] else 'FAIL'}")
        return 0 if gate["ok"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
