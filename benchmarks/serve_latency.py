"""Open-loop serving latency: the p50/p99-vs-offered-load knee, the
scheduler x executor bit-identity matrix, and the straggler-link tail.

Three sections, merged into ``BENCH_serve.json`` (read-merge-write, the
BENCH idiom):

* ``latency_curve`` -- p50/p99/goodput at each offered load on both
  fabrics.  Below the knee latency is flat; past the aggregate service
  capacity the queue grows for the whole trace window and p99 explodes.
  The knee must be *visible*: p99 at the top load >= ``KNEE_GATE`` x
  p99 at the bottom load.
* ``bit_identity`` -- one serial oracle per fabric, then every round
  scheduler x executor combination must reproduce its
  ``ServeReport.summary()`` exactly (the serving analog of the replay
  determinism gate).
* ``fault_tail`` -- a straggler ICI link on tenant 0's ring under the
  event fabric: global p99 and tenant 0's p99 must rise strictly above
  healthy while tenant 1 (disjoint links) is bit-unchanged.  The same
  plan on the analytic fabric is untargetable (ValueError) -- asserted.

All gates are deterministic simulation quantities (no wall-clock), so
they hold on any host.  ``--quick`` runs a smaller trace for CI and
exits nonzero if any gate fails.

Run as: PYTHONPATH=src:. python -m benchmarks.serve_latency [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import SystemSpec
from repro.serve.sim import build_scenario, run_serving

SPEC = SystemSpec(pod_shape=(2, 2))
SEED = 11
DURATION_S = 0.02
QUICK_DURATION_S = 0.008

LOADS_FULL = (250.0, 500.0, 1000.0, 2000.0, 3000.0, 4000.0, 6000.0)
LOADS_QUICK = (500.0, 2000.0, 4000.0)

SCHED_X_EXEC = [(s, e) for s in ("batch", "lookahead", "bounded")
                for e in ("threads", "procs")]
SCHED_X_EXEC_QUICK = [("batch", "threads"), ("lookahead", "procs"),
                      ("bounded", "procs")]

STRAGGLER = {"fabric.pod0.ici[0,1]+x": [(0.0, "slow", 32.0)]}
KNEE_GATE = 2.0          # p99(top load) / p99(bottom load), both fabrics
FAULT_GATE = 1.05        # faulted tenant-0 p99 / healthy tenant-0 p99


def _scenario(rate_rps: float, duration_s: float):
    scen = build_scenario(SPEC, rate_rps=rate_rps, duration_s=duration_s,
                          seed=SEED)
    assert scen is not None
    return scen


def latency_curve(loads, duration_s: float) -> dict:
    """p50/p99/goodput per offered load, analytic + event fabrics."""
    rows = []
    for rate in loads:
        scen = _scenario(rate, duration_s)
        row = {"rate_rps_per_tenant": rate}
        for fabric in ("analytic", "event"):
            t0 = time.perf_counter()
            rep = run_serving(scen, spec=SPEC, fabric=fabric)
            row[fabric] = {
                "offered": rep.offered,
                "offered_rps": round(rep.offered_rps, 1),
                "completed": rep.completed,
                "goodput_rps": round(rep.goodput_rps, 1),
                "p50_ms": round(rep.p50_s * 1e3, 4),
                "p99_ms": round(rep.p99_s * 1e3, 4),
                "queue_mean_ms": round(rep.queue_mean_s * 1e3, 4),
                "events": rep.events,
                "wall_s": round(time.perf_counter() - t0, 3),
            }
        rows.append(row)
    out = {"rows": rows}
    for fabric in ("analytic", "event"):
        lo, hi = rows[0][fabric]["p99_ms"], rows[-1][fabric]["p99_ms"]
        out[f"knee_ratio_{fabric}"] = round(hi / lo, 2) if lo else None
    return out


def bit_identity(combos, duration_s: float, rate_rps: float = 1000.0) -> dict:
    """Serial oracle per fabric; every scheduler x executor must match."""
    scen = _scenario(rate_rps, duration_s)
    results, identical = {}, True
    for fabric in ("analytic", "event"):
        oracle = run_serving(scen, spec=SPEC, fabric=fabric)
        matrix = {}
        for sched, executor in combos:
            rep = run_serving(scen, spec=SPEC, fabric=fabric,
                              scheduler=sched, executor=executor,
                              max_workers=2)
            ok = rep.summary() == oracle.summary()
            matrix[f"{sched}+{executor}"] = ok
            identical = identical and ok
        results[fabric] = {"p99_ms": round(oracle.p99_s * 1e3, 4),
                           "matrix": matrix}
    results["bit_identical"] = identical
    results["combos_per_fabric"] = len(combos)
    return results


def fault_tail(duration_s: float, rate_rps: float = 1000.0) -> dict:
    """Straggler link vs healthy on the event fabric; analytic rejects."""
    scen = _scenario(rate_rps, duration_s)
    healthy = run_serving(scen, spec=SPEC, fabric="event")
    faulted = run_serving(scen, spec=SPEC, fabric="event", faults=STRAGGLER)
    try:
        run_serving(scen, spec=SPEC, fabric="analytic", faults=STRAGGLER)
        analytic_rejects = False
    except ValueError:
        analytic_rejects = True
    t0h, t0f = healthy.tenant_p99_s[0], faulted.tenant_p99_s[0]
    return {
        "fault_plan": {k: [list(a) for a in v] for k, v in STRAGGLER.items()},
        "healthy_p99_ms": round(healthy.p99_s * 1e3, 4),
        "fault_p99_ms": round(faulted.p99_s * 1e3, 4),
        "p99_ratio_fault_over_healthy": round(
            faulted.p99_s / healthy.p99_s, 4) if healthy.p99_s else None,
        "tenant0_p99_ratio": round(t0f / t0h, 4) if t0h else None,
        "tenant1_unchanged": (faulted.tenant_p99_s[1]
                              == healthy.tenant_p99_s[1]),
        "p99_raised": faulted.p99_s > healthy.p99_s,
        "completed_preserved": faulted.completed == healthy.completed,
        "analytic_rejects_link_plan": analytic_rejects,
    }


def merge_bench(update: dict) -> str:
    """Read-merge-write BENCH_serve.json (this benchmark owns all of it,
    but quick and full runs write disjoint sections)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_serve.json")
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(update)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return path


def _gates(curve: dict, ident: dict, fault: dict) -> bool:
    return (ident["bit_identical"]
            and curve["knee_ratio_analytic"] is not None
            and curve["knee_ratio_analytic"] >= KNEE_GATE
            and curve["knee_ratio_event"] >= KNEE_GATE
            and fault["p99_raised"]
            and fault["tenant0_p99_ratio"] >= FAULT_GATE
            and fault["tenant1_unchanged"]
            and fault["completed_preserved"]
            and fault["analytic_rejects_link_plan"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer load points, shorter traces, "
                         "3 identity combos; writes *_quick sections and "
                         "gates bit-identity + knee + fault-degrades-p99")
    args = ap.parse_args(argv)

    dur = QUICK_DURATION_S if args.quick else DURATION_S
    loads = LOADS_QUICK if args.quick else LOADS_FULL
    combos = SCHED_X_EXEC_QUICK if args.quick else SCHED_X_EXEC

    curve = latency_curve(loads, dur)
    ident = bit_identity(combos, dur)
    fault = fault_tail(dur)

    suffix = "_quick" if args.quick else ""
    path = merge_bench({f"latency_curve{suffix}": curve,
                        f"bit_identity{suffix}": ident,
                        f"fault_tail{suffix}": fault})

    print("rate_rps_per_tenant,fabric,offered,p50_ms,p99_ms,goodput_rps")
    for row in curve["rows"]:
        for fabric in ("analytic", "event"):
            r = row[fabric]
            print(f"{row['rate_rps_per_tenant']:.0f},{fabric},"
                  f"{r['offered']},{r['p50_ms']},{r['p99_ms']},"
                  f"{r['goodput_rps']}")
    print(f"# knee: p99 top/bottom = {curve['knee_ratio_analytic']}x "
          f"analytic, {curve['knee_ratio_event']}x event "
          f"(gate >= {KNEE_GATE}x)")
    print(f"# bit-identity: {ident['combos_per_fabric']} scheduler x "
          f"executor combos per fabric, identical="
          f"{ident['bit_identical']}")
    print(f"# fault tail: straggler-link p99 "
          f"{fault['fault_p99_ms']}ms vs healthy "
          f"{fault['healthy_p99_ms']}ms (tenant0 ratio "
          f"{fault['tenant0_p99_ratio']}x, tenant1 unchanged="
          f"{fault['tenant1_unchanged']}, analytic rejects plan="
          f"{fault['analytic_rejects_link_plan']})")
    ok = _gates(curve, ident, fault)
    print(f"# gates {'pass' if ok else 'FAIL'}; wrote {path}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
