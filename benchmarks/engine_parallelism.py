"""Fig. 2 analog — events executable concurrently per timestamp.

The paper plots how many same-time events the AES simulation schedules
(60-100), arguing a conservative parallel engine has enough work for
4-8 cores.  We replay the MGMark-analog traces on the system model and
report the batch-width distribution of the event queue.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import SystemSpec, simulate
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp


def synthetic_workload(n_devices: int, layers: int = 12) -> HloCost:
    """AES-analog: compute-heavy partitioned segments + periodic sync."""
    cost = HloCost()
    groups = [list(range(n_devices))]
    for i in range(layers):
        cost.trace.append(TraceOp("compute", f"seg{i}", flops=5e9,
                                  hbm_bytes=2e8))
        rec = CollectiveRecord("all-reduce", f"ar{i}", 1e6, int(1e6),
                               int(1e6), groups)
        cost.collectives.append(rec)
        cost.trace.append(TraceOp("collective", f"ar{i}", collective=rec))
    return cost


def main() -> int:
    print("name,us_per_call,derived")
    for n in (16, 64, 256):
        spec = SystemSpec(pod_shape=(int(np.sqrt(n)), int(np.sqrt(n))))
        rep = simulate(cost=synthetic_workload(n), spec=spec,
                       device_limit=None)
        w = np.asarray(rep.batch_widths)
        print(f"batch_width_mean_{n}dev,{w.mean():.1f},"
              f"p50={np.percentile(w, 50):.0f}|p95={np.percentile(w, 95):.0f}"
              f"|max={w.max()}")
    # the paper's claim: enough parallelism for 4-8 cores
    ok = np.percentile(np.asarray(rep.batch_widths), 50) >= 8
    print(f"# median batch width supports >=8 workers: {ok}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
