"""Thin alias -- the Fig. 2 width-distribution benchmark moved into
:mod:`benchmarks.engine_scalability` (``run_width_distributions``).

Kept so ``python -m benchmarks.engine_parallelism`` and the historical
``from benchmarks.engine_parallelism import synthetic_workload`` import
both keep working.
"""
from __future__ import annotations

import sys

from .engine_scalability import (_dist, run_width_distributions,
                                 synthetic_workload)

__all__ = ["synthetic_workload", "run_width_distributions"]


def main() -> int:
    print("name,us_per_call,derived")
    return run_width_distributions()


if __name__ == "__main__":
    sys.exit(main())
