"""Fig. 2 analog — events executable concurrently per scheduler round.

The paper plots how many same-time events the AES simulation schedules
(60-100), arguing a conservative parallel engine has enough work for
4-8 cores.  We replay the MGMark-analog traces on the system model and
report two distributions side by side:

* batch widths  — events per same-timestamp batch (the paper's DP-5
  grouping, serial/batch schedulers);
* window widths — events per lookahead window ``[t, t + min latency)``
  (the conservative-PDES grouping of engine/lookahead.py).

Window widths dominate batch widths whenever per-device timestamps
diverge; on perfectly aligned SPMD traces they merge adjacent
timestamps and still come out wider.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import SystemSpec, simulate
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp


def synthetic_workload(n_devices: int, layers: int = 12) -> HloCost:
    """AES-analog: compute-heavy partitioned segments + periodic sync."""
    cost = HloCost()
    groups = [list(range(n_devices))]
    for i in range(layers):
        cost.trace.append(TraceOp("compute", f"seg{i}", flops=5e9,
                                  hbm_bytes=2e8))
        rec = CollectiveRecord("all-reduce", f"ar{i}", 1e6, int(1e6),
                               int(1e6), groups)
        cost.collectives.append(rec)
        cost.trace.append(TraceOp("collective", f"ar{i}", collective=rec))
    return cost


def _dist(widths) -> str:
    w = np.asarray(widths)
    return (f"p50={np.percentile(w, 50):.0f}|p95={np.percentile(w, 95):.0f}"
            f"|max={w.max()}")


def main() -> int:
    print("name,us_per_call,derived")
    rep = rep_look = None
    for n in (16, 64, 256):
        spec = SystemSpec(pod_shape=(int(np.sqrt(n)), int(np.sqrt(n))))
        cost = synthetic_workload(n)
        rep = simulate(cost=cost, spec=spec, device_limit=None)
        rep_look = simulate(cost=cost, spec=spec, device_limit=None,
                            scheduler="lookahead")
        assert rep_look.summary() == rep.summary(), "determinism violated"
        bw = np.asarray(rep.batch_widths)
        ww = np.asarray(rep_look.window_widths)
        print(f"batch_width_mean_{n}dev,{bw.mean():.1f},{_dist(bw)}")
        print(f"window_width_mean_{n}dev,{ww.mean():.1f},{_dist(ww)}")
    # the paper's claim: enough parallelism for 4-8 cores
    ok_batch = np.percentile(np.asarray(rep.batch_widths), 50) >= 8
    ok_window = np.percentile(np.asarray(rep_look.window_widths), 50) >= 8
    print(f"# median batch width supports >=8 workers: {ok_batch}")
    print(f"# median window width supports >=8 workers: {ok_window}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
