import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
"""Fig. 9 analog — U-mode vs D-mode on the MGMark-TPU suite (4 devices).

The paper's case study: for each workload, cross-device traffic and
execution time under the unified (U-MGPU) vs discrete (D-MGPU)
programming model on a 4-GPU box.  Here: jit/GSPMD vs shard_map on a
4-chip slice, traffic parsed from the compiled HLO, time from the
timeline simulator.  Expected replication of the paper's lesson:
  * Partitioned (AES/KM): both modes near-zero traffic;
  * D-mode <= U-mode traffic everywhere (explicit placement wins);
  * traffic correlates with simulated time.
"""
import sys

import jax
from repro.compat import make_auto_mesh
import jax.numpy as jnp
import numpy as np


def main() -> int:
    from repro.patterns import WORKLOADS, evaluate
    mesh = make_auto_mesh((4,), ("dev",))
    sizes = {"aes": 64 * 1024, "km": 32 * 1024, "fir": 64 * 1024,
             "sc": 512, "gd": 16 * 1024, "mt": 512, "bs": 32 * 1024}
    print("name,us_per_call,derived")
    rows = []
    with mesh:
        for name, mod in WORKLOADS.items():
            args = mod.make_args(sizes[name])
            if name == "aes":
                plain, key, rk, sb = args
                oracle = mod.reference(plain, key)
                jargs = (jnp.asarray(plain), jnp.asarray(rk),
                         jnp.asarray(sb))
            else:
                oracle = mod.reference(*args)
                jargs = tuple(jnp.asarray(a) for a in args)
            for mode, mk in [("umode", mod.make_umode),
                             ("dmode", mod.make_dmode)]:
                rep = evaluate(name, mod.PATTERN, mode, mk(mesh), jargs,
                               oracle)
                rows.append(rep)
                print(f"{name}_{mode},{rep.sim_time_s * 1e6:.2f},"
                      f"coll_bytes={rep.collective_bytes:.0f}"
                      f"|pattern={rep.pattern}|correct={rep.correct}")
    # paper-lesson checks
    by = {(r.name, r.mode): r for r in rows}
    d_wins = sum(by[(n, "dmode")].collective_bytes
                 <= by[(n, "umode")].collective_bytes + 1
                 for n in WORKLOADS)
    aes_zero = by[("aes", "dmode")].collective_bytes == 0
    # traffic/time correlation across workloads (D-mode)
    t = np.array([by[(n, "dmode")].sim_time_s for n in WORKLOADS])
    b = np.array([by[(n, "dmode")].collective_bytes for n in WORKLOADS])
    corr = float(np.corrcoef(b, t)[0, 1]) if b.std() > 0 else 0.0
    print(f"# D-mode traffic <= U-mode: {d_wins}/{len(WORKLOADS)}")
    print(f"# AES partitioned zero-traffic: {aes_zero}")
    print(f"# corr(traffic, sim_time) across workloads: {corr:.2f}")
    ok = all(r.correct for r in rows)
    print(f"# all outputs match oracles: {ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
