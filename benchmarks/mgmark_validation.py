import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
"""Fig. 7 analog — full-workload validation of the system model.

The paper validates MGSim against real-GPU wall time (5.5% mean error).
No TPU is attached here, so the golden reference is the analytic
roofline bound of each compiled workload (compute/memory/collective
terms from the real per-device HLO); the simulator must land close to
it while adding queueing/serialization effects on top.  Reported per
workload: simulated time, analytic bound, ratio (>= 1, close to 1 for
the bandwidth-dominated ones).
"""
import sys

import jax
from repro.compat import make_auto_mesh
import jax.numpy as jnp

from repro.core import SINGLE_POD, SystemSpec, analyze, simulate
from repro.core.roofline import collective_sim_time


def main() -> int:
    from repro.patterns import WORKLOADS
    mesh = make_auto_mesh((4,), ("dev",))
    spec = SystemSpec(pod_shape=(1, 4))
    sizes = {"aes": 64 * 1024, "km": 32 * 1024, "fir": 64 * 1024,
             "sc": 512, "gd": 16 * 1024, "mt": 512, "bs": 32 * 1024}
    print("name,us_per_call,derived")
    worst = 0.0
    with mesh:
        for name, mod in WORKLOADS.items():
            args = mod.make_args(sizes[name])
            if name == "aes":
                plain, key, rk, sb = args
                jargs = (jnp.asarray(plain), jnp.asarray(rk),
                         jnp.asarray(sb))
            else:
                jargs = tuple(jnp.asarray(a) for a in args)
            compiled = mod.make_dmode(mesh).lower(*jargs).compile()
            cost = analyze(compiled.as_text())
            rep = simulate(cost=cost, spec=spec, device_limit=None)
            c = spec.chip
            bound = (cost.flops / c.peak_bf16_flops
                     + cost.hbm_bytes / c.hbm_bandwidth
                     + collective_sim_time(cost, spec))
            ratio = rep.time_s / max(bound, 1e-12)
            print(f"{name},{rep.time_s * 1e6:.2f},"
                  f"bound_us={bound * 1e6:.2f}|ratio={ratio:.2f}")
            worst = max(worst, ratio)
    print(f"# max sim/bound ratio: {worst:.2f} "
          f"(1.0 = at the roofline; launch overheads push it above)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
