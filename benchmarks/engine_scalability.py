"""Fig. 8 analog — engine throughput across schedulers and worker counts.

The paper reports 2.5-3.5x speedups from conservative parallel execution
on 4 physical cores.  Two workloads, three schedulers:

* **aligned** — the MGMark-analog SPMD trace replayed through the full
  system model.  All devices share timestamps, so same-timestamp
  batching (DP-5) already finds the parallelism; we assert all three
  schedulers produce bit-identical ``SimReport``s.
* **diverged** — per-device op latencies jitter (the realistic regime
  the lookahead window exists for).  Same-timestamp batches collapse to
  width ~1 and the batch scheduler drowns in per-timestamp round
  overhead, while the lookahead scheduler executes every event in
  ``[t, t + min link latency)`` per round.  Wall-clock for serial /
  batch / lookahead at 1/2/4 workers goes to ``BENCH_engine.json`` so
  future PRs have a perf trajectory to compare against.

Note on absolute speedups: under CPython's GIL, pure-Python handlers
gain no real parallel speedup from threads, so the honest deliverables
are (a) bit-identical results, (b) rounds/dispatch overhead per scheme
and (c) lookahead-vs-batch wall-clock at equal worker count — the ratio
the paper's Go threads turn into physical-core speedup.
"""
from __future__ import annotations

import json
import os
import random
import sys
import time

import numpy as np

from repro.core import (Component, Connection, Engine, Request, SystemSpec,
                        simulate)
from .engine_parallelism import synthetic_workload

SCHEDULERS = ("serial", "batch", "lookahead")
WORKER_COUNTS = (1, 2, 4)


# -- aligned workload: full system model -------------------------------------

def _run_aligned(scheduler: str, workers: int = 4, n_dev: int = 64,
                 fabric: str = None, layers: int = 24):
    spec = SystemSpec(pod_shape=(8, 8))
    cost = synthetic_workload(n_dev, layers=layers)
    t0 = time.time()
    rep = simulate(cost=cost, spec=spec, scheduler=scheduler,
                   max_workers=workers, device_limit=None, fabric=fabric)
    return rep, time.time() - t0


# -- fabric dimension: scheduler x workers x interconnect backend ------------

def run_fabric_bench(repeat: int = 3) -> list:
    """Event-fabric runs multiply the event count (per-hop transfers);
    record wall/events per (fabric, scheduler, workers) so the fabric
    overhead trajectory is tracked alongside the engine's.  Serial is the
    per-fabric oracle; every row must match it bit-for-bit.

    Walls are best-of-``repeat`` *interleaved* repetitions, and every
    row records ``executor`` / ``cpu_count`` / ``events_per_sec``.
    Both changes come out of the PR-4-era "batch @2 workers slower than
    @1" anomaly in earlier BENCH files: single-shot timings on a loaded
    2-vCPU host swing 30%+ (the noise), stacked on the thread pool
    dispatching GIL-bound handler rounds that cannot win (the real
    regression -- the threads executor now declines the pool below
    ``pool_min_events``, and ``executor="procs"`` is the backend that
    actually buys cores).  With best-of interleaving plus these fields,
    any future anomaly is attributable at a glance."""
    cpu = os.cpu_count()
    configs = []
    for fabric in ("analytic", "event"):
        for sched in SCHEDULERS:
            for workers in WORKER_COUNTS if sched != "serial" else (1,):
                configs.append((fabric, sched, workers))
    walls: dict = {}
    reports: dict = {}
    oracle: dict = {}
    for _ in range(max(1, repeat)):
        for cfg in configs:
            fabric, sched, workers = cfg
            rep, wall = _run_aligned(sched, workers, n_dev=16,
                                     fabric=fabric, layers=12)
            oracle.setdefault(fabric, rep)
            assert rep.summary() == oracle[fabric].summary(), \
                f"{sched}@{workers} diverged from serial on {fabric}"
            if cfg not in walls or wall < walls[cfg]:
                walls[cfg] = wall
            reports[cfg] = rep
    rows = []
    for cfg in configs:
        fabric, sched, workers = cfg
        rep, wall = reports[cfg], walls[cfg]
        rows.append({"fabric": fabric, "scheduler": sched,
                     "workers": workers, "executor": rep.executor,
                     "cpu_count": cpu, "wall_s": round(wall, 4),
                     "events": rep.events,
                     "events_per_sec": round(rep.events / wall)})
        print(f"fabric_{fabric}_{sched}{workers},"
              f"{1e6 * wall / rep.events:.2f},events={rep.events}")
    return rows


# -- diverged workload: jittered per-device latencies ------------------------

class JitterNode(Component):
    """Device-analog whose op latencies diverge across devices."""

    def __init__(self, name, seed, ticks, send_every=40):
        super().__init__(name)
        self.rng = random.Random(seed)
        self.ticks = ticks
        self.count = 0
        self.received = 0
        self.send_every = send_every
        self.sig = 0

    def start(self):
        self.schedule("tick", self.rng.randint(50, 550))

    def handle(self, event):
        self.sig = hash((self.sig, self.engine.now, event.kind))
        if event.kind == "tick":
            self.count += 1
            if self.count % self.send_every == 0 and "out" in self.ports:
                self.port("out").send(Request(src=self.port("out"), dst=None,
                                              kind="ping", size_bytes=64))
            if self.count < self.ticks:
                self.schedule("tick", self.rng.randint(50, 550))
        else:
            self.received += 1


def _run_diverged(scheduler: str, workers: int, n: int = 32,
                  ticks: int = 1200, repeat: int = 3):
    """Best-of-``repeat`` wall clock (single-shot timings on shared CI
    hosts swing 30%+); every repetition's state must be identical --
    asserted here across repetitions, and by the caller against the
    serial oracle."""
    best = None
    state = None
    for _ in range(max(1, repeat)):
        eng = Engine(scheduler=scheduler, max_workers=workers)
        nodes = [eng.register(JitterNode(f"n{i}", i, ticks))
                 for i in range(n)]
        for i in range(n):
            conn = eng.register(Connection(f"ring{i}", latency_s=4e-9))
            conn.plug(nodes[i].port("out")).plug(nodes[(i + 1) % n].port("in"))
        for nd in nodes:
            nd.start()
        t0 = time.perf_counter()
        end = eng.run()
        wall = time.perf_counter() - t0
        rep_state = tuple((nd.sig, nd.count, nd.received) for nd in nodes)
        if state is None:
            state = rep_state
        assert rep_state == state, \
            f"{scheduler}@{workers} diverged across repetitions"
        if best is None or wall < best:
            best = wall
    return state, end, eng, best


def main() -> int:
    print("name,us_per_call,derived")
    bench = {"workers": list(WORKER_COUNTS), "aligned": {}, "diverged": {}}

    # aligned: determinism + throughput at 4 workers (serial runs first
    # and doubles as the oracle the others must match bit-for-bit)
    rep_oracle = None
    for sched in SCHEDULERS:
        rep, wall = _run_aligned(sched)
        rep_oracle = rep_oracle or rep
        identical = rep.summary() == rep_oracle.summary()
        assert identical, f"{sched} diverged from serial on aligned trace"
        eps = rep.events / wall
        widths = rep.window_widths if sched == "lookahead" else rep.batch_widths
        print(f"engine_aligned_{sched}4,{1e6 * wall / rep.events:.2f},"
              f"events_per_s={eps:.0f}|rounds={len(widths)}")
        bench["aligned"][sched] = {"wall_s": round(wall, 4),
                                   "events": rep.events,
                                   "events_per_sec": round(eps),
                                   "rounds": len(widths)}
    w = np.asarray(rep_oracle.batch_widths)
    print(f"# aligned trace: median batch width "
          f"{np.percentile(w, 50):.0f} (paper Fig.2 range: 60-100)")

    # diverged: scaling curves; the Fig. 8 analog
    oracle_state, oracle_end, _, _ = _run_diverged("serial", 1)
    for sched in SCHEDULERS:
        for workers in WORKER_COUNTS if sched != "serial" else (1,):
            state, end, eng, wall = _run_diverged(sched, workers)
            assert (state, end) == (oracle_state, oracle_end), \
                f"{sched}@{workers} diverged from serial"
            eps = eng.events_processed / wall
            rounds = (len(eng.window_widths) if sched == "lookahead"
                      else len(eng.batch_widths))
            print(f"engine_diverged_{sched}{workers},"
                  f"{1e6 * wall / eng.events_processed:.2f},"
                  f"events_per_s={eps:.0f}|rounds={rounds}")
            bench["diverged"].setdefault(sched, {})[str(workers)] = \
                round(wall, 4)
            bench["diverged"][sched][f"events_per_sec_{workers}"] = \
                round(eps)

    look4 = bench["diverged"]["lookahead"]["4"]
    batch4 = bench["diverged"]["batch"]["4"]
    serial1 = bench["diverged"]["serial"]["1"]
    speedup = batch4 / look4
    bench["speedup_lookahead_vs_batch_4w"] = round(speedup, 2)
    # Same wall-ratio fields as BENCH_fabric.json's replay section: the
    # scheduler's wall-clock overhead over serial on ITS best regime.
    bench["wall_serial_s"] = serial1
    bench["wall_lookahead4_s"] = look4
    bench["wall_ratio_lookahead4_over_serial"] = round(look4 / serial1, 2)
    bench["bit_identical"] = True
    print(f"# all schedulers bit-identical to serial: True")
    print(f"# lookahead vs batch wall-clock at 4 workers: {speedup:.2f}x "
          f"(paper Fig.8 range: 2.5-3.5x); lookahead4/serial wall ratio "
          f"{bench['wall_ratio_lookahead4_over_serial']:.2f}")

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = os.path.join(root, "BENCH_engine.json")
    prior = {}
    if os.path.exists(out):                 # merge-write: keep any keys
        with open(out) as f:                # other tools have recorded
            prior = json.load(f)
    prior.update(bench)
    with open(out, "w") as f:
        json.dump(prior, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")

    # fabric backend x scheduler x worker count (bit-identity asserted).
    # Merge-write via fabric_contention.merge_bench: that benchmark owns
    # the "replay" section of BENCH_fabric.json, this one owns "runs".
    from .fabric_contention import merge_bench
    rows = run_fabric_bench()
    wall = {(r["fabric"], r["scheduler"], r["workers"]): r["wall_s"]
            for r in rows}
    fab = merge_bench({
        "runs": rows, "bit_identical": True,
        "wall_lookahead_vs_serial_event_4w": round(
            wall[("event", "serial", 1)] / wall[("event", "lookahead", 4)],
            2),
    })
    print(f"# wrote {fab}")
    # Exit status gates on the deterministic properties only (the
    # bit-identity asserts above); the wall-clock ratio is reported but
    # not gated -- on a loaded 2-vCPU CI runner it is a coin flip.
    return 0


if __name__ == "__main__":
    sys.exit(main())
