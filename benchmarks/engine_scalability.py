"""Fig. 2 + Fig. 8 analog — engine width and throughput across schedulers
and worker counts.

Absorbs the former ``benchmarks.engine_parallelism`` (Fig. 2 width
distributions; ``synthetic_workload`` still importable from either
module).  The paper reports 2.5-3.5x speedups from conservative parallel
execution on 4 physical cores.  Two workloads, four schedulers (serial /
batch / lookahead / bounded-lag):

* **aligned** — the MGMark-analog SPMD trace replayed through the full
  system model.  All devices share timestamps, so same-timestamp
  batching (DP-5) already finds the parallelism; we assert all three
  schedulers produce bit-identical ``SimReport``s.
* **diverged** — per-device op latencies jitter (the realistic regime
  the lookahead window exists for).  Same-timestamp batches collapse to
  width ~1 and the batch scheduler drowns in per-timestamp round
  overhead, while the lookahead scheduler executes every event in
  ``[t, t + min link latency)`` per round.  Wall-clock for serial /
  batch / lookahead at 1/2/4 workers goes to ``BENCH_engine.json`` so
  future PRs have a perf trajectory to compare against.

Note on absolute speedups: under CPython's GIL, pure-Python handlers
gain no real parallel speedup from threads, so the honest deliverables
are (a) bit-identical results, (b) rounds/dispatch overhead per scheme
and (c) lookahead-vs-batch wall-clock at equal worker count — the ratio
the paper's Go threads turn into physical-core speedup.
"""
from __future__ import annotations

import json
import os
import random
import sys
import time

import numpy as np

from repro.core import (Component, Connection, Engine, Request, SystemSpec,
                        simulate)
from repro.core.hlo import CollectiveRecord, HloCost, TraceOp

SCHEDULERS = ("serial", "batch", "lookahead", "bounded")
WORKER_COUNTS = (1, 2, 4)


def synthetic_workload(n_devices: int, layers: int = 12) -> HloCost:
    """AES-analog: compute-heavy partitioned segments + periodic sync."""
    cost = HloCost()
    groups = [list(range(n_devices))]
    for i in range(layers):
        cost.trace.append(TraceOp("compute", f"seg{i}", flops=5e9,
                                  hbm_bytes=2e8))
        rec = CollectiveRecord("all-reduce", f"ar{i}", 1e6, int(1e6),
                               int(1e6), groups)
        cost.collectives.append(rec)
        cost.trace.append(TraceOp("collective", f"ar{i}", collective=rec))
    return cost


# -- Fig. 2 analog: events executable concurrently per scheduler round -------

def _dist(widths) -> str:
    w = np.asarray(widths)
    return (f"p50={np.percentile(w, 50):.0f}|p95={np.percentile(w, 95):.0f}"
            f"|max={w.max()}")


def run_width_distributions() -> int:
    """The paper plots how many same-time events the AES simulation
    schedules (60-100), arguing a conservative parallel engine has
    enough work for 4-8 cores.  Replay the MGMark-analog traces and
    report batch widths (same-timestamp, DP-5) next to lookahead-window
    widths side by side; window widths dominate whenever per-device
    timestamps diverge.  (Formerly ``benchmarks.engine_parallelism``.)
    """
    rep = rep_look = None
    for n in (16, 64, 256):
        spec = SystemSpec(pod_shape=(int(np.sqrt(n)), int(np.sqrt(n))))
        cost = synthetic_workload(n)
        rep = simulate(cost=cost, spec=spec, device_limit=None)
        rep_look = simulate(cost=cost, spec=spec, device_limit=None,
                            scheduler="lookahead")
        assert rep_look.summary() == rep.summary(), "determinism violated"
        bw = np.asarray(rep.batch_widths)
        ww = np.asarray(rep_look.window_widths)
        print(f"batch_width_mean_{n}dev,{bw.mean():.1f},{_dist(bw)}")
        print(f"window_width_mean_{n}dev,{ww.mean():.1f},{_dist(ww)}")
    # the paper's claim: enough parallelism for 4-8 cores
    ok_batch = np.percentile(np.asarray(rep.batch_widths), 50) >= 8
    ok_window = np.percentile(np.asarray(rep_look.window_widths), 50) >= 8
    print(f"# median batch width supports >=8 workers: {ok_batch}")
    print(f"# median window width supports >=8 workers: {ok_window}")
    return 0


def _rounds(rep) -> int:
    """Round count for any scheduler: window schedulers (lookahead,
    bounded) record ``window_widths``, batch records ``batch_widths``;
    serial has neither (every event is its own "round")."""
    ww = getattr(rep, "window_widths", None) or ()
    bw = getattr(rep, "batch_widths", None) or ()
    return len(ww) or len(bw) or getattr(rep, "events", 0) or \
        getattr(rep, "events_processed", 0)


# -- aligned workload: full system model -------------------------------------

def _run_aligned(scheduler: str, workers: int = 4, n_dev: int = 64,
                 fabric: str = None, layers: int = 24):
    spec = SystemSpec(pod_shape=(8, 8))
    cost = synthetic_workload(n_dev, layers=layers)
    t0 = time.time()
    rep = simulate(cost=cost, spec=spec, scheduler=scheduler,
                   max_workers=workers, device_limit=None, fabric=fabric)
    return rep, time.time() - t0


# -- fabric dimension: scheduler x workers x interconnect backend ------------

def run_fabric_bench(repeat: int = 3) -> list:
    """Event-fabric runs multiply the event count (per-hop transfers);
    record wall/events per (fabric, scheduler, workers) so the fabric
    overhead trajectory is tracked alongside the engine's.  Serial is the
    per-fabric oracle; every row must match it bit-for-bit.

    Walls are best-of-``repeat`` *interleaved* repetitions, and every
    row records ``executor`` / ``cpu_count`` / ``events_per_sec``.
    Both changes come out of the PR-4-era "batch @2 workers slower than
    @1" anomaly in earlier BENCH files: single-shot timings on a loaded
    2-vCPU host swing 30%+ (the noise), stacked on the thread pool
    dispatching GIL-bound handler rounds that cannot win (the real
    regression -- the threads executor now declines the pool below
    ``pool_min_events``, and ``executor="procs"`` is the backend that
    actually buys cores).  With best-of interleaving plus these fields,
    any future anomaly is attributable at a glance."""
    cpu = os.cpu_count()
    configs = []
    for fabric in ("analytic", "event"):
        for sched in SCHEDULERS:
            for workers in WORKER_COUNTS if sched != "serial" else (1,):
                configs.append((fabric, sched, workers))
    walls: dict = {}
    reports: dict = {}
    oracle: dict = {}
    for _ in range(max(1, repeat)):
        for cfg in configs:
            fabric, sched, workers = cfg
            rep, wall = _run_aligned(sched, workers, n_dev=16,
                                     fabric=fabric, layers=12)
            oracle.setdefault(fabric, rep)
            assert rep.summary() == oracle[fabric].summary(), \
                f"{sched}@{workers} diverged from serial on {fabric}"
            if cfg not in walls or wall < walls[cfg]:
                walls[cfg] = wall
            reports[cfg] = rep
    rows = []
    for cfg in configs:
        fabric, sched, workers = cfg
        rep, wall = reports[cfg], walls[cfg]
        rounds = _rounds(rep)
        serial_wall = walls[(fabric, "serial", 1)]
        row = {"fabric": fabric, "scheduler": sched,
               "workers": workers, "executor": rep.executor,
               "cpu_count": cpu, "wall_s": round(wall, 4),
               "events": rep.events,
               "events_per_sec": round(rep.events / wall),
               "rounds": rounds,
               "rounds_per_sec": round(rounds / wall)}
        if sched != "serial":
            from .fabric_contention import sync_overhead_fields
            row.update(sync_overhead_fields(
                "sync_overhead_us_per_round", wall, serial_wall, rounds))
        rows.append(row)
        print(f"fabric_{fabric}_{sched}{workers},"
              f"{1e6 * wall / rep.events:.2f},events={rep.events}"
              f"|rounds={rounds}")
    return rows


# -- diverged workload: jittered per-device latencies ------------------------

class JitterNode(Component):
    """Device-analog whose op latencies diverge across devices."""

    def __init__(self, name, seed, ticks, send_every=40):
        super().__init__(name)
        self.rng = random.Random(seed)
        self.ticks = ticks
        self.count = 0
        self.received = 0
        self.send_every = send_every
        self.sig = 0

    def start(self):
        self.schedule("tick", self.rng.randint(50, 550))

    def handle(self, event):
        self.sig = hash((self.sig, self.engine.now, event.kind))
        if event.kind == "tick":
            self.count += 1
            if self.count % self.send_every == 0 and "out" in self.ports:
                self.port("out").send(Request(src=self.port("out"), dst=None,
                                              kind="ping", size_bytes=64))
            if self.count < self.ticks:
                self.schedule("tick", self.rng.randint(50, 550))
        else:
            self.received += 1


def _run_diverged(scheduler: str, workers: int, n: int = 32,
                  ticks: int = 1200, repeat: int = 3):
    """Best-of-``repeat`` wall clock (single-shot timings on shared CI
    hosts swing 30%+); every repetition's state must be identical --
    asserted here across repetitions, and by the caller against the
    serial oracle."""
    best = None
    state = None
    for _ in range(max(1, repeat)):
        eng = Engine(scheduler=scheduler, max_workers=workers)
        nodes = [eng.register(JitterNode(f"n{i}", i, ticks))
                 for i in range(n)]
        for i in range(n):
            conn = eng.register(Connection(f"ring{i}", latency_s=4e-9))
            conn.plug(nodes[i].port("out")).plug(nodes[(i + 1) % n].port("in"))
        for nd in nodes:
            nd.start()
        t0 = time.perf_counter()
        end = eng.run()
        wall = time.perf_counter() - t0
        rep_state = tuple((nd.sig, nd.count, nd.received) for nd in nodes)
        if state is None:
            state = rep_state
        assert rep_state == state, \
            f"{scheduler}@{workers} diverged across repetitions"
        if best is None or wall < best:
            best = wall
    return state, end, eng, best


def main() -> int:
    print("name,us_per_call,derived")
    run_width_distributions()
    bench = {"workers": list(WORKER_COUNTS), "aligned": {}, "diverged": {}}

    # aligned: determinism + throughput at 4 workers.  Serial doubles as
    # the bit-for-bit oracle; walls are best-of-3 *interleaved* (serial,
    # batch, ... round-robin) so a noise burst on a shared host cannot
    # bias one scheduler's number -- and the serial-relative sync
    # overhead compares walls measured in the same noise window.
    from .fabric_contention import sync_overhead_fields
    aligned_walls: dict = {}
    aligned_reps: dict = {}
    for _ in range(3):
        for sched in SCHEDULERS:
            rep, wall = _run_aligned(sched)
            aligned_reps.setdefault(sched, rep)
            assert rep.summary() == aligned_reps["serial"].summary(), \
                f"{sched} diverged from serial on aligned trace"
            if sched not in aligned_walls or wall < aligned_walls[sched]:
                aligned_walls[sched] = wall
    rep_oracle = aligned_reps["serial"]
    for sched in SCHEDULERS:
        rep, wall = aligned_reps[sched], aligned_walls[sched]
        eps = rep.events / wall
        rounds = _rounds(rep)
        print(f"engine_aligned_{sched}4,{1e6 * wall / rep.events:.2f},"
              f"events_per_s={eps:.0f}|rounds={rounds}")
        bench["aligned"][sched] = {"wall_s": round(wall, 4),
                                   "events": rep.events,
                                   "events_per_sec": round(eps),
                                   "rounds": rounds,
                                   "rounds_per_sec": round(rounds / wall)}
        if sched != "serial":
            bench["aligned"][sched].update(sync_overhead_fields(
                "sync_overhead_us_per_round", wall,
                aligned_walls["serial"], rounds))
    w = np.asarray(rep_oracle.batch_widths)
    print(f"# aligned trace: median batch width "
          f"{np.percentile(w, 50):.0f} (paper Fig.2 range: 60-100)")

    # diverged: scaling curves; the Fig. 8 analog.  Same interleaved
    # best-of-3 discipline: every (scheduler, workers) config -- serial
    # included -- is timed round-robin, so the sync-overhead deltas
    # subtract walls from the same noise window.
    div_configs = [(s, w) for s in SCHEDULERS
                   for w in (WORKER_COUNTS if s != "serial" else (1,))]
    div_walls: dict = {}
    div_out: dict = {}
    oracle_state = oracle_end = None
    for _ in range(3):
        for cfg in div_configs:
            state, end, eng, wall = _run_diverged(cfg[0], cfg[1], repeat=1)
            if oracle_state is None:
                oracle_state, oracle_end = state, end
            assert (state, end) == (oracle_state, oracle_end), \
                f"{cfg[0]}@{cfg[1]} diverged from serial"
            div_out[cfg] = eng
            if cfg not in div_walls or wall < div_walls[cfg]:
                div_walls[cfg] = wall
    serial_div_wall = div_walls[("serial", 1)]
    for sched, workers in div_configs:
        eng, wall = div_out[(sched, workers)], div_walls[(sched, workers)]
        eps = eng.events_processed / wall
        rounds = _rounds(eng)
        print(f"engine_diverged_{sched}{workers},"
              f"{1e6 * wall / eng.events_processed:.2f},"
              f"events_per_s={eps:.0f}|rounds={rounds}")
        bench["diverged"].setdefault(sched, {})[str(workers)] = \
            round(wall, 4)
        bench["diverged"][sched][f"events_per_sec_{workers}"] = \
            round(eps)
        bench["diverged"][sched][f"rounds_{workers}"] = rounds
        bench["diverged"][sched][f"rounds_per_sec_{workers}"] = \
            round(rounds / wall)
        if sched != "serial":
            bench["diverged"][sched].update(sync_overhead_fields(
                f"sync_overhead_us_per_round_{workers}", wall,
                serial_div_wall, rounds))

    look4 = bench["diverged"]["lookahead"]["4"]
    batch4 = bench["diverged"]["batch"]["4"]
    bounded4 = bench["diverged"]["bounded"]["4"]
    serial1 = bench["diverged"]["serial"]["1"]
    speedup = batch4 / look4
    bench["speedup_lookahead_vs_batch_4w"] = round(speedup, 2)
    # Same wall-ratio fields as BENCH_fabric.json's replay section: the
    # scheduler's wall-clock overhead over serial on ITS best regime.
    bench["wall_serial_s"] = serial1
    bench["wall_lookahead4_s"] = look4
    bench["wall_ratio_lookahead4_over_serial"] = round(look4 / serial1, 2)
    bench["wall_bounded4_s"] = bounded4
    bench["wall_ratio_bounded4_over_serial"] = round(bounded4 / serial1, 2)
    bench["rounds_lookahead4"] = bench["diverged"]["lookahead"]["rounds_4"]
    bench["rounds_bounded4"] = bench["diverged"]["bounded"]["rounds_4"]
    bench["bit_identical"] = True
    print(f"# all schedulers bit-identical to serial: True")
    print(f"# lookahead vs batch wall-clock at 4 workers: {speedup:.2f}x "
          f"(paper Fig.8 range: 2.5-3.5x); lookahead4/serial wall ratio "
          f"{bench['wall_ratio_lookahead4_over_serial']:.2f}")

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = os.path.join(root, "BENCH_engine.json")
    prior = {}
    if os.path.exists(out):                 # merge-write: keep any keys
        with open(out) as f:                # other tools have recorded
            prior = json.load(f)
    prior.update(bench)
    with open(out, "w") as f:
        json.dump(prior, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")

    # fabric backend x scheduler x worker count (bit-identity asserted).
    # Merge-write via fabric_contention.merge_bench: that benchmark owns
    # the "replay" section of BENCH_fabric.json, this one owns "runs".
    from .fabric_contention import merge_bench
    rows = run_fabric_bench()
    wall = {(r["fabric"], r["scheduler"], r["workers"]): r["wall_s"]
            for r in rows}
    fab = merge_bench({
        "runs": rows, "bit_identical": True,
        "wall_lookahead_vs_serial_event_4w": round(
            wall[("event", "serial", 1)] / wall[("event", "lookahead", 4)],
            2),
    })
    print(f"# wrote {fab}")
    # Exit status gates on the deterministic properties only (the
    # bit-identity asserts above); the wall-clock ratio is reported but
    # not gated -- on a loaded 2-vCPU CI runner it is a coin flip.
    return 0


if __name__ == "__main__":
    sys.exit(main())
