"""Fig. 8 analog — engine throughput, serial vs conservative-parallel.

The paper reports 3.5x/2.5x speedups on 4 physical cores.  This host has
ONE core, so the honest deliverables are (a) events/second of the serial
engine, (b) the conservative-parallel engine's *bit-identical* results
(asserted), and (c) the available batch parallelism (work the threads
could take).  Speedup on real multi-core hosts comes for free from (c).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import SystemSpec, simulate
from .engine_parallelism import synthetic_workload


def _run(parallel: bool, n_dev: int = 64):
    spec = SystemSpec(pod_shape=(8, 8))
    cost = synthetic_workload(n_dev, layers=24)
    t0 = time.time()
    rep = simulate(cost=cost, spec=spec, parallel=parallel,
                   device_limit=None)
    wall = time.time() - t0
    return rep, wall


def main() -> int:
    print("name,us_per_call,derived")
    rep_s, wall_s = _run(parallel=False)
    eps_s = rep_s.events / wall_s
    print(f"engine_serial,{1e6 * wall_s / rep_s.events:.2f},"
          f"events_per_s={eps_s:.0f}")
    rep_p, wall_p = _run(parallel=True)
    eps_p = rep_p.events / wall_p
    print(f"engine_parallel4,{1e6 * wall_p / rep_p.events:.2f},"
          f"events_per_s={eps_p:.0f}")
    identical = (rep_s.time_s == rep_p.time_s
                 and rep_s.events == rep_p.events
                 and rep_s.collectives_completed
                 == rep_p.collectives_completed)
    print(f"# parallel bit-identical to serial: {identical}")
    w = np.asarray(rep_s.batch_widths)
    print(f"# available parallelism: median batch width "
          f"{np.percentile(w, 50):.0f} (paper Fig.2 range: 60-100)")
    return 0 if identical else 1


if __name__ == "__main__":
    sys.exit(main())
