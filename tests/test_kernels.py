"""Pallas kernels vs ref.py oracles — shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32
                             ).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 64),        # MHA
    (2, 256, 8, 2, 64),        # GQA 4:1
    (1, 384, 6, 2, 32),        # uneven heads, S % bq != 0 via bq=128
    (2, 128, 16, 16, 128),     # wide MHA, hd=128
])
def test_flash_attention_shapes(B, S, H, K, hd):
    q, k, v = (_rand((B, S, H, hd), k=1), _rand((B, S, K, hd), k=2),
               _rand((B, S, K, hd), k=3))
    got = ops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q, k, v = (_rand((1, 128, 4, 64), jnp.bfloat16, 1),
               _rand((1, 128, 2, 64), jnp.bfloat16, 2),
               _rand((1, 128, 2, 64), jnp.bfloat16, 3))
    got = ops.flash_attention(q, k, v, causal=True).astype(jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=True).astype(jnp.float32)
    np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


def test_flash_attention_noncausal():
    q, k, v = (_rand((1, 128, 4, 32), k=4), _rand((1, 128, 4, 32), k=5),
               _rand((1, 128, 4, 32), k=6))
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_attention_rows_convex_combination():
    """Property: each output is a convex combination of V rows, so it
    lies inside V's coordinate-wise range."""
    q, k = _rand((1, 128, 2, 32), k=7), _rand((1, 128, 2, 32), k=8)
    v = _rand((1, 128, 2, 32), k=9)
    out = ops.flash_attention(q, k, v, causal=True)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,H,Q,P,N", [(2, 2, 64, 32, 16),
                                       (1, 4, 128, 64, 64),
                                       (3, 1, 32, 16, 8)])
def test_ssd_chunk_kernel(R, H, Q, P, N):
    x = _rand((R, H, Q, P), k=10)
    dt = jax.nn.softplus(_rand((R, H, Q), k=11))
    A = -jnp.exp(_rand((H,), k=12))
    cs = jnp.cumsum(dt * A[None, :, None], axis=-1)
    Bm, Cm = _rand((R, H, Q, N), k=13), _rand((R, H, Q, N), k=14)
    y1, s1 = ops.ssd_chunk_kernel(x, dt, cs, Bm, Cm)
    y2, s2 = ref.ssd_chunk_ref(x, dt, cs, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)


def test_ssd_pallas_matches_reference_scan():
    from repro.models.ssm import ssd_reference
    B, L, H, P, N = 2, 128, 4, 32, 16
    x = _rand((B, L, H, P), k=15)
    dt = jax.nn.softplus(_rand((B, L, H), k=16))
    A = -jnp.exp(_rand((H,), k=17))
    Bm, Cm = _rand((B, L, 1, N), k=18), _rand((B, L, 1, N), k=19)
    y1 = ops.ssd_pallas(x, dt, A, Bm, Cm, chunk=32)
    y2 = ssd_reference(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)


def test_ssd_matches_naive_recurrence():
    """SSD == the literal sequential state-space recurrence (the real
    semantic oracle, independent of chunking)."""
    from repro.models.ssm import ssd_reference
    B, L, H, P, N = 1, 24, 2, 8, 4
    x = np.asarray(_rand((B, L, H, P), k=20), np.float64)
    dt = np.asarray(jax.nn.softplus(_rand((B, L, H), k=21)), np.float64)
    A = np.asarray(-jnp.exp(_rand((H,), k=22)), np.float64)
    Bm = np.asarray(_rand((B, L, 1, N), k=23), np.float64)
    Cm = np.asarray(_rand((B, L, 1, N), k=24), np.float64)
    S = np.zeros((B, H, N, P))
    y_naive = np.zeros((B, L, H, P))
    for t in range(L):
        dA = np.exp(dt[:, t] * A[None, :])                    # (B,H)
        S = dA[..., None, None] * S + np.einsum(
            "bgn,bh,bhp->bhnp", Bm[:, t], dt[:, t], x[:, t])
        y_naive[:, t] = np.einsum("bgn,bhnp->bhp", Cm[:, t], S)
    y = ssd_reference(jnp.asarray(x, jnp.float32),
                      jnp.asarray(dt, jnp.float32),
                      jnp.asarray(A, jnp.float32),
                      jnp.asarray(Bm, jnp.float32),
                      jnp.asarray(Cm, jnp.float32), chunk=8)
    np.testing.assert_allclose(np.asarray(y), y_naive, atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# rmsnorm / stencil / bitonic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,d", [(64, 256), (100, 512), (1, 128)])
def test_rmsnorm(rows, d):
    x, w = _rand((rows, d), k=25), _rand((d,), k=26)
    np.testing.assert_allclose(ops.rmsnorm(x, w, block_rows=32),
                               ref.rmsnorm_ref(x, w), atol=1e-5, rtol=1e-4)


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_rmsnorm_scale_invariance(p):
    """Property: rmsnorm(c*x) == rmsnorm(x) for any positive scale c."""
    x, w = _rand((16, 64), k=27), _rand((64,), k=28)
    c = float(2 ** p)
    np.testing.assert_allclose(ops.rmsnorm(c * x, w), ops.rmsnorm(x, w),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("H,W,K", [(128, 64, 3), (256, 128, 5), (64, 64, 3)])
def test_stencil(H, W, K):
    img, kern = _rand((H, W), k=29), _rand((K, K), k=30)
    got = ops.stencil2d(img, kern, block_rows=min(64, H))
    np.testing.assert_allclose(got, ref.stencil2d_ref(img, kern),
                               atol=1e-4, rtol=1e-4)


def test_bitonic_stage_matches_ref():
    x = _rand((2048,), k=31)
    for size, dist in [(2, 1), (8, 4), (64, 16), (2048, 256)]:
        got = ops.bitonic_stage(x, dist, size, block=512) if dist < 512 \
            else ref.bitonic_stage_ref(x, dist, size)
        want = ref.bitonic_stage_ref(x, dist, size)
        np.testing.assert_allclose(got, want)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_bitonic_full_sort_property(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))
    y = np.asarray(ref.bitonic_sort_ref(x))
    np.testing.assert_allclose(y, np.sort(np.asarray(x)))
