"""Topology analytics: replica-group parsing + collective cost formulas."""
import pytest

from repro.core import SystemSpec, Topology, parse_replica_groups


def test_parse_iota_form():
    groups = parse_replica_groups("replica_groups=[2,4]<=[8]")
    assert groups == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_parse_iota_transposed():
    groups = parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_parse_list_form():
    groups = parse_replica_groups("replica_groups={{0,1},{2,3}}")
    assert groups == [[0, 1], [2, 3]]


def test_parse_iota_transposed_with_whitespace():
    """XLA pretty-printers may space the dims; the parse must not care."""
    groups = parse_replica_groups("replica_groups=[4, 2]<=[2, 4]T(1, 0)")
    assert groups == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_parse_iota_3d_transpose():
    groups = parse_replica_groups("replica_groups=[2,4]<=[2,2,2]T(2,0,1)")
    flat = [0, 2, 4, 6, 1, 3, 5, 7]
    assert groups == [flat[:4], flat[4:]]


def test_parse_empty_braces_means_no_groups():
    """XLA's `replica_groups={}` shorthand (one flat group) parses to []
    so callers fall back to their own default grouping."""
    assert parse_replica_groups("replica_groups={}") == []


def test_parse_no_replica_groups_attr_is_empty():
    """collective-permute attrs carry source_target_pairs instead."""
    assert parse_replica_groups("source_target_pairs={{0,1},{1,2}}") == []


def test_parse_malformed_raises_not_falls_through():
    with pytest.raises(ValueError, match="malformed replica_groups"):
        parse_replica_groups("replica_groups=oops")


def test_parse_iota_size_mismatch_raises():
    with pytest.raises(ValueError, match="yield"):
        parse_replica_groups("replica_groups=[2,4]<=[3]")


def test_parse_iota_bad_transpose_perm_raises():
    with pytest.raises(ValueError, match="not a permutation"):
        parse_replica_groups("replica_groups=[4,2]<=[2,4]T(1,1)")


SPEC = SystemSpec(pod_shape=(4, 4), num_pods=2)


def _topo():
    return Topology(SPEC)


def test_classify_groups():
    t = _topo()
    assert t.classify_group([0, 1, 2, 3]) == "ring_x"       # same y row
    assert t.classify_group([0, 4, 8, 12]) == "ring_y"      # same x col
    assert t.classify_group(list(range(16))) == "block_2d"
    assert t.classify_group([0, 16]) == "cross_pod"
    assert t.classify_group([3]) == "self"


def test_ring_allreduce_time_formula():
    t = _topo()
    c = SPEC.chip
    B, n = 1e6, 4
    got = t.collective_time_s("all-reduce", B, [[0, 1, 2, 3]])
    expect = 2 * (n - 1) / n * B / (2 * c.ici_link_bandwidth) \
        + 2 * (n - 1) * c.ici_hop_latency_s
    assert got == pytest.approx(expect, rel=1e-9)


def test_allgather_half_of_allreduce():
    t = _topo()
    B = 1e7
    ar = t.collective_time_s("all-reduce", B, [[0, 1, 2, 3]])
    ag = t.collective_time_s("all-gather", B, [[0, 1, 2, 3]])
    assert ar == pytest.approx(2 * ag, rel=0.2)   # ~2 phases vs 1


def test_collective_permute_is_one_hop():
    t = _topo()
    c = SPEC.chip
    got = t.collective_time_s("collective-permute", 5e5, [[0, 1]])
    assert got == pytest.approx(5e5 / c.ici_link_bandwidth
                                + c.ici_hop_latency_s, rel=1e-9)


def test_cross_pod_uses_dcn():
    t = _topo()
    B = 1e8
    groups = [[i, i + 16] for i in range(16)]     # pod-axis pairs
    got = t.collective_time_s("all-reduce", B, groups)
    # all 16 groups share pod DCN bandwidth
    dcn = 16 * B * 2 * (2 - 1) / 2 / SPEC.dcn_bandwidth_per_pod
    assert got >= dcn
    assert t.dcn[0].bytes_total > 0


def test_link_debits_accumulate():
    t = _topo()
    t.collective_time_s("all-reduce", 1e6, [[0, 1, 2, 3]])
    rep = t.link_report()
    assert rep["hottest_links"], "links must be debited"


def test_singleton_group_free():
    t = _topo()
    assert t.collective_time_s("all-reduce", 1e9, [[5]]) == 0.0


def test_bigger_payload_takes_longer():
    t = _topo()
    small = t.collective_time_s("all-to-all", 1e5, [list(range(16))])
    big = t.collective_time_s("all-to-all", 1e7, [list(range(16))])
    assert big > small
