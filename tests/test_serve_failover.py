"""Stateful failover: spare-chip re-placement, KV migration /
checkpointed prefill, quorum-based failure detection, capped backoff,
and abort idempotence.  Complements tests/test_serve_sim.py (which pins
the PR-9 detection -> re-mesh -> requeue behaviors); see docs/faults.md
"Spare pool, migration & quorum"."""
import pytest

from repro.core import SystemSpec
from repro.core.hooks import FaultInjector
from repro.core.hw import s_to_ps
from repro.serve.sim import (RecoveryPolicy, ServingSystem, build_scenario,
                             run_serving, _fault_candidates)

SMALL = SystemSpec(pod_shape=(2, 2))              # 2 tenants x 2 chips
WIDE = SystemSpec(pod_shape=(2, 2), num_pods=2)   # room for spares
DEADLINE = 5e-4
KILL = {"chip1.prog": [(3e-3, "fail", None)]}

SCHED_X_EXEC = [(s, e) for s in ("batch", "lookahead", "bounded")
                for e in ("threads", "procs")]


def _scenario(spec=SMALL, seed=3, rate=800.0, duration=0.006, **kw):
    scen = build_scenario(spec, rate_rps=rate, duration_s=duration,
                          seed=seed, **kw)
    assert scen is not None
    return scen


def _run_system(scen, spec, faults, policy, until_s=None, **kw):
    """White-box variant of run_serving: same fault wiring, returns the
    ServingSystem so tests can inspect per-request records."""
    system = ServingSystem(scen, spec, deadline_s=DEADLINE,
                           recovery=policy, **kw)
    plan = {name: [(s_to_ps(t), a, arg) for (t, a, arg) in acts]
            for name, acts in faults.items()}
    targets = (system.cores + system.programs + system.servers
               + system.fabric.fault_targets())
    inj = FaultInjector(plan)
    for comp in targets:
        comp.accept_hook(inj)
    inj.arm(targets)
    system.note_failover_plans(_fault_candidates(faults))
    system.run(until_s=until_s)
    return system


# --------------------------------------------------------------------------
# satellite: capped exponential backoff
# --------------------------------------------------------------------------

def test_backoff_ps_is_capped():
    p = RecoveryPolicy(backoff_base_s=1e-4, backoff_max_s=3e-4)
    delays = [p.backoff_ps(n) for n in range(1, 8)]
    assert delays[0] == s_to_ps(1e-4)
    assert delays[1] == s_to_ps(2e-4)
    assert all(d == s_to_ps(3e-4) for d in delays[2:])   # capped
    unbounded = RecoveryPolicy(backoff_base_s=1e-4, backoff_max_s=None)
    assert unbounded.backoff_ps(10) == s_to_ps(1e-4 * 2 ** 9)


def test_high_retry_requests_still_land_under_cap():
    # two kills force repeated aborts; with the cap every retry lands
    # well inside the trace horizon and nothing is stranded in backoff
    plan = {"chip1.prog": [(2e-3, "fail", None)],
            "chip2.prog": [(3e-3, "fail", None)]}
    policy = RecoveryPolicy(max_retries=16, backoff_base_s=3e-4,
                            backoff_max_s=6e-4)
    rep = run_serving(_scenario(tenants=1), spec=SMALL, deadline_s=DEADLINE,
                      recovery=policy, faults=plan)
    assert rep.chip_deaths == 2
    assert rep.dropped == 0                       # cap: retries all land
    assert rep.completed == rep.offered
    assert rep.in_flight == 0 and rep.queued == 0


# --------------------------------------------------------------------------
# satellite: idempotent abort on same-round duplicate verdicts
# --------------------------------------------------------------------------

def test_simultaneous_verdicts_do_not_double_penalize():
    # both chips of a 4-wide tenant die at the same instant: the monitor
    # declares them in one round, so two chip_dead verdicts land on the
    # server at the same timestamp.  The second abort must not charge a
    # retry to seats the first abort's re-admission just placed.
    plan = {"chip1.prog": [(3e-3, "fail", None)],
            "chip2.prog": [(3e-3, "fail", None)]}
    sys = _run_system(_scenario(spec=SMALL, tenants=1), SMALL, plan,
                      RecoveryPolicy())
    server = sys.servers[0]
    assert len(server.dead) == 2
    # every request resolved, and no record was penalized twice for the
    # one (double-verdict) abort event
    for rec in server.recs.values():
        assert rec.done_ps is not None or rec.dropped_ps is not None
        assert rec.retries <= 1


# --------------------------------------------------------------------------
# satellite: second failure during recovery + 12-way identity
# --------------------------------------------------------------------------

SECOND_KILL = {"chip1.prog": [(3e-3, "fail", None)],
               "chip2.prog": [(3.4e-3, "fail", None)]}  # inside backoff


def _second_failure_oracle(fabric):
    return run_serving(_scenario(tenants=1), spec=SMALL, fabric=fabric,
                       deadline_s=DEADLINE, recovery=True,
                       faults=SECOND_KILL)


_second_oracles: dict = {}


def _second_oracle(fabric):
    if fabric not in _second_oracles:
        _second_oracles[fabric] = _second_failure_oracle(fabric)
    return _second_oracles[fabric]


def test_second_failure_during_recovery_no_stuck_requests():
    rep = _second_oracle("analytic")
    assert rep.chip_deaths == 2
    assert rep.completed + rep.dropped == rep.offered
    assert rep.in_flight == 0 and rep.queued == 0


@pytest.mark.parametrize("fabric", ("analytic", "event"))
@pytest.mark.parametrize("sched,executor", SCHED_X_EXEC)
def test_second_failure_bit_identity(sched, executor, fabric):
    oracle = _second_oracle(fabric)
    rep = run_serving(_scenario(tenants=1), spec=SMALL, fabric=fabric,
                      scheduler=sched, executor=executor,
                      deadline_s=DEADLINE, recovery=True,
                      faults=SECOND_KILL)
    assert rep.summary() == oracle.summary()


# --------------------------------------------------------------------------
# spare pool: claim, capacity restore, return on rejoin
# --------------------------------------------------------------------------

def _spare_scenario(**kw):
    return _scenario(spec=WIDE, spares=1, **kw)


def test_spare_requires_policy():
    with pytest.raises(ValueError):
        ServingSystem(_spare_scenario(), WIDE)


def test_spare_claim_restores_capacity_and_availability():
    no_spare = run_serving(_scenario(spec=WIDE), spec=WIDE,
                           deadline_s=DEADLINE, recovery=True, faults=KILL)
    spare = run_serving(_spare_scenario(), spec=WIDE,
                        deadline_s=DEADLINE, recovery=True, faults=KILL)
    assert spare.chip_deaths == 1
    assert spare.spare_claims == 1 and spare.spare_returns == 0
    assert no_spare.spare_claims == 0
    # the claimed spare re-fills the mesh: capacity-weighted
    # availability strictly improves over serving degraded at 1/2
    assert (spare.tenant_effective_availability[0]
            > no_spare.tenant_effective_availability[0])
    # untouched tenant is perfect either way
    assert spare.tenant_effective_availability[1] == 1.0
    assert spare.completed == spare.offered
    assert spare.migrated_bytes > 0               # shards moved to the spare


def test_spare_returned_on_rejoin():
    rejoin = {"chip1.prog": [(2e-3, "fail", None), (4e-3, "recover", None)]}
    rep = run_serving(_spare_scenario(), spec=WIDE, deadline_s=DEADLINE,
                      recovery=True, faults=rejoin)
    assert rep.chip_deaths == 1 and rep.rejoins == 1
    assert rep.spare_claims == 1
    assert rep.spare_returns == 1                 # pool made whole
    assert rep.completed == rep.offered
    assert rep.in_flight == 0 and rep.queued == 0


def test_killing_the_claimed_spare_still_drains():
    # second failure lands on the freshly claimed spare itself: the pool
    # is empty, so the tenant re-meshes degraded -- nothing sticks
    plan = {"chip1.prog": [(3e-3, "fail", None)],
            "chip4.prog": [(4.2e-3, "fail", None)]}
    rep = run_serving(_spare_scenario(tenants=1), spec=WIDE,
                      deadline_s=DEADLINE, recovery=True, faults=plan)
    assert rep.chip_deaths == 2
    assert rep.spare_claims >= 1
    assert rep.completed + rep.dropped == rep.offered
    assert rep.in_flight == 0 and rep.queued == 0


@pytest.mark.parametrize("fabric", ("analytic", "event"))
@pytest.mark.parametrize("sched,executor", SCHED_X_EXEC)
def test_spare_failover_bit_identity(sched, executor, fabric):
    key = ("spare", fabric)
    if key not in _second_oracles:
        _second_oracles[key] = run_serving(
            _spare_scenario(), spec=WIDE, fabric=fabric,
            deadline_s=DEADLINE, recovery=True, faults=KILL)
    oracle = _second_oracles[key]
    rep = run_serving(_spare_scenario(), spec=WIDE, fabric=fabric,
                      scheduler=sched, executor=executor,
                      deadline_s=DEADLINE, recovery=True, faults=KILL)
    assert rep.summary() == oracle.summary()


# --------------------------------------------------------------------------
# KV migration / checkpointed prefill
# --------------------------------------------------------------------------

def test_migration_saves_prefill_and_breakdown_stays_exact():
    sys = _run_system(_scenario(tenants=1), SMALL, KILL, RecoveryPolicy())
    server = sys.servers[0]
    assert server.prefill_saved_tokens > 0        # checkpoints migrated
    assert server.prefill_recompute_tokens > 0    # the dead shard's slice
    assert server.migrated_bytes > 0              # priced fabric transfer
    for rec in server.recs.values():
        if rec.done_ps is None:
            continue
        q = rec.admit_ps - rec.arrival_ps
        p = rec.first_ps - rec.admit_ps
        d = rec.done_ps - rec.first_ps
        assert q >= 0 and p > 0 and d >= 0
        assert q + p + d == rec.done_ps - rec.arrival_ps  # int-exact


def test_migration_traffic_visible_in_fabric_report():
    rep = run_serving(_scenario(tenants=1), spec=SMALL, deadline_s=DEADLINE,
                      recovery=True, faults=KILL)
    healthy = run_serving(_scenario(tenants=1), spec=SMALL)
    assert rep.migrated_bytes > 0
    # migration rides all-to-all chunks on a dense tenant that has none
    assert rep.fabric_traffic.get("all-to-all", 0) > 0
    assert healthy.fabric_traffic.get("all-to-all", 0) == 0


def test_healthy_run_unchanged_by_failover_layer():
    # no faults: checkpointing must not change a single timestamp
    base = run_serving(_scenario(), spec=SMALL)
    assert base.prefill_saved_tokens == 0
    assert base.migrated_bytes == 0
    assert base.spare_claims == 0
    assert base.completed == base.offered


# --------------------------------------------------------------------------
# quorum detection
# --------------------------------------------------------------------------

def test_quorum_unreachable_keeps_suspect_alive():
    # 2-chip tenant: a dead chip can gather at most 2 accusers (its peer
    # + the tenant server); quorum=3 is unreachable, so the chip is
    # never fenced -- the partitioned-but-alive scenario.  The tenant
    # stalls (every iteration times out), so run to a horizon.
    policy = RecoveryPolicy(quorum=3, max_retries=2)
    rep = run_serving(_scenario(), spec=SMALL, deadline_s=DEADLINE,
                      recovery=policy, faults=KILL, until_s=0.012)
    assert rep.chip_deaths == 0                   # evidence below quorum
    assert rep.collective_timeouts >= 1
    assert rep.dropped > 0                        # retries burn out instead


def test_quorum_reachable_fences_the_chip():
    policy = RecoveryPolicy(quorum=2)
    rep = run_serving(_scenario(), spec=SMALL, deadline_s=DEADLINE,
                      recovery=policy, faults=KILL)
    assert rep.chip_deaths == 1
    assert rep.completed == rep.offered
    assert rep.in_flight == 0 and rep.queued == 0


def test_default_quorum_is_peer_majority():
    p = RecoveryPolicy()
    rep = run_serving(_scenario(tenants=1), spec=SMALL, deadline_s=DEADLINE,
                      recovery=p, faults=KILL)
    # 4-chip tenant: majority of 3 live peers = 2 accusers -- reachable
    # through gossip + the coordinator's timeout roster
    assert rep.chip_deaths == 1
    assert rep.completed + rep.dropped == rep.offered


def test_slow_quorum_still_reconciles_unseated_checkpoints():
    # With quorum=2 the verdict lags the first coll_failed abort, so the
    # interrupted request is in backoff (not seated) when the chip is
    # finally fenced.  Its checkpoint still loses the dead chip's shard:
    # the lost fraction is recomputed and the survivors' share is priced
    # as migration -- no free full-checkpoint resume on the new mesh.
    scen = build_scenario(WIDE, rate_rps=600.0, duration_s=0.02, seed=11,
                          spares=1)
    faults = {"chip1.prog": [(5e-3, "fail", None)]}
    rep = run_serving(scen, spec=WIDE, deadline_s=DEADLINE,
                      recovery=RecoveryPolicy(quorum=2), faults=faults)
    assert rep.chip_deaths == 1 and rep.spare_claims == 1
    assert rep.prefill_recompute_tokens > 0       # lost shard recomputed
    assert rep.migrated_bytes > 0                 # surviving share priced
    assert rep.completed + rep.dropped == rep.offered
