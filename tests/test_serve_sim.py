"""Open-loop serving simulation: generator determinism, slot-ledger
properties, latency accounting exactness, the scheduler x executor x
fabric bit-identity matrix (healthy + fault-injected), and the
fault-produces-the-tail assertions.  See docs/serving.md."""
import numpy as np
import pytest

from repro.core import SystemSpec
from repro.serve.sim import (GENERATORS, ServeSizing, ServingScenario,
                             ServingSystem, SlotLedger, TenantSpec,
                             build_scenario, make_requests, run_serving)

SMALL = SystemSpec(pod_shape=(2, 2))

EXECUTOR_VARIANTS = ("threads", "procs")
SCHED_X_EXEC = [(s, e) for s in ("batch", "lookahead", "bounded")
                for e in EXECUTOR_VARIANTS]

STRAGGLER_LINK = {"fabric.pod0.ici[0,1]+x": [(0.0, "slow", 32.0)]}


def _scenario(seed=3, rate=800.0, duration=0.006, **kw):
    scen = build_scenario(SMALL, rate_rps=rate, duration_s=duration,
                          seed=seed, **kw)
    assert scen is not None
    return scen


_oracles: dict = {}


def _oracle(key, **kw):
    """Serial-scheduler reference runs, one sim per distinct config."""
    if key not in _oracles:
        _oracles[key] = run_serving(_scenario(), spec=SMALL, **kw)
    return _oracles[key]


# --------------------------------------------------------------------------
# arrival-trace generators: seeded determinism + rate sanity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_generator_deterministic_and_ordered(name):
    gen = GENERATORS[name]
    a = gen(500.0, 0.1, seed=7)
    b = gen(500.0, 0.1, seed=7)
    assert np.array_equal(a, b)                      # same seed, same trace
    c = gen(500.0, 0.1, seed=8)
    assert not np.array_equal(a, c)                  # seed actually matters
    assert len(a) > 0
    assert np.all(np.diff(a) > 0)                    # strictly increasing
    assert 0.0 < a[0] and a[-1] < 0.1                # inside the window


def test_poisson_mean_interarrival_bound():
    t = GENERATORS["poisson"](1000.0, 2.0, seed=0)
    mean_gap = np.diff(t).mean()
    assert 0.8e-3 < mean_gap < 1.25e-3               # ~1/rate


def test_bursty_rate_between_states():
    # MMPP alternates rate/4 and rate*4; long-run mean stays in between
    t = GENERATORS["bursty"](1000.0, 2.0, seed=0)
    assert 1000.0 * 2.0 / 4.5 < len(t) < 1000.0 * 2.0 * 4.5
    # and it is actually burstier than Poisson: CV^2 of gaps > 1
    gaps = np.diff(t)
    cv2 = gaps.var() / gaps.mean() ** 2
    assert cv2 > 1.2


def test_diurnal_rate_bounds_and_modulation():
    rate, dur = 1000.0, 2.0
    t = GENERATORS["diurnal"](rate, dur, seed=0, depth=0.8, period_s=dur)
    assert 0.5 * rate * dur < len(t) < 1.5 * rate * dur
    # first half-period runs above the base rate, second half below
    first, second = (t < dur / 2).sum(), (t >= dur / 2).sum()
    assert first > 1.3 * second


def test_make_requests_deterministic_and_ranged():
    times = GENERATORS["poisson"](500.0, 0.05, seed=1)
    a = make_requests(times, seed=2, prompt_range=(8, 16),
                      decode_range=(2, 5))
    b = make_requests(times, seed=2, prompt_range=(8, 16),
                      decode_range=(2, 5))
    assert a == b
    assert all(8 <= r.prompt_len <= 16 for r in a)
    assert all(2 <= r.decode_len <= 5 for r in a)
    assert [r.uid for r in a] == list(range(len(a)))
    assert make_requests(times, seed=3)[0] != a[0]


def test_unknown_generator_rejected():
    with pytest.raises(ValueError, match="unknown arrival generator"):
        build_scenario(SMALL, arrival="lognormal")


# --------------------------------------------------------------------------
# slot ledger: capacity as pure accounting
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ledger_random_interleaving_invariants(seed):
    rng = np.random.default_rng(seed)
    led = SlotLedger(capacity=4)
    waiting = list(range(60))
    seated: list = []
    while waiting or seated:
        if seated and (not waiting or not led.has_free()
                       or rng.uniform() < 0.5):
            uid = seated.pop(rng.integers(len(seated)))
            led.release(uid)
        else:
            uid = waiting.pop(0)
            led.admit(uid)
            seated.append(uid)
        assert led.in_use <= led.capacity            # never over capacity
        assert led.in_use == len(seated)
    assert led.completed == set(range(60))           # none lost
    assert led.peak <= 4 and led.in_use == 0


def test_ledger_rejects_misuse():
    led = SlotLedger(2)
    led.admit(0)
    with pytest.raises(ValueError, match="already seated"):
        led.admit(0)
    led.admit(1)
    with pytest.raises(RuntimeError, match="no free slot"):
        led.admit(2)
    led.release(0)
    with pytest.raises(ValueError, match="already completed"):
        led.admit(0)                                 # uids never come back
    with pytest.raises(ValueError, match="double-completed"):
        led.release(0)
    with pytest.raises(ValueError, match="not seated"):
        led.release(9)
    with pytest.raises(ValueError, match="capacity"):
        SlotLedger(0)


def test_ledger_lowest_free_slot_first():
    led = SlotLedger(3)
    assert [led.admit(u) for u in (10, 11, 12)] == [0, 1, 2]
    led.release(11)
    led.release(10)
    assert led.admit(13) == 0                        # lowest freed slot


def test_ledger_hypothesis_capacity_and_conservation():
    pytest.importorskip("hypothesis",
                        reason="hypothesis not installed in this image")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(cap=st.integers(1, 8),
           actions=st.lists(st.tuples(st.booleans(), st.integers(0, 63)),
                            max_size=120))
    def run(cap, actions):
        led = SlotLedger(cap)
        seated: set = set()
        for admit, pick in actions:
            if admit and led.has_free():
                uid = next((u for u in range(64)
                            if u not in seated and u not in led.completed),
                           None)
                if uid is None:
                    continue
                led.admit(uid)
                seated.add(uid)
            elif seated:
                uid = sorted(seated)[pick % len(seated)]
                led.release(uid)
                seated.remove(uid)
            assert led.in_use <= cap
            assert led.in_use + len(led.free) == cap  # slots conserved
            assert set(led.seated) == seated
            assert not (seated & led.completed)       # no double life

    run()


def test_ledger_hypothesis_queue_plus_service_is_e2e():
    pytest.importorskip("hypothesis",
                        reason="hypothesis not installed in this image")
    from hypothesis import given, settings, strategies as st

    # The sim stores integer-ps stamps; queue + prefill + decode must
    # reconstruct end-to-end latency with zero residue for ANY stamps
    # (this is why _ReqLog keeps ints and never converts to seconds).
    @settings(max_examples=100, deadline=None)
    @given(arrival=st.integers(0, 10**15), queue=st.integers(0, 10**12),
           prefill=st.integers(1, 10**12), decode=st.integers(0, 10**12))
    def run(arrival, queue, prefill, decode):
        admit = arrival + queue
        first = admit + prefill
        done = first + decode
        assert (admit - arrival) + (first - admit) + (done - first) \
            == done - arrival
        assert float(done - arrival) / 1e12 == (done - arrival) / 1e12

    run()


# --------------------------------------------------------------------------
# scenario construction + system validation
# --------------------------------------------------------------------------

def test_build_scenario_places_disjoint_row_blocks():
    scen = _scenario()
    assert [t.devices for t in scen.tenants] == [(0, 1), (2, 3)]
    assert build_scenario(SMALL, tenants=3) is None   # no row per tenant
    big = build_scenario(SystemSpec(pod_shape=(4, 4)), tenants=2)
    assert [t.devices for t in big.tenants] == [
        tuple(range(0, 8)), tuple(range(8, 16))]


def test_overlapping_or_out_of_range_tenants_rejected():
    t0 = _scenario().tenants[0]
    overlap = ServingScenario("bad", (t0, t0))
    with pytest.raises(ValueError, match="two tenants"):
        ServingSystem(overlap, SMALL)
    import dataclasses
    off = dataclasses.replace(t0, devices=(0, 99))
    with pytest.raises(ValueError, match="outside"):
        ServingSystem(ServingScenario("bad", (off,)), SMALL)


def test_sizing_is_exact_integers():
    t = _scenario().tenants[0]
    s = ServeSizing(t)
    for b in range(1, t.slots + 1):
        assert isinstance(s.ar_bytes(b), int)
        assert s.ar_bytes(b) == b * s.ar_bytes(1)     # linear in batch
    assert s.prefill_flops(32) == 2 * s.prefill_flops(16)


# --------------------------------------------------------------------------
# serving run: accounting exactness + capacity + open-loop behavior
# --------------------------------------------------------------------------

def test_latency_breakdown_sums_exactly():
    sys = ServingSystem(_scenario(), SMALL)
    sys.run()
    checked = 0
    for server in sys.servers:
        for rec in server.recs.values():
            assert rec.done_ps is not None            # everything drains
            q = rec.admit_ps - rec.arrival_ps
            p = rec.first_ps - rec.admit_ps
            d = rec.done_ps - rec.first_ps
            assert q >= 0 and p > 0 and d >= 0
            assert q + p + d == rec.done_ps - rec.arrival_ps  # int-exact
            checked += 1
    assert checked == sum(len(t.requests) for t in _scenario().tenants)


def test_report_counts_and_goodput():
    rep = _oracle(("analytic", "none"))
    assert rep.offered == rep.completed + rep.in_flight + rep.queued
    assert rep.completed == rep.offered               # drained run
    assert rep.goodput_rps > 0 and rep.offered_rps > 0
    assert rep.p50_s <= rep.p99_s <= rep.max_s
    assert all(1 <= p <= 4 for p in rep.peak_slots)
    assert rep.devices == 4 and rep.tenants == 2
    assert len(rep.tenant_p99_s) == 2


def test_summary_excludes_execution_fields():
    rep = _oracle(("analytic", "none"))
    s = rep.summary()
    assert "scheduler" not in s and "executor" not in s
    assert "p99_s" in s and "per_request" in s


def test_slots_cap_batch_and_queueing_appears_under_overload():
    calm = run_serving(_scenario(seed=5, rate=300.0, slots=2),
                       spec=SMALL)
    slam = run_serving(_scenario(seed=5, rate=4000.0, slots=2),
                       spec=SMALL)
    assert all(p <= 2 for p in slam.peak_slots)       # capacity respected
    assert max(slam.peak_slots) == 2                  # and actually reached
    assert slam.queue_mean_s > calm.queue_mean_s      # admission waited
    assert slam.p99_s > calm.p99_s                    # the knee, in small


def test_collective_count_matches_iterations():
    dense = _oracle(("analytic", "none"))
    assert dense.collectives_completed == dense.iterations * 4
    moe = run_serving(_scenario(moe=True), spec=SMALL)
    assert moe.collectives_completed == moe.iterations * 6  # +2 a2a
    assert moe.summary() != dense.summary()


# --------------------------------------------------------------------------
# bit-identity matrix: scheduler x executor x fabric, healthy + faulted
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fabric", ("analytic", "event"))
@pytest.mark.parametrize("sched,executor", SCHED_X_EXEC)
def test_serving_bit_identity(sched, executor, fabric):
    oracle = _oracle((fabric, "none"), fabric=fabric)
    rep = run_serving(_scenario(), spec=SMALL, scheduler=sched,
                      executor=executor, max_workers=2, fabric=fabric)
    assert rep.summary() == oracle.summary()
    assert rep.scheduler == sched and rep.executor == executor


@pytest.mark.parametrize("sched,executor",
                         [("batch", "threads"), ("lookahead", "procs"),
                          ("bounded", "procs")])
def test_serving_bit_identity_under_fault(sched, executor):
    oracle = _oracle(("event", "straggler"), fabric="event",
                     faults=STRAGGLER_LINK)
    rep = run_serving(_scenario(), spec=SMALL, scheduler=sched,
                      executor=executor, max_workers=2, fabric="event",
                      faults=STRAGGLER_LINK)
    assert rep.summary() == oracle.summary()
    assert oracle.summary() != _oracle(("event", "none"),
                                       fabric="event").summary()


# --------------------------------------------------------------------------
# the fabric, not the generator, produces the tail
# --------------------------------------------------------------------------

def test_straggler_link_raises_event_p99():
    healthy = _oracle(("event", "none"), fabric="event")
    faulted = _oracle(("event", "straggler"), fabric="event",
                      faults=STRAGGLER_LINK)
    assert faulted.p99_s > healthy.p99_s
    # the faulted link is on tenant 0's ring; its tail takes the hit,
    # tenant 1 is bit-unchanged (its links are disjoint)
    assert faulted.tenant_p99_s[0] > healthy.tenant_p99_s[0]
    assert faulted.tenant_p99_s[1] == healthy.tenant_p99_s[1]
    assert faulted.completed == healthy.completed     # degraded, not broken


def test_analytic_run_is_unchanged_and_rejects_link_plans():
    a = _oracle(("analytic", "none"))
    b = run_serving(_scenario(), spec=SMALL)          # fresh run, same seed
    assert a.summary() == b.summary()                 # generator-stable
    with pytest.raises(ValueError, match="require fabric='event'"):
        run_serving(_scenario(), spec=SMALL, fabric="analytic",
                    faults=STRAGGLER_LINK)


def test_transient_link_stalls_only_the_affected_tenant():
    rep = run_serving(
        _scenario(), spec=SMALL, fabric="event",
        faults={"fabric.pod0.ici[0,1]+x": [(1e-3, "transient", 1e-3)]})
    healthy = _oracle(("event", "none"), fabric="event")
    assert rep.completed < healthy.completed          # dropped chunks stall
    assert rep.in_flight + rep.queued > 0             # the ring never drains
    # tenant 1 shares no link with the fault: completes its whole trace
    assert rep.tenant_p99_s[1] == healthy.tenant_p99_s[1]


def test_chip_straggler_degrades_analytic_and_event_alike():
    healthy = _oracle(("analytic", "none"))
    slow = run_serving(_scenario(), spec=SMALL,
                       faults={"chip0.core": [(0.0, "slow", 4.0)]})
    assert slow.tenant_p99_s[0] > healthy.tenant_p99_s[0]
    assert slow.mean_s > healthy.mean_s


# --------------------------------------------------------------------------
# sweep integration
# --------------------------------------------------------------------------

def test_sweep_exposes_serving_scenarios():
    from tools import sweep
    assert {"serving_poisson", "serving_overload", "serving_burst",
            "serving_diurnal", "serving_moe"} <= set(sweep.SCENARIOS)
    cfgs = sweep.expand_grid({"scenario": ["serving_poisson"],
                              "topology": ["pod2x2"],
                              "scheduler": ["serial"],
                              "fabric": ["analytic"],
                              "faults": ["none", "slow_link"]})
    # slow_link needs the event fabric: only the healthy combo expands
    assert len(cfgs) == 1


def test_sweep_runs_serving_config_with_latency_row():
    from tools import sweep
    cfg = sweep.expand_grid({"scenario": ["serving_poisson"],
                             "topology": ["pod2x2"],
                             "scheduler": ["serial"],
                             "fabric": ["analytic"],
                             "faults": ["none"]})[0]
    row = sweep.run_config(cfg)
    assert row["p99_s"] > row["p50_s"] > 0
    assert row["completed"] == row["offered"] > 0
    assert row["goodput_rps"] > 0
    assert "error" not in row


# --------------------------------------------------------------------------
# recovery layer: detection -> abort -> re-mesh -> requeue (docs/faults.md)
# --------------------------------------------------------------------------

DEADLINE = 5e-4
KILL = {"chip1.prog": [(3e-3, "fail", None)]}          # tenant 0, mid-trace
REJOIN = {"chip1.prog": [(2e-3, "fail", None), (4e-3, "recover", None)]}


def _rec_oracle(key, **kw):
    """Serial reference runs for the recovery matrix (cached like
    _oracle; recovery runs are slower, so one sim per config)."""
    if key not in _oracles:
        _oracles[key] = run_serving(_scenario(), spec=SMALL,
                                    deadline_s=DEADLINE, recovery=True, **kw)
    return _oracles[key]


def test_ledger_evict_reclaims_seat_without_retiring_uid():
    led = SlotLedger(2)
    led.admit(7)
    led.admit(8)
    assert led.evict(7) == 0
    assert led.in_use == 1 and 7 not in led.completed
    assert led.admit(7) == 0                          # re-admit works
    led.release(7)
    with pytest.raises(ValueError, match="already completed"):
        led.evict(7)                                  # done is done
    with pytest.raises(ValueError, match="not seated"):
        led.evict(9)
    assert led.evict(8) == 1 and led.in_use == 0


def test_recovery_serves_through_chip_kill():
    rep = _rec_oracle(("rec", "analytic", "kill"), faults=KILL)
    assert rep.offered == rep.completed + rep.dropped  # zero stuck
    assert rep.retries > 0 and rep.recoveries >= 1
    assert rep.chip_deaths == 1 and rep.collective_timeouts >= 1
    # availability dips for the tenant that lost a chip, nobody else
    assert rep.tenant_availability[0] < 1.0
    assert rep.tenant_availability[1] == 1.0
    assert rep.tenant_outage_s[0] > 0 and rep.tenant_outage_s[1] == 0
    assert rep.outage_windows[0] and not rep.outage_windows[1]
    assert rep.goodput_in_outage_rps < rep.goodput_outside_outage_rps


@pytest.mark.parametrize("fabric", ("analytic", "event"))
@pytest.mark.parametrize("sched,executor", SCHED_X_EXEC)
def test_recovery_bit_identity_mid_recovery(sched, executor, fabric):
    """The hard invariant: death + abort + re-mesh + requeue all ride
    engine events, so every scheduler x executor reproduces the serial
    oracle bit-for-bit *while* the trace recovers."""
    oracle = _rec_oracle(("rec", fabric, "kill"), fabric=fabric, faults=KILL)
    rep = run_serving(_scenario(), spec=SMALL, scheduler=sched,
                      executor=executor, max_workers=2, fabric=fabric,
                      deadline_s=DEADLINE, recovery=True, faults=KILL)
    assert rep.summary() == oracle.summary()
    assert rep.retries == oracle.retries > 0


def test_recovery_cross_fabric_behavioral_identity():
    """Analytic and event price these small rings identically, so even
    mid-recovery only the fabric-artifact fields may differ."""
    a = _rec_oracle(("rec", "analytic", "kill"), faults=KILL).summary()
    e = _rec_oracle(("rec", "event", "kill"), fabric="event",
                    faults=KILL).summary()
    skip = ("events", "fabric", "link_report", "link_utilization")
    assert {k: v for k, v in a.items() if k not in skip} \
        == {k: v for k, v in e.items() if k not in skip}


def test_rejoin_rolls_the_chip_back_in():
    rep = run_serving(_scenario(), spec=SMALL, deadline_s=DEADLINE,
                      recovery=True, faults=REJOIN)
    assert rep.rejoins == 1 and rep.chip_deaths == 1
    assert rep.completed == rep.offered                # everything drains
    assert rep.retries > 0
    # the rejoin re-mesh itself is loss-free: nothing gets dropped
    assert rep.dropped == 0


def test_transient_link_served_through_with_recovery():
    """PR 8 left this stalling forever (in_flight + queued > 0); with a
    deadline + recovery the lost chunks surface as a timeout, the
    iteration retries, and the trace completes -- no chip is falsely
    declared dead (the roster was complete; the fabric stalled)."""
    rep = run_serving(
        _scenario(), spec=SMALL, fabric="event", deadline_s=DEADLINE,
        recovery=True,
        faults={"fabric.pod0.ici[0,1]+x": [(1e-3, "transient", 1e-3)]})
    assert rep.completed == rep.offered
    assert rep.retries >= 1 and rep.recoveries >= 1
    assert rep.chip_deaths == 0


def test_deadline_threads_through_run_serving_healthy():
    """deadline_s alone (recovery=False) must not perturb a healthy run:
    no timeouts, identical latency behavior (only the engine's internal
    event count may differ -- deadline events exist now)."""
    base = _oracle(("analytic", "none"))
    rep = run_serving(_scenario(), spec=SMALL, deadline_s=DEADLINE,
                      recovery=False)
    assert rep.collective_timeouts == 0
    assert rep.p99_s == base.p99_s and rep.completed == base.completed
    assert rep.retries == rep.recoveries == rep.chip_deaths == 0


def test_detection_only_mode_counts_timeouts_but_stalls():
    """recovery=False keeps PR 8 semantics under a kill: the signal
    fires, nobody reacts, the tenant stalls -- the explicit contrast
    that motivates the recovery layer."""
    rep = run_serving(_scenario(), spec=SMALL, deadline_s=DEADLINE,
                      recovery=False, faults=KILL)
    assert rep.collective_timeouts >= 1
    assert rep.completed < rep.offered
    assert rep.retries == 0 and rep.recoveries == 0


def test_heartbeat_detects_death_on_collective_free_tenant():
    """Single-chip tenants never run collectives, so the deadline signal
    can't fire -- only the heartbeat probe path can declare the death.
    The dead tenant's unserviceable requests stay queued (there is no
    surviving chip to re-mesh onto) but the run still terminates."""
    tiny = SystemSpec(pod_shape=(2, 1))
    scen = build_scenario(tiny, rate_rps=800.0, duration_s=0.006, seed=3)
    assert [t.devices for t in scen.tenants] == [(0,), (1,)]
    rep = run_serving(scen, spec=tiny, deadline_s=DEADLINE, recovery=True,
                      faults={"chip0.prog": [(2e-3, "fail", None)]})
    assert rep.chip_deaths == 1 and rep.collective_timeouts == 0
    assert rep.tenant_availability[0] < 1.0
    assert rep.tenant_availability[1] == 1.0
    assert rep.queued > 0                              # dead tenant's tail
    assert rep.completed + rep.queued == rep.offered
